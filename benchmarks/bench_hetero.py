"""Paper Table 3 / §5.8: heterogeneous-graph R-GCN training.

R-GCN relation transforms commute with aggregation (Σ_u w·(H W_r)_u =
(Σ_u w·H_u) W_r), so typed aggregation runs on feature slices and the W_r
mix happens post-gather — the TP extension the paper calls 'natural'.
Compares single-device coupled R-GCN vs per-relation decoupled epoch time
and validates the commuted formulation numerically.
"""
from __future__ import annotations

import time

from .common import emit, write_json


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.gnn import layers as L
    from repro.gnn import models as M
    from repro.graph import heterogeneous_sbm

    data = heterogeneous_sbm(n=2048, num_classes=8, num_edge_types=4,
                             feat_dim=64, avg_degree=12, seed=5)
    g = L.edge_list_dev(data.graph)
    etypes = jnp.asarray(data.edge_types)
    x = jnp.asarray(data.features)
    cfg = M.GNNConfig(model="rgcn", in_dim=64, hidden_dim=64, num_classes=8,
                      num_layers=1, decoupled=False, num_edge_types=4)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    coupled = jax.jit(lambda p, xx: M.coupled_forward(p, cfg, g, xx,
                                                      etypes))
    out_ref = coupled(params, x)

    def decoupled_commuted(p, xx):
        """aggregate-per-relation on slices, transform after gather."""
        h = xx
        rel_w = p["rel"][0]                     # (R, D, D_out)
        acc = jnp.zeros((xx.shape[0], rel_w.shape[-1]), xx.dtype)
        for r in range(cfg.num_edge_types):
            wr = jnp.where(etypes == r, g.weight, 0.0)
            agg = L.aggregate(g, h, edge_weight=wr)   # sliceable
            acc = acc + agg @ rel_w[r]                # post-gather mix
        return acc + L.dense(p["self"][0], h)
    dec = jax.jit(decoupled_commuted)
    out_dec = dec(params, x)
    err = float(jnp.abs(out_ref - out_dec).max())

    def timed(fn, iters=5):
        o = fn(params, x)
        jax.block_until_ready(o)
        t0 = time.perf_counter()
        for _ in range(iters):
            o = fn(params, x)
        jax.block_until_ready(o)
        return (time.perf_counter() - t0) / iters

    t_c = timed(coupled)
    t_d = timed(dec)
    emit("hetero_rgcn_coupled", t_c * 1e6, f"commute_err={err:.2e}")
    emit("hetero_rgcn_decoupled_commuted", t_d * 1e6,
         f"speed_ratio={t_c / t_d:.2f}")
    assert err < 1e-3, err

    write_json("hetero")


if __name__ == "__main__":
    main()
