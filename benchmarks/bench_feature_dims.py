"""Paper Fig. 14: per-epoch runtime vs input feature dimension."""
from __future__ import annotations

from .common import record_output, run_subprocess_bench, write_json


def main():
    for dim in (64, 128, 256, 512):
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,decoupled_pipelined",
                  "--feat-dim", str(dim), "--n", "2048",
                  "--tag-prefix", f"featdim_{dim}_"])
        print(record_output(out), end="")

    write_json("feature_dims")


if __name__ == "__main__":
    main()
