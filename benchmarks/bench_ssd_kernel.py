"""Kernel microbenchmark: Pallas SSD intra-chunk kernel (interpret mode)
vs the chunked jnp path and the dense dual oracle.  Reports the structural
quantities for TPU (VMEM working set, modeled HBM traffic vs the jnp
path's censused (Q,Q) shuffle traffic)."""
from __future__ import annotations

import time

from .common import emit, write_json


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.ssd import (hbm_bytes_model, ssd_chunked_pallas,
                                   ssd_dense_ref)
    from repro.nn.ssm import ssd_chunked

    b, s, h, p, n, chunk = 2, 512, 8, 64, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(0.3 * jax.random.normal(ks[2], (h,)))
    bm = jax.random.normal(ks[3], (b, s, n)) / np.sqrt(n)
    cm = jax.random.normal(ks[4], (b, s, n)) / np.sqrt(n)

    jnp_fn = jax.jit(lambda *ar: ssd_chunked(*ar, chunk)[0])
    pl_fn = jax.jit(lambda *ar: ssd_chunked_pallas(*ar, chunk,
                                                   interpret=True)[0])
    ref = ssd_dense_ref(x, dt, a, bm, cm)
    err_j = float(jnp.abs(jnp_fn(x, dt, a, bm, cm) - ref).max())
    err_p = float(jnp.abs(pl_fn(x, dt, a, bm, cm) - ref).max())

    def timed(fn, iters=3):
        o = fn(x, dt, a, bm, cm)
        jax.block_until_ready(o)
        t0 = time.perf_counter()
        for _ in range(iters):
            o = fn(x, dt, a, bm, cm)
        jax.block_until_ready(o)
        return (time.perf_counter() - t0) / iters

    t_j = timed(jnp_fn)
    t_p = timed(pl_fn)
    kernel_bytes = hbm_bytes_model(b, s, h, p, n, chunk=chunk)
    qq_bytes = b * (s // chunk) * h * chunk * chunk * 4 * 3  # L/scores/M
    vmem_kb = (chunk * p + 2 * chunk * n + 3 * chunk * chunk
               + chunk * p + p * n) * 4 / 1024
    emit("ssd_jnp_chunked", t_j * 1e6, f"err_vs_dense={err_j:.2e}")
    emit("ssd_pallas_interpret", t_p * 1e6,
         f"err_vs_dense={err_p:.2e};vmem_per_step_kb={vmem_kb:.0f};"
         f"hbm_model_bytes={kernel_bytes:.3e};"
         f"qq_traffic_avoided={qq_bytes:.3e}")

    write_json("ssd_kernel")


if __name__ == "__main__":
    main()
