"""Paper Table 2 analog: per-epoch runtime, GCN + GAT, all systems.

Systems: DP baseline (DepComm halo exchange), naive TP, decoupled TP (DT),
decoupled+pipelined (DT+IP) — on 8 workers (forced host devices), each on
both engine backends (explicit shard_map vs pjit/constraint: same wire
bytes, XLA-scheduled overlap may shift wall-clock).
"""
from __future__ import annotations

from .common import record_output, run_subprocess_bench, write_json


def main():
    for model in ("gcn", "gat"):
        modes = "dp,naive,decoupled,decoupled_pipelined" if model == "gcn" \
            else "naive,decoupled,decoupled_pipelined"
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", modes, "--model", model,
                  "--backends", "explicit,constraint",
                  "--tag-prefix", f"overall_{model}_"])
        print(record_output(out), end="")
    write_json("overall")


if __name__ == "__main__":
    main()
