"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only name]``
prints ``name,us_per_call,derived`` CSV rows per benchmark.

| paper artifact            | module              |
|---------------------------|---------------------|
| Table 2 (overall)         | bench_overall       |
| Fig. 3/10a (load balance) | bench_load_balance  |
| Fig. 8/10b (comm volume)  | bench_comm_volume   |
| Fig. 11 (gain ablation)   | bench_ablation      |
| Fig. 12 (cluster scaling) | bench_scaling       |
| Fig. 13 (model layers)    | bench_layers        |
| Fig. 14 (feature dims)    | bench_feature_dims  |
| Fig. 16 (accuracy)        | bench_accuracy      |
| Table 3 (heterogeneous)   | bench_hetero        |
| Table 4 (cost breakdown)  | bench_breakdown     |
| kernel microbench         | bench_spmm_kernel   |
| kernel microbench (attn)  | bench_flash_kernel  |
"""
from __future__ import annotations

import argparse
import importlib
import sys
import time
import traceback

from . import common

MODULES = [
    "bench_load_balance",
    "bench_comm_volume",
    "bench_overall",
    "bench_ablation",
    "bench_scaling",
    "bench_layers",
    "bench_feature_dims",
    "bench_accuracy",
    "bench_hetero",
    "bench_breakdown",
    "bench_spmm_kernel",
    "bench_flash_kernel",
    "bench_ssd_kernel",
    "bench_oocstream",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of module names")
    args = ap.parse_args()
    mods = MODULES if not args.only else [
        m for m in MODULES if m in set(args.only.split(","))]
    failures = []
    for name in mods:
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        common.reset_rows()
        try:
            importlib.import_module(f"benchmarks.{name}").main()
            print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failures.append(name)
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
