"""Child-process body for the distributed GNN benchmarks.

Invoked by run.py / bench modules with a forced host device count; times
one full-graph training epoch per (mode, backend, model, graph, layers,
dims) combination passed on the command line.  Prints CSV rows:

    <tag>,<us_per_epoch>,<derived>

Tags are ``<prefix><mode>`` for the default explicit backend and
``<prefix><mode>_constraint`` for the constraint backend, so existing
consumers of the explicit rows are unaffected.  ``--data R`` trains on a
hybrid (data=R, model=devices/R) mesh instead of pure TP; hybrid rows
get a ``_d<R>x<model>`` suffix and report ``replicas=R``.

Measured communication columns (always present): the **telemetry
ledger** — trace-time collective counters collected at the runtime choke
point while the train step traces (:mod:`repro.runtime.telemetry`):

    led_a2a    per-device model-axis all-to-all ring wire bytes per
               train step (fwd + autodiff-mirrored bwd)
    led_a2a_n  its collective count (decoupled: the paper's 4/epoch)
    led_ag     per-device all-gather wire bytes, all axes
    led_agd    the data-axis (replica_gather) share — nonzero iff the
               hybrid replica plumbing ran

``--multihost`` joins a ``jax.distributed`` job from the env contract
(``COORDINATOR_ADDRESS`` / ``NUM_PROCESSES`` / ``PROCESS_ID`` — see
:mod:`repro.runtime.distributed` and ``scripts/launch_multihost.sh``):
the mesh then spans the *global* devices while this process holds only
its local slice, bundles are committed per-host, and rows, ledger
asserts and census output are **process-0-only** (every process traces
the identical ledger; N processes printing or racing to raise would
corrupt the parent's CSV parse).

``--assert-ledger`` additionally asserts, in-process at full precision,
that the ledger matches the analytic §3.2 formulas
(:func:`benchmarks.bench_comm_volume.expected_ledger`) — and the HLO
census when enabled.  ``--audit`` runs the tier-2 structural audit
(:mod:`repro.analysis.jaxpr_audit`): collective primitives counted in
the step's closed jaxpr must equal what the ledger implies, plus a
phantom-entry self-check proving the audit would catch a forged
counter.  ``--hlo-census`` appends the **deprecated** HLO-regex census
columns (a2a/ag/ar/rs = per-device wire bytes split by HLO kind) as an
independent cross-check of the ledger — the jaxpr audit is its
structural replacement, so the flag emits a DeprecationWarning.
``--trace-only`` skips execution and timing entirely (rows carry 0.0 μs
and loss=nan): tracing alone fills the ledger, which is what ci.sh's
telemetry smoke uses.
"""
from __future__ import annotations

import argparse
import math
import time


def _ledger_columns(ledger, axis: str, data_axes: tuple) -> dict:
    led_a2a = ledger.wire_bytes("all_to_all", axis, train=True)
    return {
        "led_a2a": led_a2a,
        "led_a2a_n": ledger.call_count("all_to_all", axis, train=True),
        "led_ag": ledger.wire_bytes("all_gather", train=True),
        "led_agd": sum(ledger.wire_bytes("all_gather", a, train=True)
                       for a in data_axes),
    }


def _assert_ledger(tag: str, mode: str, model_name: str, led: dict,
                   census: dict | None, expected: dict | None) -> None:
    """Full-precision in-process cross-asserts (--assert-ledger).

    ledger-vs-analytic is exact (same numbers, two derivations);
    ledger-vs-census is the independent parser cross-check.  Raises with
    every number on mismatch so the report shows the full picture.
    """
    problems = []
    if expected is not None and model_name == "gcn":
        if not math.isclose(led["led_a2a"], expected["a2a_wire"],
                            rel_tol=1e-9, abs_tol=1e-6):
            problems.append(
                f"ledger a2a {led['led_a2a']!r} != analytic "
                f"{expected['a2a_wire']!r}")
        if led["led_a2a_n"] != expected["a2a_calls"]:
            problems.append(
                f"ledger a2a count {led['led_a2a_n']!r} != analytic "
                f"{expected['a2a_calls']!r}")
        if expected["ag_data_wire"] and not math.isclose(
                led["led_agd"], expected["ag_data_wire"],
                rel_tol=1e-9, abs_tol=1e-6):
            problems.append(
                f"ledger data-axis ag {led['led_agd']!r} != analytic "
                f"{expected['ag_data_wire']!r}")
    if census is not None:
        if not math.isclose(led["led_a2a"], census["all-to-all"],
                            rel_tol=1e-9, abs_tol=1e-6):
            problems.append(
                f"ledger a2a {led['led_a2a']!r} != HLO census "
                f"{census['all-to-all']!r}")
    if led["led_a2a"] <= 0:
        problems.append("ledger a2a is zero — collection did not run "
                        "(was the step already traced?)")
    if problems:
        raise AssertionError(f"{tag} [{mode}]: " + "; ".join(problems))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--modes", default="dp,naive,decoupled,"
                                       "decoupled_pipelined")
    ap.add_argument("--backends", default="explicit",
                    help="comma list of engine backends "
                         "(explicit,constraint)")
    ap.add_argument("--model", default="gcn")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--feat-dim", type=int, default=128)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--chunks", type=int, default=4)
    ap.add_argument("--graph", default="sbm", choices=["sbm", "ba"])
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--tag-prefix", default="")
    ap.add_argument("--hlo-census", action="store_true",
                    help="also report the HLO-regex census columns "
                         "(DEPRECATED cross-check of the telemetry "
                         "ledger — superseded by the structural jaxpr "
                         "audit, --audit)")
    ap.add_argument("--audit", action="store_true",
                    help="run the tier-2 jaxpr audit on every step: "
                         "jaxpr collective counts == ledger counts "
                         "(repro.analysis.jaxpr_audit), plus a phantom-"
                         "entry self-check")
    ap.add_argument("--assert-ledger", action="store_true",
                    help="assert ledger == analytic formulas (and == "
                         "census when --hlo-census) in-process")
    ap.add_argument("--trace-only", action="store_true",
                    help="trace + collect the ledger only; skip "
                         "execution, timing and HLO compilation")
    ap.add_argument("--data", type=int, default=1,
                    help="replica-group count: (data, model) hybrid mesh "
                         "with model = devices/data; 1 = pure TP")
    ap.add_argument("--multihost", action="store_true",
                    help="join a jax.distributed job from the env "
                         "contract (COORDINATOR_ADDRESS / NUM_PROCESSES "
                         "/ PROCESS_ID; see repro.runtime.distributed) — "
                         "meshes span the global devices, bundles are "
                         "committed per-host, and rows/asserts are "
                         "process-0-only")
    args = ap.parse_args()

    if args.hlo_census:
        import warnings
        warnings.warn(
            "--hlo-census (the HLO-regex census) is deprecated: the "
            "structural cross-check of the telemetry ledger is the jaxpr "
            "audit (--audit, repro.analysis.jaxpr_audit); the census "
            "remains only as an independent bytes-level parse",
            DeprecationWarning, stacklevel=2)

    from repro.runtime import distributed as dist

    if args.multihost:
        # must precede the first jax.devices(): the local-device slice
        # and the CPU gloo collectives are fixed at backend creation
        dist.initialize()

    import jax

    from repro import optim
    from repro.core import decouple as D
    from repro.gnn import dp_baseline as DP
    from repro.gnn import models as M
    from repro.graph import barabasi_albert, sbm_power_law
    from repro.runtime import collect_comm, hybrid_mesh, tp_mesh

    is_c = dist.is_coordinator()
    n_dev = len(jax.devices())
    if args.data > 1:
        mesh = hybrid_mesh(data=args.data)   # model inferred, strict
        k, replicas = mesh.size, mesh.data_size
    else:
        mesh = tp_mesh(n_dev)
        k, replicas = n_dev, 1
    gen = sbm_power_law if args.graph == "sbm" else barabasi_albert
    kw = dict(n=args.n, num_classes=args.classes, feat_dim=args.feat_dim,
              seed=7)
    if args.graph == "sbm":
        kw["avg_degree"] = args.avg_degree
    else:
        kw["m"] = args.avg_degree // 2
    data = gen(**kw)
    opt = optim.adamw(1e-2)

    for mode in args.modes.split(","):
        # graph prep / config / params are backend-independent — only the
        # engine-mapped step differs per backend
        # under --multihost the bundle must be committed to the global
        # mesh (each process contributes its local shards); single-host
        # placement stays as before
        mesh_arg = mesh if args.multihost else None
        if mode == "dp":
            bundle = DP.prepare_dp_bundle(data, k=k, n_replicas=replicas,
                                          mesh=mesh_arg)
            cfg = M.GNNConfig(model=args.model, in_dim=args.feat_dim,
                              hidden_dim=args.hidden,
                              num_classes=args.classes,
                              num_layers=args.layers, decoupled=False)
        else:
            bundle = D.prepare_bundle(data, n_workers=k,
                                      n_chunks=args.chunks,
                                      n_replicas=replicas,
                                      mesh=mesh_arg)
            cfg = D.padded_gnn_config(data, bundle, model=args.model,
                                      hidden_dim=args.hidden,
                                      num_layers=args.layers)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        if args.multihost:
            params = dist.replicate(params, mesh)
        expected = _expected_for(args, mode, k, replicas, bundle, cfg) \
            if args.assert_ledger else None
        for backend in args.backends.split(","):
            if mode == "dp":
                step, _ = DP.make_dp_train_fns(cfg, bundle, mesh, opt,
                                               backend=backend)
            else:
                step, _ = D.make_tp_train_fns(cfg, bundle, mesh, opt,
                                              mode=mode, backend=backend)
            o = opt.init(params)
            if args.multihost:
                o = dist.replicate(o, mesh)   # commit the count scalar too
            p = params
            # the telemetry ledger fills during the FIRST trace of the
            # step — collect around .lower() before any execution (a
            # cached trace records nothing); subsequent step() calls hit
            # the trace cache, so the timing loop is unaffected
            with collect_comm() as ledger:
                lowered = step.lower(p, o)
            led = _ledger_columns(ledger, mesh.axis, mesh.data_axes)
            if args.audit and is_c:
                from repro.analysis import jaxpr_audit as audit_mod
                from repro.runtime.telemetry import CommLedger

                # re-tracing outside collect_comm records nothing — the
                # wrappers no-op without an active ledger
                jxp = jax.make_jaxpr(step)(p, o)
                audit_mod.assert_clean(
                    jxp, ledger, backend=backend,
                    tag=f"{args.tag_prefix}{mode}/{backend}")
                if backend == "explicit":
                    # self-check: a forged counter must be caught, so a
                    # passing audit means "verified", not "vacuous"
                    forged = CommLedger.from_dict(ledger.as_dict())
                    forged.add("ppermute", mesh.axis, "float32",
                               payload=1.0, wire=1.0)
                    kinds = [f.kind
                             for f in audit_mod.audit(jxp, forged)]
                    if kinds != ["phantom_ledger_entry"]:
                        raise AssertionError(
                            f"{mode}/{backend}: audit failed to flag a "
                            f"forged ledger entry (got {kinds})")
            if args.trace_only:
                dt, loss = 0.0, float("nan")
            else:
                # warmup (compile)
                p, o, loss = step(p, o)
                jax.block_until_ready(loss)
                t0 = time.perf_counter()
                for _ in range(args.epochs):
                    p, o, loss = step(p, o)
                jax.block_until_ready(loss)
                dt = (time.perf_counter() - t0) / args.epochs
            derived = f"workers={k};replicas={replicas};" \
                      f"loss={float(loss):.3f}"
            derived += (f";led_a2a={led['led_a2a']:.6e}"
                        f";led_a2a_n={led['led_a2a_n']:.0f}"
                        f";led_ag={led['led_ag']:.6e}"
                        f";led_agd={led['led_agd']:.6e}")
            cb = None
            if args.hlo_census and is_c:
                from repro.launch.roofline import hlo_census
                try:
                    txt = lowered.compile().as_text()
                    cb = hlo_census(txt)["collectives"]
                    derived += (f";coll_bytes={cb['total']:.6e}"
                                f";a2a={cb['all-to-all']:.6e}"
                                f";ag={cb['all-gather']:.6e}"
                                f";ar={cb['all-reduce']:.6e}"
                                f";rs={cb['reduce-scatter']:.6e}")
                except Exception as e:  # noqa: BLE001
                    if args.assert_ledger:
                        raise
                    derived += f";census_error={type(e).__name__}"
            # process-0-only under multihost: every process collects the
            # identical trace-time ledger, but N processes printing rows
            # (or racing to raise) would corrupt the parent's CSV parse
            if args.assert_ledger and is_c:
                _assert_ledger(args.tag_prefix + mode, mode, args.model,
                               led, cb, expected)
                derived += ";led_ok=1"
            if args.audit and is_c:
                derived += ";audit_ok=1"
            tag = mode if backend == "explicit" else f"{mode}_{backend}"
            if replicas > 1:
                tag += f"_d{replicas}x{k}"
            if is_c:
                print(f"{args.tag_prefix}{tag},{dt*1e6:.1f},{derived}",
                      flush=True)


def _expected_for(args, mode: str, k: int, replicas: int, bundle, cfg):
    """Analytic expected-ledger values for this row, or None where no
    exact model exists (pipelined padding, hybrid dp, non-GCN)."""
    if args.model != "gcn":
        return None
    try:
        from .bench_comm_volume import expected_ledger
    except ImportError:  # run as a script, not -m benchmarks._dist_gnn
        import os
        import sys
        repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        if repo not in sys.path:
            sys.path.insert(0, repo)
        from benchmarks.bench_comm_volume import expected_ledger

    try:
        if mode == "dp":
            return expected_ledger(
                "dp", n=args.n, feat=args.feat_dim, hidden=args.hidden,
                classes=args.classes, L=args.layers, model=k,
                data=replicas, halo_slots=k * k * bundle.graph.m)
        return expected_ledger(
            mode, n=bundle.n_padded, feat=cfg.in_dim,
            hidden=cfg.hidden_dim, classes=cfg.num_classes,
            L=cfg.num_layers, model=k, data=replicas)
    except ValueError:
        return None


if __name__ == "__main__":
    main()
