"""Child-process body for the distributed GNN benchmarks.

Invoked by run.py / bench modules with a forced host device count; times
one full-graph training epoch per (mode, backend, model, graph, layers,
dims) combination passed on the command line.  Prints CSV rows:

    <tag>,<us_per_epoch>,<derived>

Tags are ``<prefix><mode>`` for the default explicit backend and
``<prefix><mode>_constraint`` for the constraint backend, so existing
consumers of the explicit rows are unaffected.  ``--data R`` trains on a
hybrid (data=R, model=devices/R) mesh instead of pure TP; hybrid rows
get a ``_d<R>x<model>`` suffix and report ``replicas=R`` so the census
columns (a2a = model-axis gather/split, ar = reductions incl. the
data-axis grad all-reduce) can be split by axis kind.
"""
from __future__ import annotations

import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--modes", default="dp,naive,decoupled,"
                                       "decoupled_pipelined")
    ap.add_argument("--backends", default="explicit",
                    help="comma list of engine backends "
                         "(explicit,constraint)")
    ap.add_argument("--model", default="gcn")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--feat-dim", type=int, default=128)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--chunks", type=int, default=4)
    ap.add_argument("--graph", default="sbm", choices=["sbm", "ba"])
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--tag-prefix", default="")
    ap.add_argument("--census", action="store_true",
                    help="also report collective wire bytes per epoch")
    ap.add_argument("--data", type=int, default=1,
                    help="replica-group count: (data, model) hybrid mesh "
                         "with model = devices/data; 1 = pure TP")
    args = ap.parse_args()

    import jax

    from repro import optim
    from repro.core import decouple as D
    from repro.gnn import dp_baseline as DP
    from repro.gnn import models as M
    from repro.graph import barabasi_albert, sbm_power_law
    from repro.launch.roofline import hlo_census
    from repro.runtime import hybrid_mesh, tp_mesh

    n_dev = len(jax.devices())
    if args.data > 1:
        mesh = hybrid_mesh(data=args.data)   # model inferred, strict
        k, replicas = mesh.size, mesh.data_size
    else:
        mesh = tp_mesh(n_dev)
        k, replicas = n_dev, 1
    gen = sbm_power_law if args.graph == "sbm" else barabasi_albert
    kw = dict(n=args.n, num_classes=args.classes, feat_dim=args.feat_dim,
              seed=7)
    if args.graph == "sbm":
        kw["avg_degree"] = args.avg_degree
    else:
        kw["m"] = args.avg_degree // 2
    data = gen(**kw)
    opt = optim.adamw(1e-2)

    for mode in args.modes.split(","):
        # graph prep / config / params are backend-independent — only the
        # engine-mapped step differs per backend
        if mode == "dp":
            bundle = DP.prepare_dp_bundle(data, k=k, n_replicas=replicas)
            cfg = M.GNNConfig(model=args.model, in_dim=args.feat_dim,
                              hidden_dim=args.hidden,
                              num_classes=args.classes,
                              num_layers=args.layers, decoupled=False)
        else:
            bundle = D.prepare_bundle(data, n_workers=k,
                                      n_chunks=args.chunks,
                                      n_replicas=replicas)
            cfg = D.padded_gnn_config(data, bundle, model=args.model,
                                      hidden_dim=args.hidden,
                                      num_layers=args.layers)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        for backend in args.backends.split(","):
            if mode == "dp":
                step, _ = DP.make_dp_train_fns(cfg, bundle, mesh, opt,
                                               backend=backend)
            else:
                step, _ = D.make_tp_train_fns(cfg, bundle, mesh, opt,
                                              mode=mode, backend=backend)
            o = opt.init(params)
            p = params
            # warmup (compile)
            p, o, loss = step(p, o)
            jax.block_until_ready(loss)
            t0 = time.perf_counter()
            for _ in range(args.epochs):
                p, o, loss = step(p, o)
            jax.block_until_ready(loss)
            dt = (time.perf_counter() - t0) / args.epochs
            derived = f"workers={k};replicas={replicas};" \
                      f"loss={float(loss):.3f}"
            if args.census:
                try:
                    txt = step.lower(p, o).compile().as_text()
                    cb = hlo_census(txt)["collectives"]
                    derived += (f";coll_bytes={cb['total']:.3e}"
                                f";a2a={cb['all-to-all']:.3e}"
                                f";ag={cb['all-gather']:.3e}"
                                f";ar={cb['all-reduce']:.3e}")
                except Exception as e:  # noqa: BLE001
                    derived += f";census_error={type(e).__name__}"
            tag = mode if backend == "explicit" else f"{mode}_{backend}"
            if replicas > 1:
                tag += f"_d{replicas}x{k}"
            print(f"{args.tag_prefix}{tag},{dt*1e6:.1f},{derived}",
                  flush=True)


if __name__ == "__main__":
    main()
