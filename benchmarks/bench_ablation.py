"""Paper Fig. 11: performance-gain ablation — baseline(DP+CS) → +TP → +DT
→ +IP, normalized speedups on two graph families."""
from __future__ import annotations

import re

from .common import emit, record_output, run_subprocess_bench, write_json


def main():
    for graph in ("sbm", "ba"):
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,naive,decoupled,decoupled_pipelined",
                  "--graph", graph,
                  "--tag-prefix", f"ablation_{graph}_"])
        rows = {}
        for line in record_output(out).strip().splitlines():
            parts = line.split(",")
            rows[parts[0]] = float(parts[1])
            print(line)
        base = rows.get(f"ablation_{graph}_dp")
        if base:
            for mode, label in (("naive", "+TP"),
                                ("decoupled", "+TP+DT"),
                                ("decoupled_pipelined", "+TP+DT+IP")):
                t = rows.get(f"ablation_{graph}_{mode}")
                if t:
                    emit(f"ablation_{graph}_speedup_{label}", t,
                         f"speedup_vs_baseline={base / t:.2f}x")

    write_json("ablation")


if __name__ == "__main__":
    main()
