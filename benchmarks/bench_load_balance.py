"""Paper Figs. 3 & 10(a): per-worker compute/comm load under different
partitioning strategies vs tensor parallelism (analytic, from partitions —
the same methodology as the paper's 'edges per partition' figures)."""
from __future__ import annotations

import numpy as np

from .common import emit, write_json


def main():
    from repro.graph import (barabasi_albert, chunk_partition,
                             greedy_edge_cut_partition, hash_partition,
                             tensor_parallel_stats, workload_stats)
    data = barabasi_albert(n=8192, m=8, feat_dim=128, seed=3)
    g = data.graph
    k = 4
    parts = {
        "chunk": chunk_partition(g, k),
        "chunk_edge_balanced": chunk_partition(g, k, balance="edge"),
        "hash": hash_partition(g, k),
        "greedy_edge_cut(metis-like)": greedy_edge_cut_partition(g, k,
                                                                 passes=1),
    }
    for name, part in parts.items():
        st = workload_stats(g, part)
        emit(f"load_balance_{name}", 0.0,
             f"compute_imbalance={st.compute_imbalance:.3f};"
             f"comm_imbalance={st.comm_imbalance:.3f};"
             f"edges_per_worker={st.edges.tolist()};"
             f"remote_srcs={st.remote_srcs.tolist()}")
    st = tensor_parallel_stats(g, k, d=128)
    emit("load_balance_tensor_parallel", 0.0,
         f"compute_imbalance={st.compute_imbalance:.3f};"
         f"comm_imbalance={st.comm_imbalance:.3f};"
         "note=exact_by_construction")

    write_json("load_balance")


if __name__ == "__main__":
    main()
