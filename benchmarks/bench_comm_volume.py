"""Paper Fig. 8 + §3.2: communication volume & frequency — naive TP vs
decoupled TP vs data parallelism.

Two measurements:
  * analytic bytes/epoch from the paper's formulas instantiated on the real
    graph + halo plan (what Fig. 10(b) plots), and
  * measured collective wire bytes from the compiled 8-worker HLO (census
    over the actual runtime-engine sharded programs).
"""
from __future__ import annotations

from .common import emit, run_subprocess_bench


def main():
    import numpy as np
    from repro.graph import chunk_partition, halo_plan, sbm_power_law

    n, feat, hidden, classes, L, k = 4096, 128, 64, 16, 2, 8
    data = sbm_power_law(n=n, num_classes=classes, feat_dim=feat,
                         avg_degree=16, seed=7)
    g = data.graph
    f32 = 4

    # --- analytic (paper §3.2) ---
    # naive TP: 2 collectives per layer, each V·D_layer/N per worker → total
    dims = [feat] + [hidden] * (L - 1) + [classes]
    naive = sum(2 * g.n * d * f32 for d in dims[1:]) * 1  # per epoch (fwd)
    # decoupled: one split at embedding dim + one gather at class dim (fwd)
    dec = g.n * classes * f32 * 2
    # DP: per layer, every remote src row of dim d
    plan = halo_plan(g, chunk_partition(g, k))
    halo_rows = int((plan.send_idx >= 0).sum())
    dp = sum(halo_rows * d * f32 for d in dims[:-1])
    emit("comm_volume_analytic_naive_tp", 0.0, f"bytes_fwd={naive:.3e}")
    emit("comm_volume_analytic_decoupled_tp", 0.0, f"bytes_fwd={dec:.3e}")
    emit("comm_volume_analytic_dp", 0.0,
         f"bytes_fwd={dp:.3e};halo_rows={halo_rows}")
    emit("comm_frequency", 0.0,
         f"naive_per_epoch={2 * L + 2};decoupled_per_epoch=4")

    # --- measured from compiled HLO (full train step, fwd+bwd) ---
    out = run_subprocess_bench(
        "benchmarks._dist_gnn", devices=8,
        args=["--modes", "dp,naive,decoupled", "--census",
              "--tag-prefix", "comm_volume_measured_"])
    print(out, end="")


if __name__ == "__main__":
    main()
