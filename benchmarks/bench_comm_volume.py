"""Paper Fig. 8 + §3.2: communication volume & frequency — naive TP vs
decoupled TP vs data parallelism.

Three measurements, cross-asserted:

  * analytic bytes/epoch from the paper's formulas instantiated on the
    real graph + halo plan (what Fig. 10(b) plots);
  * the **telemetry ledger** — trace-time collective counters from the
    runtime choke point (:mod:`repro.runtime.telemetry`), the primary
    measured columns (``led_*``), asserted *exactly* against the
    analytic formulas via :func:`expected_ledger`;
  * the HLO-regex census (:func:`repro.launch.roofline.hlo_census`),
    demoted to an independent cross-check behind ``--hlo-census`` —
    ledger and census must agree byte-for-byte, so a silent-zero
    regression in either (two shipped in the census parser already)
    fails loudly.

Both engine backends are reported side by side: the explicit shard_map
path and the pjit/constraint path must show identical all-to-all wire
bytes — the constraint backend changes who *schedules* the collectives,
not what goes over the wire.

``--analytic-only`` skips every subprocess (pure formula smoke);
``--telemetry-smoke`` additionally runs a fast trace-only subprocess
(no execution, no HLO compile) that asserts ledger == analytic on a
small workload — the tier-1 cross-check scripts/ci.sh runs.
"""
from __future__ import annotations

import argparse

from .common import emit, record_output, run_subprocess_bench, write_json

F32 = 4


def analytic_volumes(n: int, feat: int, hidden: int, classes: int, L: int,
                     halo_rows: int, data: int = 1, model: int | None = None,
                     param_bytes: int = 0) -> dict:
    """Forward-pass bytes/epoch summed over all workers (paper §3.2).

    ``dims`` are the per-layer *input* dims [feat, hidden, ..., hidden]:
    ``tp_naive_forward`` splits/gathers the activations entering layer i
    (shape V × dims[i]) — layer *outputs* only ever move as the next
    layer's input, so summing output dims would both drop the feat-dim
    move (the largest) and double-count nothing in its place.

    Hybrid DP×TP changes both columns of the fleet total.  Model-axis:
    every replica group redundantly runs the same gather/split
    all-to-alls (after ``replica_gather`` each group holds the full
    activation block), so the fleet a2a bytes scale with ``data``.
    Data-axis: each of the ``model`` groups ring-all-reduces the
    replicated parameter gradients — ``2·(data−1)·param_bytes`` wire
    bytes per group, zero for pure TP (``data=1``).  The *per-group*
    a2a volume is what the paper's formulas give and is
    replica-count-independent; keeping the two kinds of bytes in
    separate keys (scaled to the same fleet-total convention) is what
    lets the benches expose the a2a-vs-grad-allreduce tradeoff.
    """
    if data > 1 and model is None:
        raise ValueError(
            "hybrid accounting (data > 1) needs the model-group count — "
            "pass model=<TP degree> (a silent default would undercount "
            "grad_allreduce_data by the group factor)")
    if data > 1 and param_bytes <= 0:
        raise ValueError(
            "hybrid accounting (data > 1) needs param_bytes > 0 — a "
            "defaulted 0 would silently zero the data-axis "
            "grad_allreduce_data term")
    dims = [feat] + [hidden] * (L - 1) + [classes]
    return {
        # naive TP: split + gather per layer at the layer-input dim,
        # executed once per replica group
        "naive": data * sum(2 * n * d * F32 for d in dims[:-1]),
        # decoupled: one split + one gather at the class (NN-output) dim
        "decoupled": data * n * classes * F32 * 2,
        # DP: per layer, every remote src row at the layer-input dim
        "dp": data * sum(halo_rows * d * F32 for d in dims[:-1]),
        # all-to-all collectives per epoch: forward + mirrored backward
        "naive_per_epoch": 4 * L,
        "decoupled_per_epoch": 4,
        # data-axis grad all-reduce (ring), summed over the model groups
        "grad_allreduce_data": 2 * (data - 1) * param_bytes * (model or 1),
    }


def expected_ledger(mode: str, *, n: int, feat: int, hidden: int,
                    classes: int, L: int, model: int, data: int = 1,
                    halo_slots: int | None = None) -> dict:
    """Telemetry-ledger quantities implied by the analytic §3.2 formulas.

    Converts the fleet-total forward *payload* convention of
    :func:`analytic_volumes` into the ledger's convention — per-device
    ring wire bytes of one train step (fwd + autodiff-mirrored bwd):

    * per-device = per-group payload / ``model`` (the a2a moves each
      group's block once, and every replica group runs the same ops, so
      the per-device number is replica-count-independent);
    * ring wire = payload × (model−1)/model (the local block never
      crosses the wire — same model as the HLO census);
    * backward mirrors every forward a2a whose *input is differentiated*:
      both decoupled transitions are (4 a2a/epoch total, the paper's
      frequency), but the coupled forwards' layer-0 collectives move raw
      input features — the backward stops at the first weight matmul, so
      naive counts 2L + 2(L−1) = ``naive_per_epoch − 2`` a2a and dp
      counts L + (L−1), with the byte sums shrunk accordingly (the HLO
      census confirms this is what autodiff actually emits).

    ``dims`` (all pre-padded by the caller to the mesh contract — padding
    must be a no-op for exactness) follow the layer-*input* convention of
    :func:`analytic_volumes`.  ``mode="dp"`` needs ``halo_slots``: the
    fleet count of *padded* per-layer send slots ``k·k·m`` — the
    rectangular halo all-to-all moves its padding zeros too, which the
    halo_rows-based analytic "dp" key deliberately excludes.

    Hybrid (``data > 1``, single data axis) adds ``ag_data_wire``: the
    per-device data-axis all-gather wire bytes of the replica_gather
    transitions ((data−1) × per-device payload per gather, layer-0
    unmirrored for the coupled modes).  Raises for hybrid dp — its
    per-partition row padding is bundle-dependent, so the bench does not
    assert it.
    """
    vols = analytic_volumes(n=n, feat=feat, hidden=hidden, classes=classes,
                            L=L, halo_rows=0)
    dims = [feat] + [hidden] * (L - 1)       # per-layer input dims
    ring = (model - 1) / model
    if mode == "decoupled":
        fwd = vols["decoupled"]
        bwd = fwd
        calls = vols["decoupled_per_epoch"]
    elif mode == "naive":
        fwd = vols["naive"]
        bwd = sum(2 * n * d * F32 for d in dims[1:])
        calls = vols["naive_per_epoch"] - 2
    elif mode == "dp":
        if data > 1:
            raise ValueError(
                "hybrid dp rows are not analytically modelled (replica "
                "padding of n_local_max is bundle-dependent) — do not "
                "assert them")
        if halo_slots is None:
            raise ValueError(
                "mode='dp' needs halo_slots (= k·k·m padded send slots "
                "per layer) — the rectangular halo a2a moves its padding "
                "zeros, which halo_rows excludes")
        fwd = sum(halo_slots * d * F32 for d in dims)
        bwd = sum(halo_slots * d * F32 for d in dims[1:])
        calls = 2 * L - 1
    else:
        raise ValueError(
            f"no exact analytic ledger model for mode {mode!r} (the "
            f"pipelined chunk tables are padded — cross-check that mode "
            f"against the HLO census instead)")
    out = {"a2a_wire": (fwd + bwd) / model * ring, "a2a_calls": calls,
           "ag_data_wire": 0.0}
    if data > 1:
        if mode == "decoupled":
            gathers = [(n * classes * F32, True)]
        else:   # naive: one replica_gather per layer, layer-0 unmirrored
            gathers = [(n * d * F32, i > 0) for i, d in enumerate(dims)]
        ag = 0.0
        for fleet_payload, mirrored in gathers:
            per_dev = fleet_payload / (model * data)
            ag += (data - 1) * per_dev * (2 if mirrored else 1)
        out["ag_data_wire"] = ag
    return out


def main(argv=()):
    # default () so run.py's ``main()`` never sees run.py's own sys.argv;
    # the CLI entry below passes sys.argv[1:] explicitly.
    ap = argparse.ArgumentParser()
    ap.add_argument("--analytic-only", action="store_true",
                    help="formulas only: skip every subprocess")
    ap.add_argument("--telemetry-smoke", action="store_true",
                    help="formulas + a fast trace-only subprocess "
                         "asserting ledger == analytic (ci.sh tier-1)")
    args = ap.parse_args(argv)

    from repro.graph import chunk_partition, halo_plan, sbm_power_law

    n, feat, hidden, classes, L, k = 4096, 128, 64, 16, 2, 8
    data = sbm_power_law(n=n, num_classes=classes, feat_dim=feat,
                         avg_degree=16, seed=7)
    g = data.graph

    # --- analytic (paper §3.2) ---
    plan = halo_plan(g, chunk_partition(g, k))
    halo_rows = int((plan.send_idx >= 0).sum())
    # GCN params for the standard workload (grad bytes of the data axis)
    param_bytes = (feat * hidden + hidden + hidden * classes + classes) * F32
    vols = analytic_volumes(n=g.n, feat=feat, hidden=hidden,
                            classes=classes, L=L, halo_rows=halo_rows)
    # hybrid DP×TP on the same 8 devices: (data=2, model=4)
    hyb = analytic_volumes(n=g.n, feat=feat, hidden=hidden,
                           classes=classes, L=L, halo_rows=halo_rows,
                           data=2, model=4, param_bytes=param_bytes)
    # regression pins for the standard workload (ci.sh smoke): naive moves
    # the feat-dim activations — 2·4096·(128+64)·4 — not the output dims.
    assert vols["naive"] == 2 * 4096 * (128 + 64) * 4, vols["naive"]
    assert vols["decoupled"] == 2 * 4096 * 16 * 4, vols["decoupled"]
    assert vols["naive"] > vols["decoupled"] > 0
    assert vols["dp"] > 0 and vols["naive_per_epoch"] == 8
    # data-axis pins: pure TP has no grad all-reduce term; two replica
    # groups of four workers ring-reduce the replicated grads — the bytes
    # are a *data-axis* quantity, invisible to the model-axis formulas.
    assert vols["grad_allreduce_data"] == 0, vols["grad_allreduce_data"]
    assert param_bytes == 37184, param_bytes
    assert hyb["grad_allreduce_data"] == 2 * 1 * param_bytes * 4, \
        hyb["grad_allreduce_data"]
    # fleet-total convention: every replica group redundantly runs the
    # model-axis all-to-alls, so hybrid a2a bytes are data× the pure run
    assert hyb["naive"] == 2 * vols["naive"] and \
        hyb["decoupled"] == 2 * vols["decoupled"], \
        "hybrid fleet a2a must scale with the replica count"
    # expected-ledger pins: the ledger convention of the same formulas —
    # per-device ring wire bytes per train step (these exact numbers were
    # independently measured by the PR 2 HLO census: 1.147e5 / 9.175e5)
    exp_dec = expected_ledger("decoupled", n=n, feat=feat, hidden=hidden,
                              classes=classes, L=L, model=k)
    exp_nai = expected_ledger("naive", n=n, feat=feat, hidden=hidden,
                              classes=classes, L=L, model=k)
    assert exp_dec["a2a_wire"] == 114688.0, exp_dec
    assert exp_dec["a2a_calls"] == 4, exp_dec
    assert exp_nai["a2a_wire"] == 917504.0, exp_nai
    assert exp_nai["a2a_calls"] == 6, exp_nai

    emit("comm_volume_analytic_naive_tp", 0.0,
         f"bytes_fwd={vols['naive']:.3e}")
    emit("comm_volume_analytic_decoupled_tp", 0.0,
         f"bytes_fwd={vols['decoupled']:.3e}")
    emit("comm_volume_analytic_dp", 0.0,
         f"bytes_fwd={vols['dp']:.3e};halo_rows={halo_rows}")
    emit("comm_volume_analytic_hybrid_d2x4", 0.0,
         f"bytes_a2a_fwd={hyb['decoupled']:.3e};"
         f"bytes_grad_ar_data={hyb['grad_allreduce_data']:.3e}")
    emit("comm_frequency", 0.0,
         f"naive_per_epoch={vols['naive_per_epoch']};"
         f"decoupled_per_epoch={vols['decoupled_per_epoch']}")

    if args.telemetry_smoke:
        # fast tier-1 lane: trace-only (no execution, no HLO compile) on a
        # small divisible workload; _dist_gnn --assert-ledger does the
        # exact ledger-vs-analytic comparison in-process at full precision
        smoke = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,naive,decoupled", "--trace-only",
                  "--assert-ledger", "--audit", "--n", "512",
                  "--feat-dim", "32", "--hidden", "32",
                  "--tag-prefix", "telemetry_smoke_"])
        print(record_output(smoke), end="")
        smoke_h = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "decoupled,naive", "--trace-only",
                  "--assert-ledger", "--audit", "--data", "2",
                  "--n", "512", "--feat-dim", "32", "--hidden", "32",
                  "--tag-prefix", "telemetry_smoke_"])
        print(record_output(smoke_h), end="")
        _require_ledger_rows(smoke + smoke_h, "telemetry_smoke_",
                             audited=True)

    # --- measured, both engine backends: the telemetry ledger is the
    # primary column (asserted against the analytic formulas in-process
    # by --assert-ledger), the HLO census rides along as a cross-check ---
    if not (args.analytic_only or args.telemetry_smoke):
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,naive,decoupled", "--hlo-census",
                  "--assert-ledger",
                  "--backends", "explicit,constraint",
                  "--tag-prefix", "comm_volume_measured_"])
        print(record_output(out), end="")
        _check_backend_parity(out)

        # hybrid (data=2, model=4) on the same 8 devices: the a2a column
        # is model-axis gather/split traffic; the data axis shows up in
        # the ledger's led_agd column (replica_gather wire bytes) and in
        # the census all-gather column — traffic pure-TP GCN rows
        # provably lack
        hyb_out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "decoupled,naive", "--hlo-census",
                  "--assert-ledger", "--data", "2",
                  "--backends", "explicit,constraint",
                  "--tag-prefix", "comm_volume_measured_"])
        print(record_output(hyb_out), end="")
        _check_hybrid_census(hyb_out, out)

    write_json("comm_volume")


def _census_field(derived: str, key: str) -> float | None:
    for field in derived.split(";"):
        if field.startswith(key + "="):
            return float(field[len(key) + 1:])
    return None


def _require_ledger_rows(out: str, prefix: str, *,
                         audited: bool = False) -> None:
    """Every row of a --assert-ledger run must carry nonzero led_a2a and
    the in-process assertion marker — an empty ledger that still printed
    rows would be the silent-zero failure mode.  ``audited=True`` also
    requires the tier-2 structural marker (``--audit``: jaxpr collective
    counts == ledger counts, repro.analysis.jaxpr_audit)."""
    from .common import parse_rows

    rows = [r for r in parse_rows(out) if r["name"].startswith(prefix)]
    assert rows, f"no {prefix}* rows in child output"
    bad = [r["name"] for r in rows
           if not (_census_field(r["derived"], "led_a2a") or 0) > 0
           or _census_field(r["derived"], "led_ok") != 1.0
           or (audited and
               _census_field(r["derived"], "audit_ok") != 1.0)]
    assert not bad, f"rows without asserted ledger bytes: {bad}"


def _check_hybrid_census(hyb_out: str, pure_out: str) -> None:
    """Hybrid rows must show *data-axis* traffic on top of the model-axis
    all-to-alls.  Primary signal: the ledger's ``led_agd`` column (the
    replica_gather data-axis wire bytes, asserted against the analytic
    expectation in-process by --assert-ledger) must be nonzero.
    Cross-check: the census all-gather column — explicit GCN
    decoupled/naive on pure TP emit no all-gathers at all (split and
    gather are a2a, reductions are ar), so ``hybrid ag > pure ag`` holds
    iff the replica_gather/psum-scatter plumbing actually ran — a
    silently-dropped data axis (``data_axes=()``) would zero both while
    leaving a2a and ar plausible-looking."""
    from .common import parse_rows

    hyb = {r["name"]: r["derived"] for r in parse_rows(hyb_out)}
    pure = {r["name"]: r["derived"] for r in parse_rows(pure_out)}
    problems = []
    for mode in ("decoupled", "naive"):
        for bk in ("", "_constraint"):
            derived = hyb.get(f"comm_volume_measured_{mode}{bk}_d2x4")
            a2a = _census_field(derived, "a2a") if derived else None
            ag = _census_field(derived, "ag") if derived else None
            led_agd = _census_field(derived, "led_agd") if derived \
                else None
            pure_derived = pure.get(f"comm_volume_measured_{mode}{bk}")
            pure_ag = _census_field(pure_derived, "ag") if pure_derived \
                else None
            ok = (a2a is not None and a2a > 0
                  and led_agd is not None and led_agd > 0
                  and ag is not None and pure_ag is not None
                  and ag > pure_ag)
            emit(f"comm_volume_hybrid_census_{mode}{bk}", 0.0,
                 f"a2a={a2a};led_agd={led_agd};ag={ag};"
                 f"pure_ag={pure_ag};ok={ok}")
            if not ok:
                problems.append((mode, bk, a2a, led_agd, ag, pure_ag))
    assert not problems, problems


def _check_backend_parity(out: str) -> None:
    """The constraint backend moves who *schedules* the all-to-alls, not
    what crosses the wire: per mode, the ledger's measured a2a bytes must
    be identical across backends — and must match the census cross-check
    column (``a2a``), which an in-process assert already compared at full
    precision (led_ok)."""
    from .common import parse_rows

    led, census = {}, {}
    for row in parse_rows(out):
        b = _census_field(row["derived"], "led_a2a")
        if b is not None:
            led[row["name"]] = b
        c = _census_field(row["derived"], "a2a")
        if c is not None:
            census[row["name"]] = c
    mismatches = []
    for mode in ("dp", "naive", "decoupled"):
        e = led.get(f"comm_volume_measured_{mode}")
        c = led.get(f"comm_volume_measured_{mode}_constraint")
        ce = census.get(f"comm_volume_measured_{mode}")
        # e > 0 guards the collection itself: an empty ledger (or a
        # census parser regression) zeroing both sides would otherwise
        # pass as 0.0 == 0.0
        ok = e is not None and e > 0 and e == c and ce == e
        emit(f"comm_volume_backend_parity_{mode}", 0.0,
             f"explicit_led_a2a={e};constraint_led_a2a={c};"
             f"census_a2a={ce};equal={ok}")
        if not ok:
            mismatches.append((mode, e, c, ce))
    # emit every mode's parity row before failing so a mismatch report
    # shows the full picture, not just the first mode
    assert not mismatches, mismatches


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
