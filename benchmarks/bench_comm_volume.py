"""Paper Fig. 8 + §3.2: communication volume & frequency — naive TP vs
decoupled TP vs data parallelism.

Two measurements:
  * analytic bytes/epoch from the paper's formulas instantiated on the real
    graph + halo plan (what Fig. 10(b) plots), and
  * measured collective wire bytes from the compiled 8-worker HLO (census
    over the actual runtime-engine sharded programs), reported for BOTH
    engine backends side by side: the explicit shard_map path and the
    pjit/constraint path must show identical all-to-all wire bytes — the
    constraint backend changes who *schedules* the collectives, not what
    goes over the wire.

``--analytic-only`` skips the subprocess census (used by scripts/ci.sh as
a fast formula-regression smoke).
"""
from __future__ import annotations

import argparse

from .common import emit, record_output, run_subprocess_bench, write_json

F32 = 4


def analytic_volumes(n: int, feat: int, hidden: int, classes: int, L: int,
                     halo_rows: int) -> dict:
    """Forward-pass bytes/epoch summed over all workers (paper §3.2).

    ``dims`` are the per-layer *input* dims [feat, hidden, ..., hidden]:
    ``tp_naive_forward`` splits/gathers the activations entering layer i
    (shape V × dims[i]) — layer *outputs* only ever move as the next
    layer's input, so summing output dims would both drop the feat-dim
    move (the largest) and double-count nothing in its place.
    """
    dims = [feat] + [hidden] * (L - 1) + [classes]
    return {
        # naive TP: split + gather per layer at the layer-input dim
        "naive": sum(2 * n * d * F32 for d in dims[:-1]),
        # decoupled: one split + one gather at the class (NN-output) dim
        "decoupled": n * classes * F32 * 2,
        # DP: per layer, every remote src row at the layer-input dim
        "dp": sum(halo_rows * d * F32 for d in dims[:-1]),
        # all-to-all collectives per epoch: forward + mirrored backward
        "naive_per_epoch": 4 * L,
        "decoupled_per_epoch": 4,
    }


def main(argv=()):
    # default () so run.py's ``main()`` never sees run.py's own sys.argv;
    # the CLI entry below passes sys.argv[1:] explicitly.
    ap = argparse.ArgumentParser()
    ap.add_argument("--analytic-only", action="store_true",
                    help="skip the 8-device subprocess HLO census")
    args = ap.parse_args(argv)

    from repro.graph import chunk_partition, halo_plan, sbm_power_law

    n, feat, hidden, classes, L, k = 4096, 128, 64, 16, 2, 8
    data = sbm_power_law(n=n, num_classes=classes, feat_dim=feat,
                         avg_degree=16, seed=7)
    g = data.graph

    # --- analytic (paper §3.2) ---
    plan = halo_plan(g, chunk_partition(g, k))
    halo_rows = int((plan.send_idx >= 0).sum())
    vols = analytic_volumes(n=g.n, feat=feat, hidden=hidden,
                            classes=classes, L=L, halo_rows=halo_rows)
    # regression pins for the standard workload (ci.sh smoke): naive moves
    # the feat-dim activations — 2·4096·(128+64)·4 — not the output dims.
    assert vols["naive"] == 2 * 4096 * (128 + 64) * 4, vols["naive"]
    assert vols["decoupled"] == 2 * 4096 * 16 * 4, vols["decoupled"]
    assert vols["naive"] > vols["decoupled"] > 0
    assert vols["dp"] > 0 and vols["naive_per_epoch"] == 8

    emit("comm_volume_analytic_naive_tp", 0.0,
         f"bytes_fwd={vols['naive']:.3e}")
    emit("comm_volume_analytic_decoupled_tp", 0.0,
         f"bytes_fwd={vols['decoupled']:.3e}")
    emit("comm_volume_analytic_dp", 0.0,
         f"bytes_fwd={vols['dp']:.3e};halo_rows={halo_rows}")
    emit("comm_frequency", 0.0,
         f"naive_per_epoch={vols['naive_per_epoch']};"
         f"decoupled_per_epoch={vols['decoupled_per_epoch']}")

    # --- measured from compiled HLO (full train step, fwd+bwd), both
    # engine backends: identical a2a wire bytes, different scheduler ---
    if not args.analytic_only:
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,naive,decoupled", "--census",
                  "--backends", "explicit,constraint",
                  "--tag-prefix", "comm_volume_measured_"])
        print(record_output(out), end="")
        _check_backend_parity(out)

    write_json("comm_volume")


def _a2a_bytes(derived: str) -> float | None:
    for field in derived.split(";"):
        if field.startswith("a2a="):
            return float(field[4:])
    return None


def _check_backend_parity(out: str) -> None:
    """The constraint backend moves who *schedules* the all-to-alls, not
    what crosses the wire: per mode, measured a2a bytes must be identical
    across backends."""
    from .common import parse_rows

    a2a = {}
    for row in parse_rows(out):
        b = _a2a_bytes(row["derived"])
        if b is not None:
            a2a[row["name"]] = b
    mismatches = []
    for mode in ("dp", "naive", "decoupled"):
        e = a2a.get(f"comm_volume_measured_{mode}")
        c = a2a.get(f"comm_volume_measured_{mode}_constraint")
        # e > 0 guards the census itself: a parser regression that zeroes
        # a2a bytes on both backends would otherwise pass as 0.0 == 0.0
        ok = e is not None and e > 0 and e == c
        emit(f"comm_volume_backend_parity_{mode}", 0.0,
             f"explicit_a2a={e};constraint_a2a={c};equal={ok}")
        if not ok:
            mismatches.append((mode, e, c))
    # emit every mode's parity row before failing so a mismatch report
    # shows the full picture, not just the first mode
    assert not mismatches, mismatches


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
