"""Paper Fig. 8 + §3.2: communication volume & frequency — naive TP vs
decoupled TP vs data parallelism.

Two measurements:
  * analytic bytes/epoch from the paper's formulas instantiated on the real
    graph + halo plan (what Fig. 10(b) plots), and
  * measured collective wire bytes from the compiled 8-worker HLO (census
    over the actual runtime-engine sharded programs), reported for BOTH
    engine backends side by side: the explicit shard_map path and the
    pjit/constraint path must show identical all-to-all wire bytes — the
    constraint backend changes who *schedules* the collectives, not what
    goes over the wire.

``--analytic-only`` skips the subprocess census (used by scripts/ci.sh as
a fast formula-regression smoke).
"""
from __future__ import annotations

import argparse

from .common import emit, record_output, run_subprocess_bench, write_json

F32 = 4


def analytic_volumes(n: int, feat: int, hidden: int, classes: int, L: int,
                     halo_rows: int, data: int = 1, model: int | None = None,
                     param_bytes: int = 0) -> dict:
    """Forward-pass bytes/epoch summed over all workers (paper §3.2).

    ``dims`` are the per-layer *input* dims [feat, hidden, ..., hidden]:
    ``tp_naive_forward`` splits/gathers the activations entering layer i
    (shape V × dims[i]) — layer *outputs* only ever move as the next
    layer's input, so summing output dims would both drop the feat-dim
    move (the largest) and double-count nothing in its place.

    Hybrid DP×TP changes both columns of the fleet total.  Model-axis:
    every replica group redundantly runs the same gather/split
    all-to-alls (after ``replica_gather`` each group holds the full
    activation block), so the fleet a2a bytes scale with ``data``.
    Data-axis: each of the ``model`` groups ring-all-reduces the
    replicated parameter gradients — ``2·(data−1)·param_bytes`` wire
    bytes per group, zero for pure TP (``data=1``).  The *per-group*
    a2a volume is what the paper's formulas give and is
    replica-count-independent; keeping the two kinds of bytes in
    separate keys (scaled to the same fleet-total convention) is what
    lets the benches expose the a2a-vs-grad-allreduce tradeoff.
    """
    if data > 1 and model is None:
        raise ValueError(
            "hybrid accounting (data > 1) needs the model-group count — "
            "pass model=<TP degree> (a silent default would undercount "
            "grad_allreduce_data by the group factor)")
    if data > 1 and param_bytes <= 0:
        raise ValueError(
            "hybrid accounting (data > 1) needs param_bytes > 0 — a "
            "defaulted 0 would silently zero the data-axis "
            "grad_allreduce_data term")
    dims = [feat] + [hidden] * (L - 1) + [classes]
    return {
        # naive TP: split + gather per layer at the layer-input dim,
        # executed once per replica group
        "naive": data * sum(2 * n * d * F32 for d in dims[:-1]),
        # decoupled: one split + one gather at the class (NN-output) dim
        "decoupled": data * n * classes * F32 * 2,
        # DP: per layer, every remote src row at the layer-input dim
        "dp": data * sum(halo_rows * d * F32 for d in dims[:-1]),
        # all-to-all collectives per epoch: forward + mirrored backward
        "naive_per_epoch": 4 * L,
        "decoupled_per_epoch": 4,
        # data-axis grad all-reduce (ring), summed over the model groups
        "grad_allreduce_data": 2 * (data - 1) * param_bytes * (model or 1),
    }


def main(argv=()):
    # default () so run.py's ``main()`` never sees run.py's own sys.argv;
    # the CLI entry below passes sys.argv[1:] explicitly.
    ap = argparse.ArgumentParser()
    ap.add_argument("--analytic-only", action="store_true",
                    help="skip the 8-device subprocess HLO census")
    args = ap.parse_args(argv)

    from repro.graph import chunk_partition, halo_plan, sbm_power_law

    n, feat, hidden, classes, L, k = 4096, 128, 64, 16, 2, 8
    data = sbm_power_law(n=n, num_classes=classes, feat_dim=feat,
                         avg_degree=16, seed=7)
    g = data.graph

    # --- analytic (paper §3.2) ---
    plan = halo_plan(g, chunk_partition(g, k))
    halo_rows = int((plan.send_idx >= 0).sum())
    # GCN params for the standard workload (grad bytes of the data axis)
    param_bytes = (feat * hidden + hidden + hidden * classes + classes) * F32
    vols = analytic_volumes(n=g.n, feat=feat, hidden=hidden,
                            classes=classes, L=L, halo_rows=halo_rows)
    # hybrid DP×TP on the same 8 devices: (data=2, model=4)
    hyb = analytic_volumes(n=g.n, feat=feat, hidden=hidden,
                           classes=classes, L=L, halo_rows=halo_rows,
                           data=2, model=4, param_bytes=param_bytes)
    # regression pins for the standard workload (ci.sh smoke): naive moves
    # the feat-dim activations — 2·4096·(128+64)·4 — not the output dims.
    assert vols["naive"] == 2 * 4096 * (128 + 64) * 4, vols["naive"]
    assert vols["decoupled"] == 2 * 4096 * 16 * 4, vols["decoupled"]
    assert vols["naive"] > vols["decoupled"] > 0
    assert vols["dp"] > 0 and vols["naive_per_epoch"] == 8
    # data-axis pins: pure TP has no grad all-reduce term; two replica
    # groups of four workers ring-reduce the replicated grads — the bytes
    # are a *data-axis* quantity, invisible to the model-axis formulas.
    assert vols["grad_allreduce_data"] == 0, vols["grad_allreduce_data"]
    assert param_bytes == 37184, param_bytes
    assert hyb["grad_allreduce_data"] == 2 * 1 * param_bytes * 4, \
        hyb["grad_allreduce_data"]
    # fleet-total convention: every replica group redundantly runs the
    # model-axis all-to-alls, so hybrid a2a bytes are data× the pure run
    assert hyb["naive"] == 2 * vols["naive"] and \
        hyb["decoupled"] == 2 * vols["decoupled"], \
        "hybrid fleet a2a must scale with the replica count"

    emit("comm_volume_analytic_naive_tp", 0.0,
         f"bytes_fwd={vols['naive']:.3e}")
    emit("comm_volume_analytic_decoupled_tp", 0.0,
         f"bytes_fwd={vols['decoupled']:.3e}")
    emit("comm_volume_analytic_dp", 0.0,
         f"bytes_fwd={vols['dp']:.3e};halo_rows={halo_rows}")
    emit("comm_volume_analytic_hybrid_d2x4", 0.0,
         f"bytes_a2a_fwd={hyb['decoupled']:.3e};"
         f"bytes_grad_ar_data={hyb['grad_allreduce_data']:.3e}")
    emit("comm_frequency", 0.0,
         f"naive_per_epoch={vols['naive_per_epoch']};"
         f"decoupled_per_epoch={vols['decoupled_per_epoch']}")

    # --- measured from compiled HLO (full train step, fwd+bwd), both
    # engine backends: identical a2a wire bytes, different scheduler ---
    if not args.analytic_only:
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,naive,decoupled", "--census",
                  "--backends", "explicit,constraint",
                  "--tag-prefix", "comm_volume_measured_"])
        print(record_output(out), end="")
        _check_backend_parity(out)

        # hybrid (data=2, model=4) on the same 8 devices: the a2a column
        # is model-axis gather/split traffic; the data axis shows up as
        # all-gather bytes (replica_gather) that pure-TP GCN rows never
        # have — the discriminating signal that the replica plumbing ran
        hyb_out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "decoupled,naive", "--census",
                  "--data", "2",
                  "--tag-prefix", "comm_volume_measured_"])
        print(record_output(hyb_out), end="")
        _check_hybrid_census(hyb_out, out)

    write_json("comm_volume")


def _census_field(derived: str, key: str) -> float | None:
    for field in derived.split(";"):
        if field.startswith(key + "="):
            return float(field[len(key) + 1:])
    return None


def _check_hybrid_census(hyb_out: str, pure_out: str) -> None:
    """Hybrid rows must show *data-axis* traffic on top of the model-axis
    all-to-alls.  The discriminator is the all-gather column: explicit
    GCN decoupled/naive on pure TP emit no all-gathers at all (split and
    gather are a2a, reductions are ar), so ``hybrid ag > pure ag`` holds
    iff the replica_gather/psum-scatter plumbing actually ran — a
    silently-dropped data axis (``data_axes=()``) would zero it while
    leaving a2a and ar plausible-looking."""
    from .common import parse_rows

    hyb = {r["name"]: r["derived"] for r in parse_rows(hyb_out)}
    pure = {r["name"]: r["derived"] for r in parse_rows(pure_out)}
    problems = []
    for mode in ("decoupled", "naive"):
        derived = hyb.get(f"comm_volume_measured_{mode}_d2x4")
        a2a = _census_field(derived, "a2a") if derived else None
        ag = _census_field(derived, "ag") if derived else None
        pure_derived = pure.get(f"comm_volume_measured_{mode}")
        pure_ag = _census_field(pure_derived, "ag") if pure_derived \
            else None
        ok = (a2a is not None and a2a > 0 and ag is not None
              and pure_ag is not None and ag > pure_ag)
        emit(f"comm_volume_hybrid_census_{mode}", 0.0,
             f"a2a={a2a};ag={ag};pure_ag={pure_ag};ok={ok}")
        if not ok:
            problems.append((mode, a2a, ag, pure_ag))
    assert not problems, problems


def _check_backend_parity(out: str) -> None:
    """The constraint backend moves who *schedules* the all-to-alls, not
    what crosses the wire: per mode, measured a2a bytes must be identical
    across backends."""
    from .common import parse_rows

    a2a = {}
    for row in parse_rows(out):
        b = _census_field(row["derived"], "a2a")
        if b is not None:
            a2a[row["name"]] = b
    mismatches = []
    for mode in ("dp", "naive", "decoupled"):
        e = a2a.get(f"comm_volume_measured_{mode}")
        c = a2a.get(f"comm_volume_measured_{mode}_constraint")
        # e > 0 guards the census itself: a parser regression that zeroes
        # a2a bytes on both backends would otherwise pass as 0.0 == 0.0
        ok = e is not None and e > 0 and e == c
        emit(f"comm_volume_backend_parity_{mode}", 0.0,
             f"explicit_a2a={e};constraint_a2a={c};equal={ok}")
        if not ok:
            mismatches.append((mode, e, c))
    # emit every mode's parity row before failing so a mismatch report
    # shows the full picture, not just the first mode
    assert not mismatches, mismatches


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
