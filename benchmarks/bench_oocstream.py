"""Out-of-core streaming footprint: device-resident staged bytes stay
CONSTANT while the vertex count scales (repro.core.stream).

The §4.2 out-of-core claim, measured: scale V by 4× while scaling the
chunk/stripe counts ∝ V (per-item size pinned), and

* the staged double-buffer bytes (``device_resident_bytes``:
  2 stripes + 2 chunk plans) are **identical** at every V (fixed-degree
  sweep graph, so per-chunk edge counts are exact) — asserted, not
  eyeballed;
* the measured H2D bytes/epoch (telemetry ``h2d`` column of a
  post-warmup epoch; collectives are trace-time and already cached)
  equal the analytic :func:`repro.core.stream.expected_h2d_bytes`
  **exactly** — asserted;
* at the largest V the host store is ≥8× the staged stripe budget
  (the "feature matrix 8× bigger than what the device holds" training
  scenario), and the streamed epoch's loss still matches the in-memory
  decoupled epoch to 1e-5 — asserted.

Rows: ``oocstream_V<n>`` with per-epoch wall time and the byte columns;
``oocstream_ratio`` with the store-to-staged ratio of the largest V.
Runs on the single real CPU device (tp_mesh(1)) — the footprint
accounting is whole-mesh and worker-count-independent; the 8-device
equivalence matrix is tests/dist_progs/check_oocstream.py's job.
"""
from __future__ import annotations

from .common import emit, time_epochs, write_json

BASE_N = 512           # smallest V; chunk/stripe counts scale with V
BASE_CHUNKS = 4        # → chunk size (and stripe size) pinned across V
FEAT = 32
HIDDEN = 32
LAYERS = 2


def main():
    import jax
    import numpy as np

    from repro.core import decouple as D
    from repro.core import stream as ST
    from repro.gnn import models as M
    from repro.graph import sbm_power_law
    from repro.runtime import collect_comm, tp_mesh

    from repro.graph import build_graph
    from repro.graph.synthetic import GraphData

    def regular_data(n, deg=8, seed=0):
        """Circulant (fixed in-degree) graph: every vertex has exactly
        ``deg`` distinct non-self in-neighbors (+ the self loop), so
        every chunk holds exactly chunk_size·(deg+1) edges and the
        staged-bytes-constant assert is exact.  (Skewed graphs grow the
        *hottest* chunk with V — that is the paper's load-imbalance
        motivation, a property of the degree distribution, not of the
        streaming machinery; the ratio scenario below uses the skewed
        graph.)"""
        rng = np.random.default_rng(seed)
        dst = np.repeat(np.arange(n, dtype=np.int32), deg)
        src = ((dst + np.tile(np.arange(1, deg + 1, dtype=np.int32), n))
               % n).astype(np.int32)
        g = build_graph(src, dst, n)
        labels = rng.integers(0, 8, n).astype(np.int32)
        feats = (np.eye(8, FEAT, dtype=np.float32)[labels]
                 + rng.normal(0, 0.5, (n, FEAT)).astype(np.float32))
        mask = np.ones(n, bool)
        return GraphData(graph=g, features=feats, labels=labels,
                         train_mask=mask, val_mask=mask, test_mask=mask,
                         num_classes=8)

    mesh = tp_mesh(1)
    footprints = []
    for factor in (1, 2, 4):
        n = BASE_N * factor
        data = regular_data(n)
        sb = ST.prepare_stream_bundle(data, n_workers=1,
                                      n_chunks=BASE_CHUNKS * factor)
        cfg = ST.stream_gnn_config(data, sb, hidden_dim=HIDDEN,
                                   num_layers=LAYERS)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        vg = ST.make_stream_value_and_grad(cfg, sb)
        us = time_epochs(vg, params, sb.train_mask) * 1e6
        with collect_comm() as led:
            loss, _ = vg(params, sb.train_mask)
        d = led.as_dict()
        assert all(k.startswith("h2d|") for k in d), \
            ("post-warmup epoch retraced — h2d column is polluted", d)
        h2d = sum(v["payload_bytes"] for v in d.values())
        expect = ST.expected_h2d_bytes(sb, cfg)
        assert h2d == expect, (n, h2d, expect)
        foot = ST.device_resident_bytes(sb, cfg)
        staged = (foot["staged_stripe_bytes"]
                  + foot["staged_chunk_bytes"])
        footprints.append(foot)
        emit(f"oocstream_V{n}", us,
             f"staged_bytes={staged};store_bytes={sb.store.nbytes};"
             f"h2d_bytes_per_epoch={int(h2d)};analytic=exact;"
             f"working_bytes={foot['working_bytes']};"
             f"n_chunks={sb.n_chunks}")

    stripes = [f["staged_stripe_bytes"] for f in footprints]
    chunks = [f["staged_chunk_bytes"] for f in footprints]
    assert len(set(stripes)) == 1 and len(set(chunks)) == 1, \
        (f"staged footprint must be constant across the 4x V sweep: "
         f"stripes={stripes} chunks={chunks}")

    # ratio scenario, on the SKEWED graph: the host store is >= 8x the
    # staged stripe budget and the streamed epoch's loss still matches
    # the in-memory decoupled epoch
    data = sbm_power_law(n=BASE_N * 4, num_classes=8, feat_dim=FEAT,
                         avg_degree=8, seed=0)
    sb = ST.prepare_stream_bundle(data, n_workers=1,
                                  n_chunks=BASE_CHUNKS * 4)
    cfg = ST.stream_gnn_config(data, sb, hidden_dim=HIDDEN,
                               num_layers=LAYERS)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ratio = sb.store.nbytes / sb.store.stripe_nbytes
    assert ratio >= 8, (sb.store.nbytes, sb.store.stripe_nbytes)
    stream_loss, _ = ST.make_stream_value_and_grad(cfg, sb)(
        params, sb.train_mask)
    ref = D.prepare_bundle(data, n_workers=1, n_chunks=sb.n_chunks)
    ref_loss, _ = D.make_tp_value_and_grad(cfg, ref, mesh)(
        params, ref.train_mask)
    dl = abs(float(stream_loss) - float(ref_loss))
    assert dl < 1e-5, (float(stream_loss), float(ref_loss))
    emit("oocstream_ratio", 0.0,
         f"store_to_stripe_ratio={ratio:.1f};dloss_vs_inmemory={dl:.2e};"
         f"staged_stripe_bytes={stripes[-1]};V={sb.n_padded};"
         f"graph=sbm_power_law")

    write_json("oocstream")


if __name__ == "__main__":
    main()
