"""Kernel microbenchmark: Pallas block-sparse SpMM (interpret mode) vs the
segment-sum path — correctness-at-scale plus arithmetic-intensity report.
(On CPU the interpret-mode timing is NOT indicative of TPU perf; the
derived column reports the structural quantities that matter on TPU.)
"""
from __future__ import annotations

import time

from .common import emit, write_json


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.gnn import layers as L
    from repro.graph import block_sparse, sbm_power_law
    from repro.kernels.spmm import aggregate_pallas, block_sparse_dev

    data = sbm_power_law(n=4096, num_classes=8, feat_dim=128,
                         avg_degree=16, seed=7)
    g = data.graph
    bsg = block_sparse(g, bs=128)
    dev = block_sparse_dev(bsg)
    h = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.n, 128)).astype(np.float32))

    ref_fn = jax.jit(lambda hh: L.aggregate(L.edge_list_dev(g), hh))
    out_ref = ref_fn(h)

    pl_fn = jax.jit(lambda hh: aggregate_pallas(dev, hh))
    out_pl = pl_fn(h)
    err = float(jnp.abs(out_ref - out_pl).max())

    def timed(fn, iters=3):
        o = fn(h); jax.block_until_ready(o)
        t0 = time.perf_counter()
        for _ in range(iters):
            o = fn(h)
        jax.block_until_ready(o)
        return (time.perf_counter() - t0) / iters

    t_ref = timed(ref_fn)
    t_pl = timed(pl_fn)
    flops = 2.0 * bsg.nnzb * bsg.bs * bsg.bs * h.shape[1]
    vmem_tile_kb = (bsg.bs * bsg.bs + 2 * bsg.bs * 128) * 4 / 1024
    emit("spmm_segment_sum", t_ref * 1e6, f"err_vs_pallas={err:.2e}")
    emit("spmm_pallas_interpret", t_pl * 1e6,
         f"nnzb={bsg.nnzb};density={bsg.density():.3f};"
         f"tile_flops={flops:.3e};vmem_per_step_kb={vmem_tile_kb:.0f}")

    write_json("spmm_kernel")


if __name__ == "__main__":
    main()
