"""Pallas block-sparse SpMM: kernel microbenchmark + end-to-end epoch A/B.

Two tiers, one JSON (``BENCH_spmm_kernel.json``):

* kernel micro — interpret-mode Pallas vs the segment-sum path on one
  full-graph aggregation (correctness + arithmetic-intensity report);
* epoch A/B — full decoupled-GCN training epochs through
  ``make_tp_train_fns`` with each pluggable aggregation backend
  (``repro.core.agg``: segment / blocksparse / dense) at two power-law
  sparsity levels, with the structural columns that matter on TPU:
  nnzb, block density, tile FLOPs (2·nnzb·bs²·D) vs the segment path's
  O(E·D) gather/scatter FLOPs (2·E·D).

(On CPU the interpret-mode timing is NOT indicative of TPU perf; the
derived columns report the structural quantities that matter there.)
"""
from __future__ import annotations

import time

from .common import emit, time_epochs, write_json


def _micro(jax, jnp, np):
    from repro.gnn import layers as L
    from repro.graph import block_sparse, sbm_power_law
    from repro.kernels.spmm import aggregate_pallas, block_sparse_dev

    data = sbm_power_law(n=4096, num_classes=8, feat_dim=128,
                         avg_degree=16, seed=7)
    g = data.graph
    bsg = block_sparse(g, bs=128)
    dev = block_sparse_dev(bsg)
    h = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.n, 128)).astype(np.float32))

    ref_fn = jax.jit(lambda hh: L.aggregate(L.edge_list_dev(g), hh))
    out_ref = ref_fn(h)

    pl_fn = jax.jit(lambda hh: aggregate_pallas(dev, hh))
    out_pl = pl_fn(h)
    err = float(jnp.abs(out_ref - out_pl).max())

    def timed(fn, iters=3):
        o = fn(h); jax.block_until_ready(o)
        t0 = time.perf_counter()
        for _ in range(iters):
            o = fn(h)
        jax.block_until_ready(o)
        return (time.perf_counter() - t0) / iters

    t_ref = timed(ref_fn)
    t_pl = timed(pl_fn)
    flops = 2.0 * bsg.nnzb * bsg.bs * bsg.bs * h.shape[1]
    vmem_tile_kb = (bsg.bs * bsg.bs + 2 * bsg.bs * 128) * 4 / 1024
    emit("spmm_segment_sum", t_ref * 1e6, f"err_vs_pallas={err:.2e}")
    emit("spmm_pallas_interpret", t_pl * 1e6,
         f"nnzb={bsg.nnzb};density={bsg.density():.3f};"
         f"tile_flops={flops:.3e};vmem_per_step_kb={vmem_tile_kb:.0f}")


def _epoch_ab(jax, jnp, np):
    """Epoch-level A/B of the pluggable aggregation backends."""
    from repro import optim
    from repro.core import decouple as D
    from repro.core.agg import AGG_BACKENDS
    from repro.gnn import models as M
    from repro.graph import sbm_power_law
    from repro.runtime import tp_mesh

    # bs=32 keeps the tile grid fine enough that the two power-law
    # degrees land at visibly different block densities
    n, feat, hidden, chunks, bs = 2048, 64, 32, 4, 32
    mesh = tp_mesh(1)
    opt = optim.adamw(1e-2)
    for avg_degree in (4, 16):
        data = sbm_power_law(n=n, num_classes=8, feat_dim=feat,
                             avg_degree=avg_degree, seed=7)
        e = data.graph.e
        seg_flops = 2.0 * e * hidden
        losses = {}
        for agg in AGG_BACKENDS:
            bundle = D.prepare_bundle(data, n_workers=1, n_chunks=chunks,
                                      agg=agg, agg_block_size=bs)
            cfg = D.padded_gnn_config(data, bundle, model="gcn",
                                      hidden_dim=hidden, num_layers=2)
            params = M.init_params(jax.random.PRNGKey(0), cfg)
            step, _ = D.make_tp_train_fns(cfg, bundle, mesh, opt,
                                          mode="decoupled")
            state = [params, opt.init(params)]

            def one_epoch():
                state[0], state[1], loss = step(state[0], state[1])
                return loss

            # 1+2 epochs: interpret-mode tile scans are slow on CPU and
            # the structural columns, not the timing, are the signal here
            t = time_epochs(one_epoch, warmup=1, iters=2)
            losses[agg] = float(one_epoch())
            if agg == "blocksparse":
                plan = bundle.graph.bsp
                nnzb = int(np.prod(plan.blocks.shape[:2]))
                density = (nnzb * plan.bs * plan.bs
                           / (chunks * plan.rows_padded * plan.cols_padded))
                tile_flops = 2.0 * nnzb * plan.bs * plan.bs * hidden
                derived = (f"nnzb={nnzb};density={density:.3f};"
                           f"tile_flops={tile_flops:.3e};"
                           f"segment_flops={seg_flops:.3e}")
            else:
                derived = f"edges={e};segment_flops={seg_flops:.3e}"
            emit(f"epoch_gcn_{agg}_deg{avg_degree}", t * 1e6, derived)
        spread = max(losses.values()) - min(losses.values())
        assert spread < 1e-4, f"backend losses diverged: {losses}"


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    _micro(jax, jnp, np)
    _epoch_ab(jax, jnp, np)
    write_json("spmm_kernel")


if __name__ == "__main__":
    main()
