"""Paper Fig. 12: per-epoch runtime vs cluster size (2/4/8 workers)."""
from __future__ import annotations

from .common import record_output, run_subprocess_bench, write_json


def main():
    for k in (2, 4, 8):
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=k,
            args=["--modes", "dp,decoupled_pipelined",
                  "--tag-prefix", f"scaling_k{k}_"])
        print(record_output(out), end="")

    write_json("scaling")


if __name__ == "__main__":
    main()
