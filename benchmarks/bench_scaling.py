"""Paper Fig. 12: per-epoch runtime vs cluster size (2/4/8 workers),
plus hybrid DP×TP shapes of the 8-device budget — (data=2, model=4) and
(data=4, model=2) — so the scaling table shows how the same devices trade
model-axis a2a volume against data-axis grad all-reduce volume.

Every row also carries the telemetry-ledger columns (``led_a2a`` /
``led_agd`` — per-device train-step wire bytes measured at trace time by
:mod:`repro.runtime.telemetry`), so the a2a-vs-replica-traffic tradeoff
is read directly off the measured table instead of an HLO census."""
from __future__ import annotations

from .common import record_output, run_subprocess_bench, write_json


def main():
    for k in (2, 4, 8):
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=k,
            args=["--modes", "dp,decoupled_pipelined",
                  "--tag-prefix", f"scaling_k{k}_"])
        print(record_output(out), end="")

    # hybrid factorizations of the 8-device budget (rows carry a
    # _d<data>x<model> suffix from _dist_gnn)
    for data in (2, 4):
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,decoupled_pipelined",
                  "--data", str(data),
                  "--tag-prefix", "scaling_k8_"])
        print(record_output(out), end="")

    write_json("scaling")


if __name__ == "__main__":
    main()
