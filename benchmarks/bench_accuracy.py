"""Paper Fig. 16 / §5.7: epoch-to-accuracy — decoupled vs coupled training
reach comparable accuracy (single device, identical data/splits)."""
from __future__ import annotations

from .common import emit, write_json


def main():
    from repro.gnn.models import GNNConfig
    from repro.gnn.train import train_full_graph
    from repro.graph import sbm_power_law

    data = sbm_power_law(n=2048, num_classes=8, feat_dim=64, avg_degree=12,
                         seed=11)
    results = {}
    for name, dec in (("coupled", False), ("decoupled", True)):
        cfg = GNNConfig(model="gcn", in_dim=64, hidden_dim=64,
                        num_classes=8, num_layers=2, decoupled=dec)
        _, logs = train_full_graph(data, cfg, epochs=100, lr=1e-2,
                                   log_every=10)
        curve = ";".join(f"e{l.epoch}={l.test_acc:.3f}" for l in logs)
        results[name] = logs[-1].test_acc
        emit(f"accuracy_{name}", sum(l.seconds for l in logs) * 1e6 / 100,
             curve)
    emit("accuracy_gap", 0.0,
         f"|coupled-decoupled|={abs(results['coupled'] - results['decoupled']):.4f}")

    write_json("accuracy")


if __name__ == "__main__":
    main()
