"""Paper Table 4: training-cost breakdown for node classification and link
prediction (GNN computation vs classification vs loss vs neg-sampling)."""
from __future__ import annotations

import time

from .common import emit, write_json


def main():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.gnn import layers as L
    from repro.gnn import models as M
    from repro.graph import sbm_power_law

    data = sbm_power_law(n=4096, num_classes=16, feat_dim=128,
                         avg_degree=16, seed=7)
    g = L.edge_list_dev(data.graph)
    x = jnp.asarray(data.features)
    labels = jnp.asarray(data.labels)
    mask = jnp.asarray(data.train_mask.astype("float32"))
    cfg = M.GNNConfig(model="gcn", in_dim=128, hidden_dim=64,
                      num_classes=16, num_layers=2, decoupled=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)

    def timed(fn, *args, iters=5):
        out = fn(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / iters

    # --- node classification phases ---
    mlp = jax.jit(lambda p, xx: M.mlp_phase(p, cfg, xx))
    h = mlp(params, x)
    t_nn = timed(mlp, params, x)

    def agg(hh):
        w = cfg.gamma * g.weight
        z = hh
        for _ in range(cfg.num_layers):
            z = L.aggregate(g, z, edge_weight=w)
        return z
    agg_j = jax.jit(agg)
    z = agg_j(h)
    t_agg = timed(agg_j, h)

    loss_j = jax.jit(lambda lg: M.cross_entropy(lg, labels, mask))
    t_loss = timed(loss_j, z)
    total = t_nn + t_agg + t_loss
    emit("breakdown_nc_gnn_computation", (t_nn + t_agg) * 1e6,
         f"fraction={(t_nn + t_agg) / total:.2f};"
         f"nn={t_nn*1e6:.0f}us;agg={t_agg*1e6:.0f}us")
    emit("breakdown_nc_loss", t_loss * 1e6,
         f"fraction={t_loss / total:.2f}")

    # --- link prediction: dot-product decoder + negative sampling ---
    rng = np.random.default_rng(0)
    pos_src = jnp.asarray(data.graph.src[: 8192])
    pos_dst = jnp.asarray(data.graph.dst[: 8192])

    def neg_sample(key):
        return jax.random.randint(key, (8192,), 0, data.graph.n)
    neg_j = jax.jit(neg_sample)
    t_neg = timed(neg_j, jax.random.PRNGKey(1))

    def lp_score(z):
        pos = jnp.sum(z[pos_src] * z[pos_dst], axis=-1)
        return pos
    lp_j = jax.jit(lp_score)
    t_score = timed(lp_j, z)

    def lp_loss(z, neg):
        pos = jnp.sum(z[pos_src] * z[pos_dst], axis=-1)
        negs = jnp.sum(z[pos_src] * z[neg], axis=-1)
        return (jax.nn.softplus(-pos).mean()
                + jax.nn.softplus(negs).mean())
    lpl_j = jax.jit(lp_loss)
    neg = neg_j(jax.random.PRNGKey(1))
    t_lploss = timed(lpl_j, z, neg)
    total_lp = t_nn + t_agg + t_neg + t_score + t_lploss
    emit("breakdown_lp_neg_sampling", t_neg * 1e6,
         f"fraction={t_neg / total_lp:.2f}")
    emit("breakdown_lp_gnn_computation", (t_nn + t_agg) * 1e6,
         f"fraction={(t_nn + t_agg) / total_lp:.2f}")
    emit("breakdown_lp_score_and_loss", (t_score + t_lploss) * 1e6,
         f"fraction={(t_score + t_lploss) / total_lp:.2f}")

    write_json("breakdown")


if __name__ == "__main__":
    main()
