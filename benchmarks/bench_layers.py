"""Paper Fig. 13: per-epoch runtime vs model depth (TP advantage grows
with L because its comm frequency is depth-independent)."""
from __future__ import annotations

from .common import record_output, run_subprocess_bench, write_json


def main():
    for layers in (2, 3, 4, 5):
        out = run_subprocess_bench(
            "benchmarks._dist_gnn", devices=8,
            args=["--modes", "dp,decoupled_pipelined",
                  "--layers", str(layers),
                  "--tag-prefix", f"layers_L{layers}_"])
        print(record_output(out), end="")

    write_json("layers")


if __name__ == "__main__":
    main()
