"""Kernel microbenchmark: Pallas flash attention (interpret mode) vs the
dense oracle and the jnp blockwise schedule — correctness at a non-trivial
shape plus the structural quantities that matter on TPU (VMEM working set,
modeled HBM traffic vs the naive S² traffic).  Interpret-mode wall time on
CPU is NOT indicative of TPU perf.
"""
from __future__ import annotations

import time

from .common import emit, write_json


def main():
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attn import flash_attention, hbm_bytes_model
    from repro.kernels.flash_attn.ref import flash_ref
    from repro.nn.attention import attention_blockwise

    b, s, hq, hkv, hd = 1, 1024, 8, 2, 64
    bq = bkv = 128
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, hq, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))

    ref_fn = jax.jit(lambda q, k, v: flash_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), causal=True).transpose(0, 2, 1, 3))
    bw_fn = jax.jit(lambda q, k, v: attention_blockwise(
        q, k, v, causal=True, block_q=bq, block_kv=bkv))
    fl_fn = jax.jit(lambda q, k, v: flash_attention(
        q, k, v, causal=True, block_q=bq, block_kv=bkv, interpret=True))

    out_ref = ref_fn(q, k, v)
    err_fl = float(jnp.abs(fl_fn(q, k, v) - out_ref).max())
    err_bw = float(jnp.abs(bw_fn(q, k, v) - out_ref).max())

    def timed(fn, iters=3):
        o = fn(q, k, v)
        jax.block_until_ready(o)
        t0 = time.perf_counter()
        for _ in range(iters):
            o = fn(q, k, v)
        jax.block_until_ready(o)
        return (time.perf_counter() - t0) / iters

    t_ref = timed(ref_fn)
    t_bw = timed(bw_fn)
    t_fl = timed(fl_fn)

    naive_bytes = b * hq * s * s * 4 * 2          # scores + probs fp32
    kernel_bytes = hbm_bytes_model(b, hq, hkv, s, s, hd, hd, block_q=bq)
    vmem_kb = (bq * hd + 2 * bkv * hd + bq * bkv + bq * (hd + 2)) * 4 / 1024
    emit("flash_dense_ref", t_ref * 1e6, f"err=0")
    emit("flash_jnp_blockwise", t_bw * 1e6, f"err_vs_ref={err_bw:.2e}")
    emit("flash_pallas_interpret", t_fl * 1e6,
         f"err_vs_ref={err_fl:.2e};vmem_per_step_kb={vmem_kb:.0f};"
         f"hbm_model_bytes={kernel_bytes:.3e};"
         f"naive_score_bytes={naive_bytes:.3e};"
         f"traffic_reduction={naive_bytes / kernel_bytes:.1f}x")

    write_json("flash_kernel")


if __name__ == "__main__":
    main()
