"""Shared benchmark utilities.

Multi-worker benchmarks run as child processes with
``XLA_FLAGS=--xla_force_host_platform_device_count=<k>`` so the main bench
process keeps the single real CPU device (per the dry-run isolation rule).

Every row a bench prints (directly via :func:`emit` or collected from a
child's stdout via :func:`record_output`) is also buffered; calling
:func:`write_json` at the end of a bench main persists the run as
``results/BENCH_<name>.json`` so the perf trajectory is machine-readable
instead of stdout-only.
"""
from __future__ import annotations

import json
import os
import re
import subprocess
import sys
import time

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
RESULTS_DIR = os.path.join(REPO_ROOT, "results")

#: Rows buffered for :func:`write_json` (cleared on each write).
_ROWS: list[dict] = []

_ROW_RE = re.compile(r"^([\w+.\-]+),([0-9.eE+\-]+),(.*)$")


def reset_rows() -> None:
    """Drop buffered rows.  run.py calls this between bench modules so a
    bench that died mid-run can't leak its rows into the next module's
    JSON (write_json only clears on success)."""
    _ROWS.clear()


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    _ROWS.append({"name": name, "us_per_call": float(us_per_call),
                  "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def parse_rows(text: str) -> list[dict]:
    """CSV rows (``tag,us,derived``) in ``text`` → list of row dicts."""
    rows = []
    for line in text.splitlines():
        m = _ROW_RE.match(line.strip())
        if m:
            rows.append({"name": m.group(1),
                         "us_per_call": float(m.group(2)),
                         "derived": m.group(3)})
    return rows


def record_output(text: str) -> str:
    """Buffer the CSV rows of a child bench's stdout; returns ``text`` so
    callers can keep printing it."""
    _ROWS.extend(parse_rows(text))
    return text


def _process_index() -> int:
    """This process's rank in a ``jax.distributed`` job (0 when jax is
    not even imported yet — plain single-process benches must not pay a
    jax import just to write their JSON).

    When ``repro.runtime.distributed`` is loaded its context is
    authoritative: it raises actionably if the multihost env contract is
    set but ``initialize()`` never ran, instead of this function
    reporting rank 0 on every process and letting N processes race on
    the same BENCH_*.json."""
    if "repro.runtime.distributed" in sys.modules:
        return sys.modules["repro.runtime.distributed"].context().process_id
    if "jax" not in sys.modules:
        return 0
    try:
        return sys.modules["jax"].process_index()
    except Exception:  # noqa: BLE001 — accounting only
        return 0


def write_json(bench_name: str, out_dir: str = RESULTS_DIR) -> str:
    """Persist the buffered rows as ``<out_dir>/BENCH_<bench_name>.json``.

    The payload is also mirrored to ``BENCH_<bench_name>.json`` at the
    repo root: the perf-trajectory tooling only scans the root, so runs
    that landed exclusively under results/ were invisible to it (an
    empty trajectory despite results existing).

    **Process-0-only** under multihost: every process of a
    ``jax.distributed`` job runs the same bench code, and N processes
    writing the same ``BENCH_*.json`` would race (interleaved/truncated
    files); non-coordinator processes drop their rows and write
    nothing."""
    if _process_index() != 0:
        _ROWS.clear()
        return os.path.join(out_dir, f"BENCH_{bench_name}.json")
    payload = json.dumps({"bench": bench_name, "entries": list(_ROWS)},
                         indent=2) + "\n"
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{bench_name}.json")
    targets = [path]
    root_path = os.path.join(REPO_ROOT, f"BENCH_{bench_name}.json")
    if os.path.abspath(root_path) != os.path.abspath(path):
        targets.append(root_path)
    for p in targets:
        with open(p, "w") as f:
            f.write(payload)
    _ROWS.clear()
    return path


def time_epochs(step_fn, *args, warmup: int = 2, iters: int = 3) -> float:
    """Median-ish per-call seconds for a jitted step closure."""
    out = None
    for _ in range(warmup):
        out = step_fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step_fn(*args)
    _block(out)
    return (time.perf_counter() - t0) / iters


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def run_subprocess_bench(module: str, devices: int = 8,
                         args: list[str] | None = None,
                         timeout: int = 1800) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", module] + (args or [])
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env,
                          cwd=os.path.dirname(SRC))
    if proc.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{proc.stderr[-4000:]}")
    return proc.stdout


# standard bench workload (Reddit-like scaled to CPU budget)
BENCH_GRAPH = dict(n=4096, num_classes=16, feat_dim=128, avg_degree=16,
                   seed=7)
BENCH_HIDDEN = 64
