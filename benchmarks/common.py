"""Shared benchmark utilities.

Multi-worker benchmarks run as child processes with
``XLA_FLAGS=--xla_force_host_platform_device_count=<k>`` so the main bench
process keeps the single real CPU device (per the dry-run isolation rule).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_epochs(step_fn, *args, warmup: int = 2, iters: int = 3) -> float:
    """Median-ish per-call seconds for a jitted step closure."""
    out = None
    for _ in range(warmup):
        out = step_fn(*args)
    _block(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = step_fn(*args)
    _block(out)
    return (time.perf_counter() - t0) / iters


def _block(out):
    import jax
    for leaf in jax.tree.leaves(out):
        if hasattr(leaf, "block_until_ready"):
            leaf.block_until_ready()


def run_subprocess_bench(module: str, devices: int = 8,
                         args: list[str] | None = None,
                         timeout: int = 1800) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    cmd = [sys.executable, "-m", module] + (args or [])
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          timeout=timeout, env=env,
                          cwd=os.path.dirname(SRC))
    if proc.returncode != 0:
        raise RuntimeError(f"{module} failed:\n{proc.stderr[-4000:]}")
    return proc.stdout


# standard bench workload (Reddit-like scaled to CPU budget)
BENCH_GRAPH = dict(n=4096, num_classes=16, feat_dim=128, avg_degree=16,
                   seed=7)
BENCH_HIDDEN = 64
