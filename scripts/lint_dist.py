#!/usr/bin/env python
"""Distributed-discipline linter CLI (tier 1 of ``repro.analysis``).

Runs the AST rule registry in ``repro.analysis.lint`` over the source
tree and exits nonzero iff any *error*-severity finding survives
(``# lint-ok: RULE`` suppressions honored; ``warn`` rules such as W100
report but never fail).  Default targets are the engine tree and the
dist programs — the two places the RT invariants bind:

    python scripts/lint_dist.py                    # src/repro + tests/dist_progs
    python scripts/lint_dist.py --json out.json    # + machine-readable artifact
    python scripts/lint_dist.py --rules            # print the rule table
    python scripts/lint_dist.py tests/fixtures/lint   # lint something else

ci.sh runs this as its ``lint`` stage (default and --fast lanes) and
drops the JSON artifact in results/ next to the BENCH files.  See
ROADMAP.md "Distributed discipline" for rule ID → invariant → PR.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
sys.path.insert(0, os.path.join(_ROOT, "src"))

from repro.analysis import lint  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="AST linter for the repo's distributed disciplines")
    ap.add_argument("paths", nargs="*",
                    help="files/dirs to lint (default: src/repro + "
                         "tests/dist_progs)")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="also write findings as a JSON artifact")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.rules:
        for rule in lint.all_rules():
            print(f"{rule.id}  [{rule.severity}]  {rule.invariant}")
        return 0

    paths = args.paths or [os.path.join(_ROOT, "src", "repro"),
                           os.path.join(_ROOT, "tests", "dist_progs")]
    findings = lint.lint_paths(paths)

    errors = [f for f in findings if f.severity == "error"]
    warns = [f for f in findings if f.severity != "error"]
    for f in findings:
        print(f.format())

    if args.json:
        artifact = {
            "rules": {r.id: {"severity": r.severity,
                             "invariant": r.invariant}
                      for r in lint.all_rules()},
            "findings": [f.as_dict() for f in findings],
            "counts": {"error": len(errors), "warn": len(warns)},
        }
        os.makedirs(os.path.dirname(os.path.abspath(args.json)),
                    exist_ok=True)
        with open(args.json, "w") as fh:
            json.dump(artifact, fh, indent=2, sort_keys=True)
            fh.write("\n")

    print(f"lint_dist: {len(errors)} error(s), {len(warns)} warning(s) "
          f"across {len(paths)} path(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
