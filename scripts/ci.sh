#!/usr/bin/env bash
# Tier-1 verification in one invocation (the ROADMAP's tier-1 command,
# reproducible):
#
#   scripts/ci.sh            # fast lane, then the 8-device subprocess lane
#   scripts/ci.sh --fast     # fast lane only (-m "not slow")
#
# The main pytest process stays on the single real device.  The "slow"
# tests launch child processes via tests/conftest.py::run_dist_prog, which
# pins XLA_FLAGS=--xla_force_host_platform_device_count=8 (the single
# definition lives in conftest.DIST_XLA_FLAGS; the dist_progs assert on
# it) so the runtime-engine collectives execute across 8 real device
# buffers.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -m "not slow"

if [[ "${1:-}" != "--fast" ]]; then
    python -m pytest -q -m slow
fi
