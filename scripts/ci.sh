#!/usr/bin/env bash
# Tier-1 verification in one invocation (the ROADMAP's tier-1 command,
# reproducible):
#
#   scripts/ci.sh            # fast lane + bench smoke, then the 8-device
#                            # subprocess lane
#   scripts/ci.sh --fast     # fast lane + bench smoke only (-m "not slow")
#
# The main pytest process stays on the single real device.  The "slow"
# tests launch child processes via tests/conftest.py::run_dist_prog, which
# pins XLA_FLAGS=--xla_force_host_platform_device_count=8 (the single
# definition lives in conftest.DIST_XLA_FLAGS; the dist_progs assert on
# it) so the runtime-engine collectives execute across 8 real device
# buffers.
#
# The bench smoke runs the analytic half of bench_comm_volume plus the
# telemetry smoke: a fast trace-only 8-device subprocess in which the
# trace-time collective ledger (repro.runtime.telemetry) must match the
# analytic comm-volume formulas exactly (led_a2a vs expected_ledger,
# asserted in-process by _dist_gnn --assert-ledger, pure TP and a
# (data=2, model=4) hybrid).  So both formula regressions — like naive
# TP summing layer-output dims instead of layer-input dims — AND
# telemetry accounting regressions fail tier-1 instead of silently
# skewing the Fig. 8 comparison.  Asserts live in
# benchmarks/bench_comm_volume.py and cover the data-axis terms of
# hybrid DP×TP (grad_allreduce_data pins: zero for pure TP, ring-bytes
# per model group for (data=2, model=4); model-axis a2a volumes must not
# change with the replica count).
#
# The slow lane includes the hybrid DP×TP equivalence dist prog
# (tests/dist_progs/check_hybrid_mesh.py via tests/test_hybrid_mesh.py):
# (data=2, model=4) and (data=4, model=2) hybrid training must match
# pure TP (model=8) and a single-device reference — losses AND grads to
# atol 1e-5 — for GCN/GAT × all four modes × both engine backends, so
# hybrid regressions fail tier-1.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m pytest -q -m "not slow"

python -m benchmarks.bench_comm_volume --telemetry-smoke

if [[ "${1:-}" != "--fast" ]]; then
    python -m pytest -q -m slow
fi
