#!/usr/bin/env bash
# Tier-1 verification in one invocation (the ROADMAP's tier-1 command,
# reproducible):
#
#   scripts/ci.sh            # fast lane + bench smokes, then the 8-device
#                            # subprocess lane
#   scripts/ci.sh --fast     # fast lane + bench smokes only (-m "not slow")
#   scripts/ci.sh --multihost-smoke   # just the multihost smoke stage
#   scripts/ci.sh --oocstream-smoke   # just the out-of-core streaming smoke
#
# Every lane (default and --fast) starts with the distributed-discipline
# lint stage (scripts/lint_dist.py): AST rules RT001-RT005 over src/repro
# and tests/dist_progs, nonzero exit on any error finding.
#
# The main pytest process stays on the single real device.  The "slow"
# tests launch child processes via tests/conftest.py::run_dist_prog, which
# pins XLA_FLAGS=--xla_force_host_platform_device_count=8 (the single
# definition lives in conftest.DIST_XLA_FLAGS; the dist_progs assert on
# it) so the runtime-engine collectives execute across 8 real device
# buffers.
#
# The bench smoke runs the analytic half of bench_comm_volume plus the
# telemetry smoke: a fast trace-only 8-device subprocess in which the
# trace-time collective ledger (repro.runtime.telemetry) must match the
# analytic comm-volume formulas exactly (led_a2a vs expected_ledger,
# asserted in-process by _dist_gnn --assert-ledger, pure TP and a
# (data=2, model=4) hybrid).  So both formula regressions — like naive
# TP summing layer-output dims instead of layer-input dims — AND
# telemetry accounting regressions fail tier-1 instead of silently
# skewing the Fig. 8 comparison.  Asserts live in
# benchmarks/bench_comm_volume.py and cover the data-axis terms of
# hybrid DP×TP (grad_allreduce_data pins: zero for pure TP, ring-bytes
# per model group for (data=2, model=4); model-axis a2a volumes must not
# change with the replica count).
#
# The multihost smoke is the REAL jax.distributed launcher path on the
# supported no-cluster topology (see scripts/launch_multihost.sh and
# repro.runtime.distributed): 2 coordinator+worker processes × 2 forced
# host devices each train one decoupled-GCN epoch on a 4-device global
# mesh — cross-process gather/split all-to-alls over gloo — with the
# trace-time telemetry ledger asserted against the analytic §3.2
# formulas in-process (_dist_gnn --multihost --assert-ledger,
# process-0-only).  A broken launcher, broken per-host bundle
# placement, or broken cross-host collective accounting fails tier-1
# here instead of only in the slow lane.
#
# The slow lane includes the hybrid DP×TP equivalence dist prog
# (tests/dist_progs/check_hybrid_mesh.py via tests/test_hybrid_mesh.py):
# (data=2, model=4) and (data=4, model=2) hybrid training must match
# pure TP (model=8) and a single-device reference — losses AND grads to
# atol 1e-5 — for GCN/GAT × all four modes × both engine backends, so
# hybrid regressions fail tier-1.  It also runs the multihost
# equivalence suite (tests/test_multihost.py → dist_progs/
# check_multihost.py under the multi-process harness): 2 processes × 4
# fake devices must reproduce the single-process 8-device losses AND
# grads to atol 1e-5 for all four modes × both backends.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

multihost_smoke() {
    scripts/launch_multihost.sh -n 2 -d 2 -t 600 -- \
        python -m benchmarks._dist_gnn --multihost --modes decoupled \
            --model gcn --n 256 --feat-dim 16 --classes 4 --hidden 8 \
            --layers 2 --chunks 2 --epochs 1 --assert-ledger \
            --tag-prefix mh_
}

oocstream_smoke() {
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
        python tests/dist_progs/check_oocstream.py --ci-smoke
}

if [[ "${1:-}" == "--multihost-smoke" ]]; then
    multihost_smoke
    exit 0
fi

if [[ "${1:-}" == "--oocstream-smoke" ]]; then
    oocstream_smoke
    exit 0
fi

# Tier-1 static analysis: the AST linter over the real tree (RT001–RT005
# distributed-discipline rules, see repro.analysis.lint).  Error findings
# fail CI; the JSON artifact lands next to the BENCH files in results/.
mkdir -p results
python scripts/lint_dist.py --json results/lint_dist.json

python -m pytest -q -m "not slow"

python -m benchmarks.bench_comm_volume --telemetry-smoke

# Aggregation-backend smoke (8 forced devices): decoupled GCN losses AND
# grads must be identical (atol 1e-5) between the segment baseline and
# the Pallas block-sparse backend, with the trace-time CommLedger
# byte-identical and the blocksparse programs passing the tier-2 jaxpr
# collective audit — the backend choice is pure local compute.
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python tests/dist_progs/check_agg_backends.py --ci-smoke

# Out-of-core streaming smoke (8 forced devices): the streamed decoupled
# epoch (host feature store + double-buffered H2D prefetch,
# repro.core.stream) must match the in-memory epoch — losses AND grads
# to atol 1e-5, collective CommLedger byte-identical, and the measured
# h2d column equal to the analytic expected_h2d_bytes exactly — for
# segment+blocksparse × both engine backends.
oocstream_smoke

multihost_smoke

if [[ "${1:-}" != "--fast" ]]; then
    python -m pytest -q -m slow
fi
