#!/usr/bin/env bash
# Spawn an N-process jax.distributed job on THIS machine (the supported
# no-cluster CI topology of repro.runtime.distributed):
#
#   scripts/launch_multihost.sh [-n N] [-d M] [-t SECONDS] [-- CMD...]
#
#   -n N        processes (default 2)
#   -d M        forced host devices per process (default 2; each child
#               gets XLA_FLAGS=--xla_force_host_platform_device_count=M,
#               so the job spans N*M global devices)
#   -t SECONDS  hard per-process timeout (default 900)
#   CMD...      the per-process command (default:
#               python -m repro.launch.multihost)
#
# Every child is launched with the runtime.distributed env contract —
# the SAME three variables a real cluster scheduler must export on every
# host, where CMD runs once per node and no devices are forced:
#
#   COORDINATOR_ADDRESS=<host:port>   here: 127.0.0.1:<fresh free port>
#   NUM_PROCESSES=<N>                 identical on every process
#   PROCESS_ID=<i>                    distinct, 0..N-1 (0 = coordinator)
#   DIST_INIT_TIMEOUT=<seconds>       optional connect timeout
#
# Process 0's output streams to stdout; the others log to a temp dir and
# are dumped only on failure.  The first failing process kills the
# stragglers (a dead peer leaves the rest blocked in a collective), and
# the per-process `timeout` is a hard cap — a hung barrier cannot
# outlive it.
#
# Examples:
#   scripts/launch_multihost.sh                      # 2x2 training demo
#   scripts/launch_multihost.sh -n 2 -d 4 -- \
#       python -m repro.launch.multihost --mode naive --backend constraint
#   scripts/launch_multihost.sh -n 2 -d 2 -t 600 -- \
#       python -m benchmarks._dist_gnn --multihost --modes decoupled \
#           --model gcn --n 256 --feat-dim 16 --classes 4 --hidden 8 \
#           --layers 2 --chunks 2 --epochs 1 --assert-ledger \
#           --tag-prefix mh_                    # ci.sh's multihost smoke
set -euo pipefail
cd "$(dirname "$0")/.."

N=2
DEVICES=2
TIMEOUT=900
while getopts "n:d:t:" opt; do
    case "$opt" in
        n) N="$OPTARG" ;;
        d) DEVICES="$OPTARG" ;;
        t) TIMEOUT="$OPTARG" ;;
        *) echo "usage: $0 [-n N] [-d M] [-t SECONDS] [-- CMD...]" >&2
           exit 2 ;;
    esac
done
shift $((OPTIND - 1))
[[ "${1:-}" == "--" ]] && shift
if [[ $# -eq 0 ]]; then
    set -- python -m repro.launch.multihost
fi

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
PORT=$(python - <<'EOF'
import socket
s = socket.socket()
s.bind(("127.0.0.1", 0))
print(s.getsockname()[1])
s.close()
EOF
)

LOGDIR=$(mktemp -d)
trap 'rm -rf "$LOGDIR"' EXIT

pids=()
for ((i = 0; i < N; i++)); do
    if [[ $i -eq 0 ]]; then
        out=/dev/stdout
    else
        out="$LOGDIR/proc$i.log"
    fi
    COORDINATOR_ADDRESS="127.0.0.1:$PORT" NUM_PROCESSES="$N" \
        PROCESS_ID="$i" \
        XLA_FLAGS="--xla_force_host_platform_device_count=$DEVICES" \
        timeout --signal=TERM --kill-after=10 "$TIMEOUT" \
        "$@" > "$out" 2>&1 &
    pids+=($!)
done

fail=0
for ((i = 0; i < N; i++)); do
    # first failure kills the stragglers; remaining waits then return fast
    if ! wait -n; then
        fail=1
        kill "${pids[@]}" 2>/dev/null || true
    fi
done

if [[ $fail -ne 0 ]]; then
    echo "launch_multihost: FAILED (N=$N, devices=$DEVICES)" >&2
    for ((i = 1; i < N; i++)); do
        echo "--- process $i log ---" >&2
        cat "$LOGDIR/proc$i.log" >&2 || true
    done
    exit 1
fi
