from . import transformer, blocks  # noqa: F401
