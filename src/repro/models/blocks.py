"""Per-layer blocks and layer grouping for the unified decoder.

A config's layers are grouped into repeating *units* so the whole stack
lowers as a few ``lax.scan``s (small HLO even at 54 layers):

  dense archs     → unit ("dense",) × L           (or ("local","global"))
  deepseek        → 1 unscanned dense layer + unit ("moe",) × 26
  mamba2          → unit ("mamba",) × 48
  zamba2          → unit ("mamba",)*5 + ("shared_attn",) × 9 groups, the
                    shared_attn params weight-tied across groups
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..nn import attention as attn
from ..nn import layers as nl
from ..nn import moe as moe_lib
from ..nn import ssm as ssm_lib
from ..nn.attention import Sharder, no_shard
from ..nn.param import param


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    unit: tuple[str, ...]   # block kinds within one scan step
    repeats: int            # scan length


def layer_groups(cfg: ArchConfig) -> list[GroupSpec]:
    kinds = cfg.layer_kinds()
    groups: list[GroupSpec] = []
    i = 0
    if cfg.moe and cfg.first_dense_layers:
        groups.append(GroupSpec(("dense",) * cfg.first_dense_layers, 1))
        i = cfg.first_dense_layers
    rest = kinds[i:]
    if not rest:
        return groups
    # find the shortest repeating unit of the remaining pattern
    for unit_len in range(1, len(rest) + 1):
        if len(rest) % unit_len:
            continue
        unit = tuple(rest[:unit_len])
        if all(tuple(rest[j:j + unit_len]) == unit
               for j in range(0, len(rest), unit_len)):
            groups.append(GroupSpec(unit, len(rest) // unit_len))
            return groups
    groups.append(GroupSpec(tuple(rest), 1))
    return groups


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def init_block(key, cfg: ArchConfig, kind: str, dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    if kind == "mamba":
        return {"norm": nl.init_rms_norm(cfg.d_model),
                "mixer": ssm_lib.init_mamba2(k1, cfg, dtype)}
    p = {
        "attn_norm": nl.init_rms_norm(cfg.d_model, plus_one=cfg.post_norm),
        "attn": attn.init_attention(k1, cfg, dtype),
        "mlp_norm": nl.init_rms_norm(cfg.d_model, plus_one=cfg.post_norm),
    }
    if cfg.post_norm:   # gemma2: extra post-block norms
        p["attn_post_norm"] = nl.init_rms_norm(cfg.d_model, plus_one=True)
        p["mlp_post_norm"] = nl.init_rms_norm(cfg.d_model, plus_one=True)
    if kind == "moe":
        p["moe"] = moe_lib.init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = nl.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def _norm(p_leaf, x, cfg: ArchConfig):
    return nl.rms_norm(x, p_leaf.astype(jnp.float32), cfg.norm_eps,
                       plus_one=cfg.post_norm)


def apply_block(p: dict, cfg: ArchConfig, kind: str, x, positions, *,
                shard: Sharder = no_shard):
    """Full-sequence block application.  Returns (x, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind == "mamba":
        h = _norm(p["norm"], x, cfg)
        return x + ssm_lib.mamba2_forward(p["mixer"], cfg, h, shard=shard), \
            aux

    h = _norm(p["attn_norm"], x, cfg)
    window = cfg.sliding_window if kind == "local" else None
    if cfg.use_mla:
        a = attn.mla_attention(p["attn"], cfg, h, positions, shard=shard)
    else:
        a = attn.gqa_attention(p["attn"], cfg, h, positions, window=window,
                               shard=shard)
    if cfg.post_norm:
        a = _norm(p["attn_post_norm"], a, cfg)
    x = x + a

    h = _norm(p["mlp_norm"], x, cfg)
    h = shard(h, "act_tokens")
    if kind == "moe":
        m, aux = moe_lib.moe_apply(p["moe"], cfg, h, shard=shard)
    else:
        m = nl.mlp(p["mlp"], h, cfg.act)
    if cfg.post_norm:
        m = _norm(p["mlp_post_norm"], m, cfg)
    return x + m, aux


def apply_block_prefill(p: dict, cfg: ArchConfig, kind: str, x, positions,
                        max_len: int, *, shard: Sharder = no_shard,
                        long_context: bool = False):
    """Full-sequence block that also materializes the decode cache.
    Returns (x, cache)."""
    if kind == "mamba":
        h = _norm(p["norm"], x, cfg)
        y, cache = ssm_lib.mamba2_prefill(p["mixer"], cfg, h, shard=shard)
        return x + y, cache

    h = _norm(p["attn_norm"], x, cfg)
    window = cfg.sliding_window if kind == "local" else None
    if cfg.use_mla:
        a, cache = attn.mla_prefill(p["attn"], cfg, h, positions, max_len,
                                    shard=shard)
    else:
        a, cache = attn.gqa_prefill(p["attn"], cfg, h, positions, max_len,
                                    window=window, shard=shard,
                                    long_context=long_context)
    if cfg.post_norm:
        a = _norm(p["attn_post_norm"], a, cfg)
    x = x + a

    h = _norm(p["mlp_norm"], x, cfg)
    h = shard(h, "act_tokens")
    if kind == "moe":
        m, _ = moe_lib.moe_apply(p["moe"], cfg, h, shard=shard)
    else:
        m = nl.mlp(p["mlp"], h, cfg.act)
    if cfg.post_norm:
        m = _norm(p["mlp_post_norm"], m, cfg)
    return x + m, cache


# ---------------------------------------------------------------------------
# Decode-step application (single token, per-layer cache)
# ---------------------------------------------------------------------------

def init_block_cache(cfg: ArchConfig, kind: str, batch: int, max_len: int,
                     dtype=jnp.float32, long_context: bool = False):
    """Cache pytree for one block.  ``long_context`` puts gemma2 local
    layers on the O(window) ring buffer."""
    if kind == "mamba":
        return ssm_lib.init_ssm_cache(cfg, batch, dtype)
    if cfg.use_mla:
        return attn.init_mla_cache(cfg, batch, max_len, dtype)
    if kind == "local" and long_context and cfg.sliding_window:
        return attn.init_window_cache(cfg, batch, dtype)
    return attn.init_kv_cache(cfg, batch, max_len, dtype)


def apply_block_decode(p: dict, cfg: ArchConfig, kind: str, x, cache, *,
                       shard: Sharder = no_shard):
    """One-token step.  Returns (x, new_cache)."""
    if kind == "mamba":
        h = _norm(p["norm"], x, cfg)
        y, cache = ssm_lib.mamba2_decode(p["mixer"], cfg, h, cache,
                                         shard=shard)
        return x + y, cache

    h = _norm(p["attn_norm"], x, cfg)
    if cfg.use_mla:
        a, cache = attn.mla_decode(p["attn"], cfg, h, cache, shard=shard)
    elif isinstance(cache, attn.WindowKVCache):
        a, cache = attn.gqa_decode_windowed(p["attn"], cfg, h, cache,
                                            shard=shard)
    else:
        a, cache = attn.gqa_decode(p["attn"], cfg, h, cache, shard=shard)
    if cfg.post_norm:
        a = _norm(p["attn_post_norm"], a, cfg)
    x = x + a

    h = _norm(p["mlp_norm"], x, cfg)
    if kind == "moe":
        m, _ = moe_lib.moe_apply(p["moe"], cfg, h, dropless=True,
                                 shard=shard)
    else:
        m = nl.mlp(p["mlp"], h, cfg.act)
    if cfg.post_norm:
        m = _norm(p["mlp_post_norm"], m, cfg)
    return x + m, cache
