"""Unified decoder-only transformer covering all ten assigned architectures.

Layer stacks lower as ``lax.scan`` over stacked per-group params (MaxText
style) so even the 8B configs produce compact HLO for the 512-device
dry-run.  The same parameter pytree serves ``forward`` (train/prefill) and
``decode_step`` (one token + caches).

Multimodal (vlm/audio) configs consume *precomputed* frontend embeddings —
the explicit stub carve-out — interleaved before the token embeddings by
:func:`assemble_inputs`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..nn import layers as nl
from ..nn.attention import Sharder, no_shard
from ..nn.param import ParamLeaf, param, split_params
from . import blocks as B


def _stack_params(trees: list) -> Any:
    """Stack a list of ParamLeaf trees along a new leading 'layers' axis."""
    def stack(*leaves: ParamLeaf) -> ParamLeaf:
        return ParamLeaf(jnp.stack([l.value for l in leaves]),
                         ("layers",) + leaves[0].names)
    return jax.tree.map(stack, *trees, is_leaf=lambda x: isinstance(
        x, ParamLeaf))


def init_transformer(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    keys = jax.random.split(key, cfg.num_layers + 8)
    groups = B.layer_groups(cfg)
    params: dict = {
        "embed": nl.init_embedding(keys[-1], cfg.padded_vocab, cfg.d_model,
                                   dtype),
        "final_norm": nl.init_rms_norm(cfg.d_model, plus_one=cfg.post_norm),
    }
    if not cfg.tie_embeddings:
        params["head"] = param(keys[-2], (cfg.d_model, cfg.padded_vocab),
                               ("embed", "vocab"), dtype=dtype)
    if cfg.modality:
        params["mm_proj"] = nl.init_dense(keys[-3], cfg.d_model,
                                          cfg.d_model, ("embed", None),
                                          dtype=dtype)
    if cfg.hybrid_attn_every:
        params["shared_block"] = B.init_block(keys[-4], cfg, "shared_attn",
                                              dtype)
    ki = 0
    gparams = []
    for g in groups:
        reps = []
        for r in range(g.repeats):
            unit = []
            for kind in g.unit:
                if kind == "shared_attn":
                    unit.append({})          # weight-tied → placeholder
                else:
                    unit.append(B.init_block(keys[ki % len(keys)], cfg,
                                             kind, dtype))
                    ki += 1
            reps.append(unit)
        if g.repeats == 1:
            gparams.append(reps[0])
        else:
            gparams.append([_stack_params([reps[r][u]
                                           for r in range(g.repeats)])
                            if g.unit[u] != "shared_attn" else {}
                            for u in range(len(g.unit))])
    params["groups"] = gparams
    return params


# ---------------------------------------------------------------------------
# Input assembly (multimodal stub carve-out)
# ---------------------------------------------------------------------------

def assemble_inputs(params, cfg: ArchConfig, tokens: jax.Array,
                    prefix_embeddings: Optional[jax.Array] = None):
    """tokens (B, S_t) [+ prefix (B, P, D)] → embeddings (B, S, D)."""
    table = params["embed"].value if isinstance(params["embed"], ParamLeaf) \
        else params["embed"]
    x = nl.embed(table.astype(jnp.float32), tokens)
    if cfg.modality:
        assert prefix_embeddings is not None, \
            f"{cfg.name} needs frontend embeddings"
        pre = nl.dense({k: v.value if isinstance(v, ParamLeaf) else v
                        for k, v in params["mm_proj"].items()},
                       prefix_embeddings.astype(jnp.float32))
        x = jnp.concatenate([pre, x], axis=1)
    x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    return x


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ArchConfig, tokens: jax.Array,
            prefix_embeddings: Optional[jax.Array] = None, *,
            shard: Sharder = no_shard, remat: bool = True,
            return_final_hidden: bool = False):
    """Returns (logits (B,S,V), aux_loss)."""
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = assemble_inputs(params, cfg, tokens, prefix_embeddings)
    x = x.astype(compute_dtype)
    x = shard(x, "act_tokens")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    aux_total = jnp.zeros((), jnp.float32)
    groups = B.layer_groups(cfg)
    shared_cast = (_cast_compute(_values(params["shared_block"]),
                                 compute_dtype)
                   if cfg.hybrid_attn_every else None)

    for gspec, gp in zip(groups, params["groups"]):
        def unit_step(x, unit_params, gspec=gspec):
            aux_sum = jnp.zeros((), jnp.float32)
            for kind, p_blk in zip(gspec.unit, unit_params):
                if kind == "shared_attn":
                    p_blk = shared_cast
                x, aux = B.apply_block(p_blk, cfg, kind, x, positions,
                                       shard=shard)
                aux_sum = aux_sum + aux
            return x, aux_sum

        if gspec.repeats == 1:
            x, aux = unit_step(x, [_cast_compute(_values(p), compute_dtype)
                                   for p in gp])
            aux_total += aux
        else:
            stacked = [_cast_compute(_values(p), compute_dtype) if p else {}
                       for p in gp]

            def scan_body(x, unit_params):
                x, aux = unit_step(x, unit_params)
                return x, aux
            if remat == "dots":   # §Perf: save matmul outputs, skip their
                body = jax.checkpoint(  # recompute in the backward pass
                    scan_body, policy=jax.checkpoint_policies
                    .dots_with_no_batch_dims_saveable)
            elif remat:
                body = jax.checkpoint(scan_body)
            else:
                body = scan_body
            x, auxs = jax.lax.scan(body, x, stacked)
            aux_total += auxs.sum()

    x = nl.rms_norm(x, _value(params["final_norm"]).astype(jnp.float32),
                    cfg.norm_eps, plus_one=cfg.post_norm)
    if return_final_hidden:
        return x, aux_total
    logits = unembed(params, cfg, x, shard=shard)
    return logits, aux_total


def unembed(params, cfg: ArchConfig, x, *, shard: Sharder = no_shard):
    if cfg.tie_embeddings:
        w = _value(params["embed"]).astype(x.dtype)
        logits = jnp.einsum("bsd,vd->bsv", x, w)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x,
                            _value(params["head"]).astype(x.dtype))
    logits = nl.softcap(logits.astype(jnp.float32), cfg.logit_softcap)
    if cfg.padded_vocab != cfg.vocab_size:
        # pad ids can never be predicted or contribute to the lse
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return shard(logits, "act_vocab")


def _value(x):
    return x.value if isinstance(x, ParamLeaf) else x


def _values(tree):
    return jax.tree.map(_value, tree,
                        is_leaf=lambda x: isinstance(x, ParamLeaf))


_KEEP_F32 = ("router", "norm")   # routing logits + norm scales stay fp32


def _cast_compute(tree, dtype):
    """Pre-cast weights to the compute dtype OUTSIDE the layer scan.

    §Perf round 2: with fp32 master weights, leaving the cast to the
    per-use ``.astype`` inside the scan body re-converts every layer's
    weights on every step AND again inside the remat re-forward — ~2 s of
    the census memory term on minitron train_4k.  One hoisted cast of the
    stacked params removes the in-loop converts (the in-block ``.astype``
    becomes a no-op).  Router and norm scales are kept fp32."""
    if dtype == jnp.float32:
        return tree

    def one(path, x):
        if not hasattr(x, "dtype") or x.dtype != jnp.float32 or x.ndim < 2:
            return x               # keep small 1-D params (biases, decay
        for entry in reversed(path):  # rates) and anything non-fp32
            key = getattr(entry, "key", None)
            if isinstance(key, str):
                if any(t in key for t in _KEEP_F32):
                    return x
                break
        return x.astype(dtype)
    return jax.tree_util.tree_map_with_path(one, tree)


def prefill(params, cfg: ArchConfig, tokens: jax.Array,
            prefix_embeddings: Optional[jax.Array] = None, *,
            max_len: int, shard: Sharder = no_shard,
            long_context: bool = False, last_only: bool = False):
    """Run the prompt through the stack, materializing decode caches.
    Returns (logits (B,S,V), caches).  ``last_only=True`` unembeds only the
    final position — (B,1,V) — which is all decode needs; skips the
    (B,S,V) logit buffer entirely (§Perf HC1 iter 2)."""
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = assemble_inputs(params, cfg, tokens, prefix_embeddings)
    x = x.astype(compute_dtype)
    x = shard(x, "act_tokens")
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    groups = B.layer_groups(cfg)
    caches = []
    shared_cast = (_cast_compute(_values(params["shared_block"]),
                                 compute_dtype)
                   if cfg.hybrid_attn_every else None)

    for gspec, gp in zip(groups, params["groups"]):
        def unit_step(x, unit_params, gspec=gspec):
            unit_caches = []
            for kind, p_blk in zip(gspec.unit, unit_params):
                if kind == "shared_attn":
                    p_blk = shared_cast
                x, c = B.apply_block_prefill(
                    p_blk, cfg, kind, x, positions, max_len, shard=shard,
                    long_context=long_context)
                unit_caches.append(c)
            return x, unit_caches

        if gspec.repeats == 1:
            x, uc = unit_step(x, [_cast_compute(_values(p), compute_dtype)
                                  for p in gp])
            caches.append(uc)
        else:
            stacked = [_cast_compute(_values(p), compute_dtype) if p else {}
                       for p in gp]

            def scan_body(x, unit_params):
                return unit_step(x, unit_params)
            x, uc = jax.lax.scan(scan_body, x, stacked)
            caches.append(uc)

    x = nl.rms_norm(x, _value(params["final_norm"]).astype(jnp.float32),
                    cfg.norm_eps, plus_one=cfg.post_norm)
    if last_only:
        x = x[:, -1:]
    logits = unembed(params, cfg, x, shard=shard)
    return logits, caches


# ---------------------------------------------------------------------------
# Decode (one token, stacked caches)
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, max_len: int,
                dtype=jnp.float32, long_context: bool = False):
    """Cache pytree mirroring the group structure."""
    groups = B.layer_groups(cfg)
    caches = []
    for g in groups:
        unit_caches = []
        for kind in g.unit:
            one = B.init_block_cache(cfg, kind, batch, max_len, dtype,
                                     long_context)
            if g.repeats == 1:
                unit_caches.append(one)
            else:
                unit_caches.append(jax.tree.map(
                    lambda l: jnp.broadcast_to(
                        l, (g.repeats,) + l.shape).copy(), one))
        caches.append(unit_caches)
    return caches


def decode_step(params, cfg: ArchConfig, token: jax.Array, caches, *,
                shard: Sharder = no_shard):
    """token (B, 1) int32 → (logits (B, 1, V), new caches)."""
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    table = _value(params["embed"])
    x = nl.embed(table.astype(jnp.float32), token)
    x = (x * jnp.sqrt(float(cfg.d_model))).astype(compute_dtype)
    groups = B.layer_groups(cfg)
    new_caches = []
    for gspec, gp, gc in zip(groups, params["groups"], caches):
        def unit_step(x, unit_params, unit_caches, gspec=gspec):
            outs = []
            for kind, p_blk, c_blk in zip(gspec.unit, unit_params,
                                          unit_caches):
                if kind == "shared_attn":
                    p_blk = _values(params["shared_block"])
                x, c_new = B.apply_block_decode(p_blk, cfg, kind, x, c_blk,
                                                shard=shard)
                outs.append(c_new)
            return x, outs

        if gspec.repeats == 1:
            x, c_new = unit_step(x, [_values(p) for p in gp], gc)
            new_caches.append(c_new)
        else:
            stacked_p = [_values(p) if p else {} for p in gp]

            def scan_body(x, pc):
                up, uc = pc
                x, uc_new = unit_step(x, up, uc)
                return x, uc_new
            x, gc_new = jax.lax.scan(scan_body, x, (stacked_p, gc))
            new_caches.append(gc_new)

    x = nl.rms_norm(x, _value(params["final_norm"]).astype(jnp.float32),
                    cfg.norm_eps, plus_one=cfg.post_norm)
    logits = unembed(params, cfg, x, shard=shard)
    return logits, new_caches


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def lm_loss(logits: jax.Array, targets: jax.Array,
            mask: Optional[jax.Array] = None) -> jax.Array:
    """Next-token cross-entropy, written with vocab-dim reductions only so
    vocab-sharded logits never need gathering."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    true_logit = jnp.take_along_axis(logits, targets[..., None],
                                     axis=-1)[..., 0]
    nll = lse - true_logit
    if mask is None:
        return nll.mean()
    mask = mask.astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
