"""Synthetic graph generators.

No dataset downloads are available offline, so we synthesize graphs with the
two properties the paper's evaluation leans on:

* **power-law degree distribution** (Friendster/Reddit-like skew) — this is
  what breaks chunk/METIS data parallelism's load balance (paper Fig. 3);
* **planted community structure** — labels correlated with topology and
  features so full-graph training has a real learning signal for the
  accuracy-parity experiment (paper Fig. 16 / §5.7).

Generators return (Graph-ready COO, features, labels, splits).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .format import Graph, build_graph


@dataclasses.dataclass(frozen=True)
class GraphData:
    graph: Graph
    features: np.ndarray    # (n, d) float32
    labels: np.ndarray      # (n,) int32
    train_mask: np.ndarray  # (n,) bool
    val_mask: np.ndarray    # (n,) bool
    test_mask: np.ndarray   # (n,) bool
    num_classes: int

    # heterogeneous-graph extension (paper §5.8): edge type per edge, or None
    edge_types: np.ndarray | None = None
    num_edge_types: int = 1


def _splits(n: int, rng: np.random.Generator,
            train: float = 0.65, val: float = 0.25):
    """Paper's split for graphs without ground truth: 65/25/10."""
    perm = rng.permutation(n)
    n_tr, n_va = int(train * n), int(val * n)
    tr = np.zeros(n, bool); va = np.zeros(n, bool); te = np.zeros(n, bool)
    tr[perm[:n_tr]] = True
    va[perm[n_tr:n_tr + n_va]] = True
    te[perm[n_tr + n_va:]] = True
    return tr, va, te


def sbm_power_law(n: int = 4096, num_classes: int = 8, feat_dim: int = 64,
                  avg_degree: int = 16, p_in: float = 0.85,
                  seed: int = 0, normalization: str = "sym") -> GraphData:
    """Stochastic block model with power-law degree propensities.

    Each vertex gets a community c(v) and a Zipf-ish propensity θ_v; an edge
    endpoint pair (u, v) is sampled ∝ θ_u·θ_v, intra-community with
    probability ``p_in``.  Features are a noisy community centroid so an MLP
    alone reaches moderate accuracy and aggregation adds more — exactly the
    paper's Assumption 1 regime.
    """
    rng = np.random.default_rng(seed)
    comm = rng.integers(0, num_classes, size=n).astype(np.int32)
    # Zipf propensities → power-law degrees
    theta = (1.0 / np.arange(1, n + 1) ** 0.75)
    theta = theta[rng.permutation(n)]
    theta /= theta.sum()

    e_target = n * avg_degree
    src = rng.choice(n, size=e_target, p=theta)
    # choose dst: with prob p_in from same community, else anywhere
    same = rng.random(e_target) < p_in
    # default: topology-propensity destination anywhere; overwrite the
    # intra-community edges per community below.
    dst = rng.choice(n, size=e_target, p=theta).astype(np.int64)
    by_comm = [np.where(comm == c)[0] for c in range(num_classes)]
    pw = [theta[idx] / theta[idx].sum() if len(idx) else None
          for idx in by_comm]
    for c in range(num_classes):
        sel = same & (comm[src] == c)
        if sel.sum() and len(by_comm[c]):
            dst[sel] = rng.choice(by_comm[c], size=sel.sum(), p=pw[c])
    keep = src != dst
    src, dst = src[keep], dst[keep]

    centroids = rng.normal(size=(num_classes, feat_dim)).astype(np.float32)
    feats = centroids[comm] + 1.2 * rng.normal(
        size=(n, feat_dim)).astype(np.float32)

    g = build_graph(src.astype(np.int32), dst.astype(np.int32), n,
                    normalization=normalization)
    tr, va, te = _splits(n, rng)
    return GraphData(graph=g, features=feats, labels=comm,
                     train_mask=tr, val_mask=va, test_mask=te,
                     num_classes=num_classes)


def barabasi_albert(n: int = 4096, m: int = 8, feat_dim: int = 64,
                    num_classes: int = 8, seed: int = 0,
                    normalization: str = "sym") -> GraphData:
    """Preferential attachment — the heavy-tail topology for the
    load-imbalance benchmarks (paper Figs. 3, 10, 11's Friendster case)."""
    rng = np.random.default_rng(seed)
    src_l, dst_l = [], []
    targets = list(range(m))
    repeated: list[int] = list(range(m))
    for v in range(m, n):
        chosen = rng.choice(repeated, size=m, replace=False) \
            if len(set(repeated)) >= m else rng.integers(0, v, size=m)
        for u in np.unique(chosen):
            src_l.append(v); dst_l.append(int(u))
            repeated.extend([v, int(u)])
    src = np.asarray(src_l + dst_l, dtype=np.int32)   # symmetrize
    dst = np.asarray(dst_l + src_l, dtype=np.int32)

    comm = rng.integers(0, num_classes, size=n).astype(np.int32)
    centroids = rng.normal(size=(num_classes, feat_dim)).astype(np.float32)
    feats = centroids[comm] + 1.5 * rng.normal(
        size=(n, feat_dim)).astype(np.float32)
    g = build_graph(src, dst, n, normalization=normalization)
    tr, va, te = _splits(n, rng)
    return GraphData(graph=g, features=feats, labels=comm,
                     train_mask=tr, val_mask=va, test_mask=te,
                     num_classes=num_classes)


def heterogeneous_sbm(n: int = 2048, num_classes: int = 6,
                      num_edge_types: int = 4, feat_dim: int = 64,
                      avg_degree: int = 12, seed: int = 0) -> GraphData:
    """Heterogeneous graph (typed edges) for the R-GCN experiment (§5.8)."""
    base = sbm_power_law(n=n, num_classes=num_classes, feat_dim=feat_dim,
                         avg_degree=avg_degree, seed=seed,
                         normalization="mean")
    rng = np.random.default_rng(seed + 1)
    etypes = rng.integers(0, num_edge_types,
                          size=base.graph.e).astype(np.int32)
    return dataclasses.replace(base, edge_types=etypes,
                               num_edge_types=num_edge_types)


REGISTRY = {
    "sbm": sbm_power_law,
    "ba": barabasi_albert,
    "hetero": heterogeneous_sbm,
}


def reddit_like(scale: float = 1.0, seed: int = 0) -> GraphData:
    """Scaled-down Reddit stand-in (0.23M vertices / 114M edges full scale;
    feature dim 602, 41 classes in the paper's Table 1)."""
    n = max(1024, int(23000 * scale))
    return sbm_power_law(n=n, num_classes=41, feat_dim=602,
                         avg_degree=max(8, int(64 * scale)), seed=seed)
