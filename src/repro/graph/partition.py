"""Graph partitioners + workload statistics.

NeutronTP itself never partitions the graph across workers — that is the
point.  These partitioners exist for (a) the data-parallel *baseline* the
paper ablates against (chunk partitioning, §5.4's "baseline"), (b) the
load-balance analysis figures (Figs. 3 & 10), and (c) the DepComm halo
exchange plan of the DP baseline.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .format import Graph


@dataclasses.dataclass(frozen=True)
class Partition:
    """Assignment of destination vertices to ``k`` workers."""

    k: int
    owner: np.ndarray        # (n,) int32 worker id per vertex
    # contiguous-chunk partitions also expose boundaries:
    bounds: np.ndarray | None = None  # (k+1,) or None for non-contiguous


def chunk_partition(g: Graph, k: int, balance: str = "vertex") -> Partition:
    """Contiguous-ID chunks (NeuGraph/ROC/NeutronStar style).

    ``balance="vertex"`` equalizes vertices per worker; ``balance="edge"``
    equalizes in-edges (a slightly fairer variant we use for comparison).
    """
    n = g.n
    if balance == "vertex":
        bounds = np.linspace(0, n, k + 1).astype(np.int64)
    elif balance == "edge":
        target = g.e / k
        csum = g.indptr[1:]  # in-edges up to vertex i inclusive
        bounds = np.searchsorted(csum, target * np.arange(1, k))
        bounds = np.concatenate([[0], bounds, [n]]).astype(np.int64)
    else:
        raise ValueError(balance)
    owner = np.zeros(n, dtype=np.int32)
    for i in range(k):
        owner[bounds[i]:bounds[i + 1]] = i
    return Partition(k=k, owner=owner, bounds=bounds)


def hash_partition(g: Graph, k: int, seed: int = 0) -> Partition:
    """Random/hash partition — balances vertices, shreds locality (the
    worst-case for DepComm communication; a METIS stand-in is below)."""
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, k, size=g.n).astype(np.int32)
    return Partition(k=k, owner=owner)


def greedy_edge_cut_partition(g: Graph, k: int, passes: int = 2) -> Partition:
    """Lightweight METIS stand-in: LDG-style greedy streaming partitioning
    minimizing edge cut under a capacity constraint.  Reproduces the paper's
    observation that edge-cut minimizers still leave compute/comm imbalance.
    """
    n = g.n
    cap = 1.05 * n / k
    owner = np.full(n, -1, dtype=np.int32)
    sizes = np.zeros(k, dtype=np.int64)
    order = np.argsort(-np.diff(g.indptr))  # high in-degree first
    for _ in range(passes):
        for v in order:
            nbrs = g.src[g.indptr[v]:g.indptr[v + 1]]
            scores = np.zeros(k)
            placed = owner[nbrs]
            for p in placed[placed >= 0]:
                scores[p] += 1
            scores *= np.maximum(0.0, 1.0 - sizes / cap)
            best = int(np.argmax(scores)) if scores.max() > 0 else \
                int(np.argmin(sizes))
            if owner[v] >= 0:
                sizes[owner[v]] -= 1
            owner[v] = best
            sizes[best] += 1
    return Partition(k=k, owner=owner)


# ---------------------------------------------------------------------------
# Workload statistics (paper Figs. 3 & 10)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkloadStats:
    vertices: np.ndarray        # (k,) local vertex count
    edges: np.ndarray           # (k,) in-edges of local dst (compute load)
    remote_srcs: np.ndarray     # (k,) distinct remote src vertices (comm in)
    compute_imbalance: float    # max/mean of edges
    comm_imbalance: float       # max/mean of remote_srcs

    def as_dict(self):
        return {
            "vertices": self.vertices.tolist(),
            "edges": self.edges.tolist(),
            "remote_srcs": self.remote_srcs.tolist(),
            "compute_imbalance": float(self.compute_imbalance),
            "comm_imbalance": float(self.comm_imbalance),
        }


def workload_stats(g: Graph, part: Partition) -> WorkloadStats:
    k = part.k
    vertices = np.bincount(part.owner, minlength=k).astype(np.int64)
    edges = np.zeros(k, dtype=np.int64)
    remote = np.zeros(k, dtype=np.int64)
    dst_owner = part.owner[g.dst]
    src_owner = part.owner[g.src]
    edges = np.bincount(dst_owner, minlength=k).astype(np.int64)
    cross = dst_owner != src_owner
    for w in range(k):
        sel = cross & (dst_owner == w)
        remote[w] = len(np.unique(g.src[sel]))
    mean_e = max(edges.mean(), 1e-9)
    mean_r = max(remote.mean(), 1e-9)
    return WorkloadStats(
        vertices=vertices, edges=edges, remote_srcs=remote,
        compute_imbalance=float(edges.max() / mean_e),
        comm_imbalance=float(remote.max() / mean_r))


def tensor_parallel_stats(g: Graph, k: int, d: int) -> WorkloadStats:
    """NeutronTP's workload: every worker has ALL edges × (d/k) dims and a
    V/k share of vertex comm — perfectly balanced by construction."""
    vertices = np.full(k, g.n // k, dtype=np.int64)
    edges = np.full(k, g.e, dtype=np.int64)  # on a d/k slice
    comm = np.full(k, g.n // k, dtype=np.int64)
    return WorkloadStats(vertices=vertices, edges=edges, remote_srcs=comm,
                         compute_imbalance=1.0, comm_imbalance=1.0)


# ---------------------------------------------------------------------------
# Halo exchange plan for the DP (DepComm) baseline
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HaloPlan:
    """Static all-to-all plan: which local rows each worker sends to every
    other worker, and where received rows land in the local halo buffer.

    All per-pair sets are padded to the global max count ``m`` so the
    exchange is a single rectangular ``all_to_all``:

      send_idx[i, j, :]  — local vertex ids worker i sends to worker j
                           (global ids; pad = -1 → zeros row)
      recv_pos[i, j, :]  — slot in worker i's halo buffer for rows received
                           from j (pad = halo_size → dropped)
    """

    k: int
    m: int                    # padded per-pair row count
    halo_size: int            # max distinct remote srcs over workers (padded)
    send_idx: np.ndarray      # (k, k, m) int32 global vertex ids
    recv_pos: np.ndarray      # (k, k, m) int32
    # remap of local aggregation: for each worker, its in-edge list with
    # src rewritten to [0, n_local + halo_size) local coordinates
    local_src: list           # k × (e_i,) int32
    local_dst: list           # k × (e_i,) int32 (0-based within worker)
    local_w: list             # k × (e_i,) float32
    n_local: np.ndarray       # (k,) vertices per worker


def halo_plan(g: Graph, part: Partition) -> HaloPlan:
    assert part.bounds is not None, "DP baseline uses contiguous chunks"
    k = part.k
    bounds = part.bounds
    sends: dict[tuple[int, int], np.ndarray] = {}
    halos: list[np.ndarray] = []
    local_src, local_dst, local_w = [], [], []
    n_local = np.diff(bounds).astype(np.int64)

    for i in range(k):
        lo, hi = bounds[i], bounds[i + 1]
        e_lo, e_hi = g.indptr[lo], g.indptr[hi]
        s, d, w = g.src[e_lo:e_hi], g.dst[e_lo:e_hi], g.weight[e_lo:e_hi]
        remote_mask = (s < lo) | (s >= hi)
        halo_vs = np.unique(s[remote_mask])
        halos.append(halo_vs)
        # rewrite src: local → [0, n_i), halo → n_i + rank-in-halo
        s_new = np.where(remote_mask,
                         n_local[i] + np.searchsorted(halo_vs, s),
                         s - lo).astype(np.int32)
        local_src.append(s_new)
        local_dst.append((d - lo).astype(np.int32))
        local_w.append(w.astype(np.float32))
        owner_of = part.owner[halo_vs]
        for j in range(k):
            sends[(j, i)] = halo_vs[owner_of == j]  # j sends these to i

    m = max(1, max(len(v) for v in sends.values()))
    halo_size = max(1, max(len(h) for h in halos))
    send_idx = np.full((k, k, m), -1, dtype=np.int32)
    recv_pos = np.full((k, k, m), halo_size, dtype=np.int32)
    for i in range(k):
        halo_rank = {int(v): r for r, v in enumerate(halos[i])}
        for j in range(k):
            rows = sends[(j, i)]
            send_idx[j, i, : len(rows)] = rows
            recv_pos[i, j, : len(rows)] = [halo_rank[int(v)] for v in rows]
    return HaloPlan(k=k, m=m, halo_size=halo_size,
                    send_idx=send_idx, recv_pos=recv_pos,
                    local_src=local_src, local_dst=local_dst,
                    local_w=local_w, n_local=n_local)
