from .format import (Graph, ChunkedGraph, BlockSparseGraph, BlockSparsePlan,
                     HostFeatureStore, build_graph, chunk_graph,
                     block_sparse, block_sparse_transpose,
                     rect_block_sparse, stack_plans, chunk_block_sparse,
                     pad_features, require_int32_edge_ids)  # noqa: F401
from .synthetic import (GraphData, sbm_power_law, barabasi_albert,
                        heterogeneous_sbm, reddit_like)  # noqa: F401
from .partition import (Partition, chunk_partition, hash_partition,
                        greedy_edge_cut_partition, workload_stats,
                        tensor_parallel_stats, halo_plan)  # noqa: F401
