"""Graph containers and TPU-friendly sparse formats.

NeutronTP replicates the full graph structure on every worker and shards the
*feature* dimension instead.  The formats here are therefore built for
single-worker full-graph aggregation:

* ``Graph``          — COO sorted by destination + CSR ``indptr`` over in-edges,
                       with GCN symmetric normalization baked into ``weight``.
* ``ChunkedGraph``   — the paper's §4.2 chunk partition: contiguous destination
                       ranges with *all* their in-edges, padded to rectangular
                       arrays so a ``lax.scan`` can stream chunks.
* ``BlockSparseGraph`` — (dst_block × src_block) dense tiles for the Pallas
                       SpMM kernel: TPUs want MXU tiles, not gather/scatter,
                       so aggregation becomes a block-sparse matmul.

Everything is constructed in numpy (host, once) and consumed as jnp arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Full graph, in-edge oriented (COO sorted by dst + CSR indptr)."""

    n: int
    src: np.ndarray       # (E,) int32, sorted by dst
    dst: np.ndarray       # (E,) int32, non-decreasing
    weight: np.ndarray    # (E,) float32 aggregation coefficients
    indptr: np.ndarray    # (n+1,) int64 CSR offsets over dst

    @property
    def e(self) -> int:
        return int(self.src.shape[0])

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int64)

    def dense_adjacency(self) -> np.ndarray:
        """Dense normalized adjacency (test oracle only)."""
        a = np.zeros((self.n, self.n), dtype=np.float32)
        a[self.dst, self.src] += self.weight
        return a


def _sort_by_dst(src: np.ndarray, dst: np.ndarray,
                 weight: np.ndarray | None = None):
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    weight = None if weight is None else weight[order]
    return src, dst, weight


def build_graph(src: np.ndarray, dst: np.ndarray, n: int, *,
                add_self_loops: bool = True,
                normalization: str = "sym") -> Graph:
    """Build a :class:`Graph` with GCN-style normalized edge weights.

    normalization:
      * ``"sym"``  — 1/sqrt(deg_in(v) · deg_out(u))  (GCN, eq. 3)
      * ``"mean"`` — 1/deg_in(v)                      (GraphSAGE mean)
      * ``"none"`` — 1                                 (GIN sum)
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if add_self_loops:
        loop = np.arange(n, dtype=np.int32)
        src = np.concatenate([src, loop])
        dst = np.concatenate([dst, loop])
    # dedupe parallel edges
    key = dst.astype(np.int64) * n + src.astype(np.int64)
    key, uniq_idx = np.unique(key, return_index=True)
    src, dst = src[uniq_idx], dst[uniq_idx]

    src, dst, _ = _sort_by_dst(src, dst)
    deg_in = np.bincount(dst, minlength=n).astype(np.float64)
    deg_out = np.bincount(src, minlength=n).astype(np.float64)
    if normalization == "sym":
        w = 1.0 / np.sqrt(np.maximum(deg_in[dst], 1.0)
                          * np.maximum(deg_out[src], 1.0))
    elif normalization == "mean":
        w = 1.0 / np.maximum(deg_in[dst], 1.0)
    elif normalization == "none":
        w = np.ones_like(src, dtype=np.float64)
    else:
        raise ValueError(f"unknown normalization {normalization!r}")
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(dst, minlength=n), out=indptr[1:])
    return Graph(n=n, src=src, dst=dst,
                 weight=w.astype(np.float32), indptr=indptr)


# ---------------------------------------------------------------------------
# Chunked format (paper §4.2: contiguous dst ranges + all their in-edges)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkedGraph:
    """Rectangular per-chunk edge arrays for ``lax.scan`` streaming.

    Padded edges carry weight 0 and point at dst slot ``chunk_size`` which is
    dropped after segment-sum, so padding is numerically inert.
    """

    n: int
    n_chunks: int
    chunk_size: int            # destinations per chunk (last chunk padded)
    src: np.ndarray            # (n_chunks, max_e) int32, pad=0
    dst_local: np.ndarray      # (n_chunks, max_e) int32 in [0, chunk_size], pad=chunk_size
    weight: np.ndarray         # (n_chunks, max_e) float32, pad=0.0
    edge_id: np.ndarray        # (n_chunks, max_e) int32 id into the flat edge
                               # list (pad=E) — lets per-edge quantities (GAT α)
                               # be re-chunked on device
    # Inter-chunk pipelining (§4.2.2): srcs whose embedding slice is first
    # used by this chunk — the dedup'd per-chunk communication task.
    new_src: np.ndarray        # (n_chunks, max_new) int32, pad=-1
    new_src_count: np.ndarray  # (n_chunks,) int32

    @property
    def max_e(self) -> int:
        return int(self.src.shape[1])


def chunk_graph(g: Graph, n_chunks: int) -> ChunkedGraph:
    n = g.n
    chunk_size = -(-n // n_chunks)
    srcs, dsts, ws, eids, news, new_counts = [], [], [], [], [], []
    seen = np.zeros(n, dtype=bool)
    max_e = 1
    max_new = 1
    for c in range(n_chunks):
        # clamp: with n_chunks ∤ n, ceil-sized chunks can overrun n (e.g.
        # n=6, n_chunks=5 → chunk 4 would start at 8); trailing chunks
        # become empty, which the padded layout already represents.
        lo = min(n, c * chunk_size)
        hi = min(n, (c + 1) * chunk_size)
        e_lo, e_hi = g.indptr[lo], g.indptr[hi]
        s = g.src[e_lo:e_hi]
        d = g.dst[e_lo:e_hi] - lo
        w = g.weight[e_lo:e_hi]
        eid = np.arange(e_lo, e_hi, dtype=np.int64)
        fresh = np.unique(s[~seen[s]]) if s.size else np.empty(0, np.int32)
        seen[fresh] = True
        srcs.append(s); dsts.append(d); ws.append(w); eids.append(eid)
        news.append(fresh)
        new_counts.append(len(fresh))
        max_e = max(max_e, len(s))
        max_new = max(max_new, len(fresh))

    def pad(a, length, value, dtype):
        out = np.full(length, value, dtype=dtype)
        out[: len(a)] = a
        return out

    return ChunkedGraph(
        n=n, n_chunks=n_chunks, chunk_size=chunk_size,
        src=np.stack([pad(s, max_e, 0, np.int32) for s in srcs]),
        dst_local=np.stack(
            [pad(d, max_e, chunk_size, np.int32) for d in dsts]),
        weight=np.stack([pad(w, max_e, 0.0, np.float32) for w in ws]),
        edge_id=np.stack([pad(e, max_e, g.e, np.int32) for e in eids]),
        new_src=np.stack([pad(f, max_new, -1, np.int32) for f in news]),
        new_src_count=np.asarray(new_counts, dtype=np.int32),
    )


# ---------------------------------------------------------------------------
# Block-sparse format for the Pallas SpMM kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSparseGraph:
    """(dst_block, src_block) dense tiles of the normalized adjacency.

    ``blocks[k]`` is the dense ``(bs, bs)`` tile for the pair
    ``(block_rows[k], block_cols[k])``; pairs are sorted by ``block_rows`` so
    a sequential kernel grid can accumulate per destination block.
    ``row_first[k]`` is 1 iff k is the first pair of its destination block.
    """

    n: int                  # original vertex count
    n_padded: int           # padded to a multiple of bs
    bs: int                 # block size (MXU-friendly, multiple of 8/128)
    n_blocks: int           # n_padded // bs
    block_rows: np.ndarray  # (nnzb,) int32, non-decreasing
    block_cols: np.ndarray  # (nnzb,) int32
    row_first: np.ndarray   # (nnzb,) int32 {0,1}
    blocks: np.ndarray      # (nnzb, bs, bs) float32

    @property
    def nnzb(self) -> int:
        return int(self.block_rows.shape[0])

    def density(self) -> float:
        return self.nnzb / float(self.n_blocks * self.n_blocks)


def block_sparse(g: Graph, bs: int = 128) -> BlockSparseGraph:
    n_padded = -(-g.n // bs) * bs
    n_blocks = n_padded // bs
    bi = g.dst.astype(np.int64) // bs
    bj = g.src.astype(np.int64) // bs
    pair = bi * n_blocks + bj
    order = np.argsort(pair, kind="stable")
    pair_sorted = pair[order]
    uniq, start = np.unique(pair_sorted, return_index=True)
    block_rows = (uniq // n_blocks).astype(np.int32)
    block_cols = (uniq % n_blocks).astype(np.int32)
    blocks = np.zeros((len(uniq), bs, bs), dtype=np.float32)
    # scatter edges into their tiles
    tile_of_edge = np.searchsorted(uniq, pair)
    blocks[tile_of_edge, g.dst % bs, g.src % bs] += g.weight
    # ensure every destination block row has >= 1 tile: the Pallas kernel
    # writes each out block only when visited, so empty rows get an explicit
    # zero diagonal tile (keeps output fully initialized).
    present = np.zeros(n_blocks, dtype=bool)
    present[block_rows] = True
    missing = np.where(~present)[0].astype(np.int32)
    if len(missing):
        block_rows = np.concatenate([block_rows, missing])
        block_cols = np.concatenate([block_cols, missing])
        blocks = np.concatenate(
            [blocks, np.zeros((len(missing), bs, bs), np.float32)])
        order = np.argsort(block_rows, kind="stable")
        block_rows, block_cols = block_rows[order], block_cols[order]
        blocks = blocks[order]
    row_first = np.ones(len(block_rows), dtype=np.int32)
    row_first[1:] = (block_rows[1:] != block_rows[:-1]).astype(np.int32)
    return BlockSparseGraph(
        n=g.n, n_padded=n_padded, bs=bs, n_blocks=n_blocks,
        block_rows=block_rows, block_cols=block_cols,
        row_first=row_first, blocks=blocks)


def pad_features(x: np.ndarray, n_padded: int) -> np.ndarray:
    if x.shape[0] == n_padded:
        return x
    out = np.zeros((n_padded,) + x.shape[1:], dtype=x.dtype)
    out[: x.shape[0]] = x
    return out
