"""Graph containers and TPU-friendly sparse formats.

NeutronTP replicates the full graph structure on every worker and shards the
*feature* dimension instead.  The formats here are therefore built for
single-worker full-graph aggregation:

* ``Graph``          — COO sorted by destination + CSR ``indptr`` over in-edges,
                       with GCN symmetric normalization baked into ``weight``.
* ``ChunkedGraph``   — the paper's §4.2 chunk partition: contiguous destination
                       ranges with *all* their in-edges, padded to rectangular
                       arrays so a ``lax.scan`` can stream chunks.
* ``BlockSparseGraph`` — (dst_block × src_block) dense tiles for the Pallas
                       SpMM kernel: TPUs want MXU tiles, not gather/scatter,
                       so aggregation becomes a block-sparse matmul.
* ``BlockSparsePlan``  — rectangular tile plan (forward + transposed tiles)
                       for one slice of Â; built per §4.2 chunk
                       (``chunk_block_sparse``) or per DP worker partition
                       (``rect_block_sparse`` + ``stack_plans``) so the
                       engines' chunk scans can stream MXU tiles with an
                       exact custom VJP through the Âᵀ plan.
* ``HostFeatureStore`` — host-resident padded feature matrix with the
                       worker-major stripe slicing contract of the
                       out-of-core streaming path (``repro.core.stream``):
                       features never commit to device wholesale, only
                       two staged stripes at a time.

Everything is constructed in numpy (host, once) and consumed as jnp arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Full graph, in-edge oriented (COO sorted by dst + CSR indptr)."""

    n: int
    src: np.ndarray       # (E,) int32, sorted by dst
    dst: np.ndarray       # (E,) int32, non-decreasing
    weight: np.ndarray    # (E,) float32 aggregation coefficients
    indptr: np.ndarray    # (n+1,) int64 CSR offsets over dst

    @property
    def e(self) -> int:
        return int(self.src.shape[0])

    def in_degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def out_degrees(self) -> np.ndarray:
        return np.bincount(self.src, minlength=self.n).astype(np.int64)

    def dense_adjacency(self) -> np.ndarray:
        """Dense normalized adjacency (test oracle only).

        ``np.add.at``, not fancy-index ``+=``: the buffered form drops
        duplicate (dst, src) contributions, and graphs built outside
        :func:`build_graph`'s dedupe may carry parallel edges."""
        a = np.zeros((self.n, self.n), dtype=np.float32)
        np.add.at(a, (self.dst, self.src), self.weight)
        return a


def _sort_by_dst(src: np.ndarray, dst: np.ndarray,
                 weight: np.ndarray | None = None):
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    weight = None if weight is None else weight[order]
    return src, dst, weight


def build_graph(src: np.ndarray, dst: np.ndarray, n: int, *,
                add_self_loops: bool = True,
                normalization: str = "sym") -> Graph:
    """Build a :class:`Graph` with GCN-style normalized edge weights.

    normalization:
      * ``"sym"``  — 1/sqrt(deg_in(v) · deg_out(u))  (GCN, eq. 3)
      * ``"mean"`` — 1/deg_in(v)                      (GraphSAGE mean)
      * ``"none"`` — 1                                 (GIN sum)
    """
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    if add_self_loops:
        loop = np.arange(n, dtype=np.int32)
        src = np.concatenate([src, loop])
        dst = np.concatenate([dst, loop])
    # dedupe parallel edges
    key = dst.astype(np.int64) * n + src.astype(np.int64)
    key, uniq_idx = np.unique(key, return_index=True)
    src, dst = src[uniq_idx], dst[uniq_idx]

    src, dst, _ = _sort_by_dst(src, dst)
    deg_in = np.bincount(dst, minlength=n).astype(np.float64)
    deg_out = np.bincount(src, minlength=n).astype(np.float64)
    if normalization == "sym":
        w = 1.0 / np.sqrt(np.maximum(deg_in[dst], 1.0)
                          * np.maximum(deg_out[src], 1.0))
    elif normalization == "mean":
        w = 1.0 / np.maximum(deg_in[dst], 1.0)
    elif normalization == "none":
        w = np.ones_like(src, dtype=np.float64)
    else:
        raise ValueError(f"unknown normalization {normalization!r}")
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(np.bincount(dst, minlength=n), out=indptr[1:])
    return Graph(n=n, src=src, dst=dst,
                 weight=w.astype(np.float32), indptr=indptr)


# ---------------------------------------------------------------------------
# Chunked format (paper §4.2: contiguous dst ranges + all their in-edges)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChunkedGraph:
    """Rectangular per-chunk edge arrays for ``lax.scan`` streaming.

    Padded edges carry weight 0 and point at dst slot ``chunk_size`` which is
    dropped after segment-sum, so padding is numerically inert.
    """

    n: int
    n_chunks: int
    chunk_size: int            # destinations per chunk (last chunk padded)
    src: np.ndarray            # (n_chunks, max_e) int32, pad=0
    dst_local: np.ndarray      # (n_chunks, max_e) int32 in [0, chunk_size], pad=chunk_size
    weight: np.ndarray         # (n_chunks, max_e) float32, pad=0.0
    edge_id: np.ndarray        # (n_chunks, max_e) int32 id into the flat edge
                               # list (pad=E) — lets per-edge quantities (GAT α)
                               # be re-chunked on device
    # Inter-chunk pipelining (§4.2.2): srcs whose embedding slice is first
    # used by this chunk — the dedup'd per-chunk communication task.
    new_src: np.ndarray        # (n_chunks, max_new) int32, pad=-1
    new_src_count: np.ndarray  # (n_chunks,) int32

    @property
    def max_e(self) -> int:
        return int(self.src.shape[1])


def require_int32_edge_ids(e: int) -> None:
    """The ``edge_id`` contract is int32 end-to-end (``ChunkedGraph``,
    ``ChunkedDev`` and ``rechunk_edge_values`` all consume int32); the
    pad value is E itself, so E must fit int32 *inclusive*."""
    if e >= np.iinfo(np.int32).max:
        raise ValueError(
            f"chunk_graph: edge count E={e} does not fit the int32 "
            f"edge_id contract (ids run 0..E-1 and the pad value is E, "
            f"so E must be < {np.iinfo(np.int32).max}) — shard the graph "
            f"before chunking or widen the ChunkedGraph/ChunkedDev "
            f"edge_id dtype end-to-end")


def chunk_graph(g: Graph, n_chunks: int) -> ChunkedGraph:
    n = g.n
    require_int32_edge_ids(g.e)
    chunk_size = -(-n // n_chunks)
    srcs, dsts, ws, eids, news, new_counts = [], [], [], [], [], []
    seen = np.zeros(n, dtype=bool)
    max_e = 1
    max_new = 1
    for c in range(n_chunks):
        # clamp: with n_chunks ∤ n, ceil-sized chunks can overrun n (e.g.
        # n=6, n_chunks=5 → chunk 4 would start at 8); trailing chunks
        # become empty, which the padded layout already represents.
        lo = min(n, c * chunk_size)
        hi = min(n, (c + 1) * chunk_size)
        e_lo, e_hi = g.indptr[lo], g.indptr[hi]
        s = g.src[e_lo:e_hi]
        d = g.dst[e_lo:e_hi] - lo
        w = g.weight[e_lo:e_hi]
        # int32 from birth: edge ids were built int64 here and silently
        # truncated by pad()'s dtype= below — consistent now, with the
        # overflow case rejected eagerly (require_int32_edge_ids)
        eid = np.arange(e_lo, e_hi, dtype=np.int32)
        fresh = np.unique(s[~seen[s]]) if s.size else np.empty(0, np.int32)
        seen[fresh] = True
        srcs.append(s); dsts.append(d); ws.append(w); eids.append(eid)
        news.append(fresh)
        new_counts.append(len(fresh))
        max_e = max(max_e, len(s))
        max_new = max(max_new, len(fresh))

    def pad(a, length, value, dtype):
        out = np.full(length, value, dtype=dtype)
        out[: len(a)] = a
        return out

    return ChunkedGraph(
        n=n, n_chunks=n_chunks, chunk_size=chunk_size,
        src=np.stack([pad(s, max_e, 0, np.int32) for s in srcs]),
        dst_local=np.stack(
            [pad(d, max_e, chunk_size, np.int32) for d in dsts]),
        weight=np.stack([pad(w, max_e, 0.0, np.float32) for w in ws]),
        edge_id=np.stack([pad(e, max_e, g.e, np.int32) for e in eids]),
        new_src=np.stack([pad(f, max_new, -1, np.int32) for f in news]),
        new_src_count=np.asarray(new_counts, dtype=np.int32),
    )


# ---------------------------------------------------------------------------
# Block-sparse format for the Pallas SpMM kernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSparseGraph:
    """(dst_block, src_block) dense tiles of the normalized adjacency.

    ``blocks[k]`` is the dense ``(bs, bs)`` tile for the pair
    ``(block_rows[k], block_cols[k])``; pairs are sorted by ``block_rows`` so
    a sequential kernel grid can accumulate per destination block.
    ``row_first[k]`` is 1 iff k is the first pair of its destination block.
    """

    n: int                  # original vertex count
    n_padded: int           # padded to a multiple of bs
    bs: int                 # block size (MXU-friendly, multiple of 8/128)
    n_blocks: int           # n_padded // bs
    block_rows: np.ndarray  # (nnzb,) int32, non-decreasing
    block_cols: np.ndarray  # (nnzb,) int32
    row_first: np.ndarray   # (nnzb,) int32 {0,1}
    blocks: np.ndarray      # (nnzb, bs, bs) float32

    @property
    def nnzb(self) -> int:
        return int(self.block_rows.shape[0])

    def density(self) -> float:
        return self.nnzb / float(self.n_blocks * self.n_blocks)


def _coo_tiles(dst: np.ndarray, src: np.ndarray, weight: np.ndarray,
               n_row_blocks: int, n_col_blocks: int, bs: int):
    """Dense (bs, bs) tiles of the non-empty (dst//bs, src//bs) pairs.

    Uses ``np.add.at`` so duplicate (dst, src) entries *accumulate* — the
    buffered fancy-index ``+=`` silently keeps only one contribution per
    tile cell, which corrupts any Graph not deduped by ``build_graph``.
    """
    bi = dst.astype(np.int64) // bs
    bj = src.astype(np.int64) // bs
    pair = bi * n_col_blocks + bj
    uniq = np.unique(pair)
    block_rows = (uniq // n_col_blocks).astype(np.int32)
    block_cols = (uniq % n_col_blocks).astype(np.int32)
    blocks = np.zeros((len(uniq), bs, bs), dtype=np.float32)
    tile_of_edge = np.searchsorted(uniq, pair)
    np.add.at(blocks, (tile_of_edge, dst % bs, src % bs), weight)
    return block_rows, block_cols, blocks


def _finalize_tiles(block_rows: np.ndarray, block_cols: np.ndarray,
                    blocks: np.ndarray, n_row_blocks: int, bs: int):
    """Sort tiles by destination block and mark each row's first tile.

    Every destination block row gets >= 1 tile: the Pallas kernel writes
    each out block only when visited, so absent rows receive an explicit
    all-zero tile (keeps the output fully initialized)."""
    present = np.zeros(n_row_blocks, dtype=bool)
    present[block_rows] = True
    missing = np.where(~present)[0].astype(np.int32)
    if len(missing):
        block_rows = np.concatenate([block_rows, missing])
        block_cols = np.concatenate(
            [block_cols, np.zeros(len(missing), np.int32)])
        blocks = np.concatenate(
            [blocks, np.zeros((len(missing), bs, bs), np.float32)])
    order = np.lexsort((block_cols, block_rows))
    block_rows, block_cols = block_rows[order], block_cols[order]
    blocks = blocks[order]
    row_first = np.ones(len(block_rows), dtype=np.int32)
    row_first[1:] = (block_rows[1:] != block_rows[:-1]).astype(np.int32)
    return block_rows, block_cols, row_first, blocks


def block_sparse(g: Graph, bs: int = 128) -> BlockSparseGraph:
    n_padded = -(-g.n // bs) * bs
    n_blocks = n_padded // bs
    rows, cols, blocks = _coo_tiles(g.dst, g.src, g.weight,
                                    n_blocks, n_blocks, bs)
    rows, cols, first, blocks = _finalize_tiles(rows, cols, blocks,
                                                n_blocks, bs)
    return BlockSparseGraph(
        n=g.n, n_padded=n_padded, bs=bs, n_blocks=n_blocks,
        block_rows=rows, block_cols=cols,
        row_first=first, blocks=blocks)


def block_sparse_transpose(bsg: BlockSparseGraph) -> BlockSparseGraph:
    """Tiles of Âᵀ, re-sorted by *source* block — the backward-pass plan.

    ``grad_h`` of ``out = Â @ h`` is ``Âᵀ @ grad_out``; swapping each
    tile's (row, col) pair and transposing the tile yields exactly the
    block-sparse form of Âᵀ, ready for the same kernel."""
    rows, cols, first, blocks = _finalize_tiles(
        bsg.block_cols.copy(), bsg.block_rows.copy(),
        np.ascontiguousarray(np.swapaxes(bsg.blocks, 1, 2)),
        bsg.n_blocks, bsg.bs)
    return BlockSparseGraph(
        n=bsg.n, n_padded=bsg.n_padded, bs=bsg.bs, n_blocks=bsg.n_blocks,
        block_rows=rows, block_cols=cols, row_first=first, blocks=blocks)


# ---------------------------------------------------------------------------
# Rectangular / per-chunk block-sparse plans (forward + transpose tiles)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockSparsePlan:
    """Rectangular block-sparse aggregation plan with its backward tiles.

    Forward tiles cover a (n_rows × n_cols) slice of Â; the ``*_t`` arrays
    are the transposed tiles (Âᵀ slice, sorted by source block) that the
    custom VJP multiplies the cotangent through.  Data arrays may carry one
    leading stack axis — chunks of the §4.2 scan, or workers of the DP
    partition — which ``lax.scan`` unstacks an instance at a time.
    """

    n_rows: int          # real destination rows per instance
    n_cols: int          # real source rows per instance
    rows_padded: int     # n_rows padded to a multiple of bs (kernel out)
    cols_padded: int     # n_cols padded to a multiple of bs (kernel in)
    bs: int
    block_rows: np.ndarray    # ([C,] nnzb) int32 non-decreasing
    block_cols: np.ndarray    # ([C,] nnzb) int32
    row_first: np.ndarray     # ([C,] nnzb) int32 {0,1}
    blocks: np.ndarray        # ([C,] nnzb, bs, bs) float32
    block_rows_t: np.ndarray  # transpose plan, same layout
    block_cols_t: np.ndarray
    row_first_t: np.ndarray
    blocks_t: np.ndarray

    @property
    def nnzb(self) -> int:
        return int(self.block_rows.shape[-1])

    @property
    def nnzb_t(self) -> int:
        return int(self.block_rows_t.shape[-1])


def rect_block_sparse(dst: np.ndarray, src: np.ndarray, weight: np.ndarray,
                      n_rows: int, n_cols: int, bs: int) -> BlockSparsePlan:
    """Plan for one rectangular slice ``out[dst] += w · h[src]`` with
    ``dst ∈ [0, n_rows)`` and ``src ∈ [0, n_cols)``, plus its transpose."""
    rows_padded = -(-n_rows // bs) * bs
    cols_padded = -(-n_cols // bs) * bs
    r_blocks, c_blocks = rows_padded // bs, cols_padded // bs
    fr, fc, fb = _coo_tiles(dst, src, weight, r_blocks, c_blocks, bs)
    fr, fc, ff, fb = _finalize_tiles(fr, fc, fb, r_blocks, bs)
    tr, tc, tb = _coo_tiles(src, dst, weight, c_blocks, r_blocks, bs)
    tr, tc, tf, tb = _finalize_tiles(tr, tc, tb, c_blocks, bs)
    return BlockSparsePlan(
        n_rows=n_rows, n_cols=n_cols,
        rows_padded=rows_padded, cols_padded=cols_padded, bs=bs,
        block_rows=fr, block_cols=fc, row_first=ff, blocks=fb,
        block_rows_t=tr, block_cols_t=tc, row_first_t=tf, blocks_t=tb)


def stack_plans(plans: list[BlockSparsePlan]) -> BlockSparsePlan:
    """Stack same-shape plans along a new leading axis for ``lax.scan``.

    Instances are padded to the max tile count with all-zero tiles at
    (row = last row block, col = 0, row_first = 0): rows stay
    non-decreasing and the kernel accumulates nothing for them."""
    meta = {(p.n_rows, p.n_cols, p.bs) for p in plans}
    if len(meta) != 1:
        raise ValueError(f"stack_plans needs uniform plan shapes, got {meta}")
    p0 = plans[0]

    def pad_set(rows, cols, first, blocks, m, n_row_blocks):
        k = m - len(rows)
        if k:
            rows = np.concatenate(
                [rows, np.full(k, n_row_blocks - 1, np.int32)])
            cols = np.concatenate([cols, np.zeros(k, np.int32)])
            first = np.concatenate([first, np.zeros(k, np.int32)])
            blocks = np.concatenate(
                [blocks, np.zeros((k, p0.bs, p0.bs), np.float32)])
        return rows, cols, first, blocks

    m_f = max(p.nnzb for p in plans)
    m_t = max(p.nnzb_t for p in plans)
    fwd = [pad_set(p.block_rows, p.block_cols, p.row_first, p.blocks,
                   m_f, p0.rows_padded // p0.bs) for p in plans]
    bwd = [pad_set(p.block_rows_t, p.block_cols_t, p.row_first_t, p.blocks_t,
                   m_t, p0.cols_padded // p0.bs) for p in plans]
    return dataclasses.replace(
        p0,
        block_rows=np.stack([s[0] for s in fwd]),
        block_cols=np.stack([s[1] for s in fwd]),
        row_first=np.stack([s[2] for s in fwd]),
        blocks=np.stack([s[3] for s in fwd]),
        block_rows_t=np.stack([s[0] for s in bwd]),
        block_cols_t=np.stack([s[1] for s in bwd]),
        row_first_t=np.stack([s[2] for s in bwd]),
        blocks_t=np.stack([s[3] for s in bwd]))


def chunk_block_sparse(g: Graph, n_chunks: int,
                       bs: int = 128) -> BlockSparsePlan:
    """Per-chunk plans for the §4.2 chunk scan, stacked for ``lax.scan``.

    Chunk ``c`` owns destination rows ``[c·cs, (c+1)·cs)`` with all their
    in-edges; sources span the full vertex set.  Chunk bounds clamp
    identically to :func:`chunk_graph` when ``n_chunks ∤ n`` (trailing
    chunks go empty and carry only zero-fill tiles)."""
    cs = -(-g.n // n_chunks)
    plans = []
    for c in range(n_chunks):
        lo = min(g.n, c * cs)
        hi = min(g.n, (c + 1) * cs)
        e_lo, e_hi = g.indptr[lo], g.indptr[hi]
        plans.append(rect_block_sparse(
            g.dst[e_lo:e_hi] - lo, g.src[e_lo:e_hi], g.weight[e_lo:e_hi],
            n_rows=cs, n_cols=g.n, bs=bs))
    return stack_plans(plans)


def pad_features(x: np.ndarray, n_padded: int) -> np.ndarray:
    if x.shape[0] == n_padded:
        return x
    out = np.zeros((n_padded,) + x.shape[1:], dtype=x.dtype)
    out[: x.shape[0]] = x
    return out


# ---------------------------------------------------------------------------
# Host-resident feature store (out-of-core streaming, repro.core.stream)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HostFeatureStore:
    """Host-resident (n_padded, d) feature matrix with the worker-major
    stripe slicing contract of the out-of-core streaming path.

    The NN phase is streamed in ``n_stripes`` slices; the device never
    holds more than two staged stripes at once (the double-buffer).  A
    stripe is *worker-major*: stripe ``s`` stacks each TP worker ``i``'s
    rows ``[i·V/N + s·rs, i·V/N + (s+1)·rs)`` (``rs = V/(N·S)``), so
    placing it with ``P(axis, None)`` hands worker ``i`` exactly its
    contiguous sub-block of the vertex-sharded layout — stripe writes
    into the per-worker (V/N, ·) buffer are plain dynamic slices at
    ``s·rs`` and streaming reproduces the in-memory row order bit-com-
    patibly.  The slicing (not the array) is the contract: ``stripe()``
    returns views/copies of host numpy, nothing here touches a device.
    """

    x: np.ndarray          # (n_padded, d) host numpy
    n_workers: int
    n_stripes: int

    def __post_init__(self):
        n_padded = int(self.x.shape[0])
        denom = self.n_workers * self.n_stripes
        if n_padded % denom:
            raise ValueError(
                f"HostFeatureStore: n_padded={n_padded} must divide by "
                f"n_workers·n_stripes={self.n_workers}·{self.n_stripes}"
                f"={denom} for rectangular stripes — pad the vertex dim "
                f"(tp.padded_size) or pick a stripe count dividing the "
                f"per-worker block")

    @property
    def n_padded(self) -> int:
        return int(self.x.shape[0])

    @property
    def d(self) -> int:
        return int(self.x.shape[1])

    @property
    def stripe_rows(self) -> int:
        """Per-worker rows of one stripe (``rs`` above)."""
        return self.n_padded // (self.n_workers * self.n_stripes)

    @property
    def nbytes(self) -> int:
        return int(self.x.nbytes)

    @property
    def stripe_nbytes(self) -> int:
        """Device bytes one staged stripe occupies (the unit of the
        two-stripe footprint contract)."""
        return self.n_workers * self.stripe_rows * self.d * \
            self.x.dtype.itemsize

    def stripe(self, s: int) -> np.ndarray:
        """Worker-major host stripe ``s``: (n_workers·stripe_rows, d)."""
        if not 0 <= s < self.n_stripes:
            raise IndexError(
                f"stripe {s} out of range [0, {self.n_stripes})")
        rs = self.stripe_rows
        return np.ascontiguousarray(
            self.x.reshape(self.n_workers, self.n_stripes, rs,
                           self.d)[:, s].reshape(-1, self.d))
