"""Trace-time jaxpr collective audit (tier 2).

The telemetry :class:`~repro.runtime.telemetry.CommLedger` is filled by
Python-side wrappers while a program traces; nothing forces it to agree
with the program JAX actually built.  The PR 2-4 arc cross-checked it
against a *regex parse of compiled HLO text* (``launch.roofline.
hlo_census``), which shipped two silent-zero parser bugs and reads
whatever XLA emitted, not what the program *is*.  This module replaces
that structural leg: it recursively walks the **closed jaxpr** of an
engine program — through ``scan``/``while``/``pjit``/``custom_vjp``
sub-jaxprs, multiplying scan bodies by their static trip count — counts
collective primitives per (op, axis label, dtype), and diffs the counts
against what the ledger implies:

* a jaxpr collective the ledger did not record → ``unledgered_collective``
  (someone bypassed the runtime choke point, or forgot ``loop_scope``);
* a ledger entry with no jaxpr counterpart → ``phantom_ledger_entry``
  (a wrapper recorded bytes autodiff never emits, e.g. a wrong
  ``mirror=`` declaration);
* collectives inside a ``while`` body (unknown trip count) →
  ``unbounded_loop`` — the repo's loops are scans with static lengths.

Exactness contract: the diff is exact over the data-moving ops
(``all_to_all``, ``all_gather``, ``psum_scatter``, ``ppermute``) —
including autodiff mirrors, which appear in the jaxpr as the transposed
primitive (``all_gather`` ↔ ``reduce_scatter``, ``a2a`` ↔ ``a2a``,
``ppermute`` ↔ reversed ``ppermute``) and in the ledger as
``mirrored_calls`` under the forward op.  ``psum`` is checked
one-directionally (phantom entries only): shard_map's transpose emits
parameter-gradient all-reduces with no forward counterpart, which the
ledger documents as out of scope (runtime/telemetry.py).

The constraint backend builds programs with **zero** collective
primitives — the SPMD partitioner materializes them after lowering — so
``backend="constraint"`` instead asserts that, and checks each
*anchored* layout transition the ledger recorded
(:class:`~repro.runtime.telemetry.TransitionRecord`, from
``layout_cast``) against the program's ``sharding_constraint``
equations by (global shape, dtype, normalized PartitionSpec).

Obtain the jaxpr with ``jax.make_jaxpr`` *outside* ``collect_comm`` —
the telemetry wrappers no-op without an active ledger, so re-tracing for
the audit records nothing.  8-device coverage of all four GNN modes ×
both backends lives in tests/dist_progs/check_telemetry.py; the bench
smoke (``benchmarks/_dist_gnn.py --audit``) runs it in tier-1 CI.
"""
from __future__ import annotations

import dataclasses

from ..runtime import telemetry as T

__all__ = [
    "AuditFinding", "audit", "assert_clean", "collective_counts",
    "sharding_constraint_counts", "expected_from_ledger",
    "DATA_OPS", "PRIM_TO_OP", "MIRROR_OP",
]

#: jaxpr primitive name → ledger op kind.
PRIM_TO_OP = {
    "psum": "psum",
    "all_gather": "all_gather",
    "all_to_all": "all_to_all",
    "ppermute": "ppermute",
    "reduce_scatter": "psum_scatter",
    "psum_scatter": "psum_scatter",
}

#: Ops audited exactly (count equality both directions).  psum is
#: excluded — see module docstring.
DATA_OPS = ("all_to_all", "all_gather", "ppermute", "psum_scatter")

#: Forward ledger op → primitive its autodiff transpose emits.
MIRROR_OP = {
    "all_to_all": "all_to_all",
    "all_gather": "psum_scatter",
    "psum_scatter": "all_gather",
    "ppermute": "ppermute",
    "psum": "psum",
}


@dataclasses.dataclass(frozen=True)
class AuditFinding:
    """One structural disagreement between jaxpr and ledger."""

    kind: str        # unledgered_collective | phantom_ledger_entry |
    #                  unbounded_loop | collective_in_constraint_program |
    #                  missing_constraint
    op: str
    axis: str
    expected: float  # what the ledger implies
    actual: float    # what the jaxpr contains
    detail: str = ""

    def format(self) -> str:
        return (f"{self.kind}: op={self.op} axis={self.axis} "
                f"ledger={self.expected:g} jaxpr={self.actual:g}"
                + (f" — {self.detail}" if self.detail else ""))


def _axis_label(axes) -> str:
    if axes is None:
        return ""
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return "+".join(str(a) for a in axes)


def _as_jaxpr(jaxpr):
    return getattr(jaxpr, "jaxpr", jaxpr)


def _sub_jaxprs(eqn):
    """Every Jaxpr/ClosedJaxpr hanging off an equation's params —
    generic, so scan/while/pjit/cond/custom_vjp/shard_map (and whatever
    a future JAX adds) are all walked without a primitive whitelist."""
    for v in eqn.params.values():
        for sub in (v if isinstance(v, (tuple, list)) else (v,)):
            if hasattr(sub, "eqns"):
                yield sub
            else:
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield inner


def _eqn_dtype(eqn) -> str:
    for v in eqn.invars:
        aval = getattr(v, "aval", None)
        if aval is not None and hasattr(aval, "dtype"):
            return str(aval.dtype)
    return "?"


def _walk(jaxpr, mult, in_while, counts, constraints, unbounded):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        op = PRIM_TO_OP.get(name)
        if op is not None:
            axes = eqn.params.get("axes", eqn.params.get("axis_name"))
            label = _axis_label(axes)
            if label:               # axes=() psums move nothing — skip
                key = (op, label, _eqn_dtype(eqn))
                if in_while:
                    unbounded.add(key)
                else:
                    counts[key] = counts.get(key, 0.0) + mult
            continue
        if name == "sharding_constraint":
            sharding = eqn.params.get("sharding")
            spec = getattr(sharding, "spec", None)
            aval = eqn.outvars[0].aval
            key = (tuple(aval.shape), str(aval.dtype),
                   T.normalize_spec(spec) if spec is not None else ())
            constraints[key] = constraints.get(key, 0.0) + mult
            continue
        sub_mult = mult
        if name == "scan":
            sub_mult = mult * float(eqn.params.get("length", 1))
        for sub in _sub_jaxprs(eqn):
            _walk(sub, sub_mult, in_while or name == "while",
                  counts, constraints, unbounded)


def _walk_all(jaxpr):
    counts: dict[tuple, float] = {}
    constraints: dict[tuple, float] = {}
    unbounded: set[tuple] = set()
    _walk(_as_jaxpr(jaxpr), 1.0, False, counts, constraints, unbounded)
    return counts, constraints, unbounded


def collective_counts(jaxpr) -> dict[tuple, float]:
    """(op, axis label, dtype) → execution count of every collective
    primitive reachable from ``jaxpr``, scan bodies multiplied by their
    static trip counts.  While-body collectives are excluded (see
    :func:`audit`, which reports them as ``unbounded_loop``)."""
    return _walk_all(jaxpr)[0]


def sharding_constraint_counts(jaxpr) -> dict[tuple, float]:
    """(global shape, dtype, normalized spec) → count of
    ``sharding_constraint`` equations, scan-trip multiplied."""
    return _walk_all(jaxpr)[1]


def expected_from_ledger(ledger: T.CommLedger) -> dict[tuple, float]:
    """Jaxpr-side collective counts the ledger implies: forward ``calls``
    under the op itself, ``mirrored_calls`` under the primitive its
    transpose emits (:data:`MIRROR_OP`).

    Non-collective ledger ops — today only the H2D staging column
    (:data:`repro.runtime.telemetry.H2D_OP`) — never appear in a jaxpr
    (a ``device_put`` from host numpy happens outside the traced
    program), so they are skipped rather than reported as phantoms."""
    exp: dict[tuple, float] = {}

    def bump(key, n):
        if n:
            exp[key] = exp.get(key, 0.0) + n

    for (op, label, dtype), e in ledger.entries().items():
        if op not in MIRROR_OP:
            continue
        bump((op, label, dtype), e.calls)
        bump((MIRROR_OP[op], label, dtype), e.mirrored_calls)
    return exp


def audit(jaxpr, ledger: T.CommLedger, *,
          backend: str = "explicit") -> list[AuditFinding]:
    """Diff ``jaxpr``'s collective structure against ``ledger``.

    Returns structured findings (empty list = clean).  See the module
    docstring for the exactness contract per backend.
    """
    counts, constraints, unbounded = _walk_all(jaxpr)
    findings = []
    for key in sorted(unbounded):
        op, label, _ = key
        findings.append(AuditFinding(
            "unbounded_loop", op, label, 0.0, float("nan"),
            "collective inside a while body — trip count is not static, "
            "so neither the ledger nor this audit can count it; use a "
            "scan with a static length under telemetry.loop_scope"))

    if backend == "constraint":
        for (op, label, dtype), n in sorted(counts.items()):
            findings.append(AuditFinding(
                "collective_in_constraint_program", op, label, 0.0, n,
                f"dtype={dtype}: constraint-backend programs carry no "
                f"collective primitives (the SPMD partitioner "
                f"materializes them after lowering) — a {op} here means "
                f"explicit-backend code leaked into a global-view body"))
        for t in ledger.transitions():
            if not t.anchored:
                continue
            for side, spec in (("src", t.src_spec), ("dst", t.dst_spec)):
                key = (t.shape, t.dtype, spec)
                have = constraints.get(key, 0.0)
                if have < t.calls:
                    findings.append(AuditFinding(
                        "missing_constraint", "sharding_constraint",
                        "+".join(str(s) for s in spec), t.calls, have,
                        f"anchored layout transition {t.src_spec} -> "
                        f"{t.dst_spec} of {t.dtype}{list(t.shape)} has "
                        f"no {side}-side sharding_constraint equation — "
                        f"layout_cast recorded a transition the traced "
                        f"program does not anchor"))
        return findings

    exp = expected_from_ledger(ledger)
    keys = {k for k in counts if k[0] in DATA_OPS} | \
           {k for k in exp if k[0] in DATA_OPS}
    for key in sorted(keys):
        op, label, dtype = key
        have, want = counts.get(key, 0.0), exp.get(key, 0.0)
        if have > want:
            findings.append(AuditFinding(
                "unledgered_collective", op, label, want, have,
                f"dtype={dtype}: the traced program contains {have:g} "
                f"{op} over {label!r} but the ledger accounts for "
                f"{want:g} — a collective bypassed "
                f"runtime/collectives.py, or a communicating scan lacks "
                f"telemetry.loop_scope"))
        elif want > have:
            findings.append(AuditFinding(
                "phantom_ledger_entry", op, label, want, have,
                f"dtype={dtype}: the ledger accounts for {want:g} {op} "
                f"over {label!r} but the traced program contains only "
                f"{have:g} — a wrapper recorded bytes autodiff never "
                f"emits (wrong mirror= declaration?)"))
    for key in sorted(k for k in exp if k[0] == "psum"):
        op, label, dtype = key
        if exp[key] > counts.get(key, 0.0):
            findings.append(AuditFinding(
                "phantom_ledger_entry", op, label, exp[key],
                counts.get(key, 0.0),
                f"dtype={dtype}: ledger psum count exceeds the program's "
                f"(the reverse direction is expected — parameter-"
                f"gradient all-reduces are out of ledger scope)"))
    return findings


def assert_clean(jaxpr, ledger: T.CommLedger, *,
                 backend: str = "explicit", tag: str = "") -> None:
    """Raise AssertionError listing every finding (CI entry point)."""
    findings = audit(jaxpr, ledger, backend=backend)
    if findings:
        head = f"jaxpr audit failed{f' [{tag}]' if tag else ''}:"
        raise AssertionError(
            "\n  ".join([head] + [f.format() for f in findings]))
