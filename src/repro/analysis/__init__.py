"""Static analysis of the repo's distributed disciplines.

The PR 1-5 arc made correctness depend on *disciplines* rather than
locality: every collective routes through ``runtime/collectives.py``,
every data-moving call site declares its autodiff ``mirror=``, scans
whose bodies communicate carry ``telemetry.loop_scope`` trip
multipliers, and ``jax.distributed`` is entered only via
``runtime/distributed.py``.  This package is the layer that turns a
violation of any of them into a fast structural error instead of a slow
byte-equality failure (or a silently skewed Fig. 8 row):

* :mod:`repro.analysis.lint` — tier 1, an AST linter over the source
  tree (rule registry RT001..RT005 + report-only W-rules; CLI:
  ``scripts/lint_dist.py``).  Catches every *spelling* of a violation
  (``from jax.lax import all_to_all``, ``import jax.lax as _l``) that
  the retired line-regex check in tests/test_collectives_chokepoint.py
  was blind to.
* :mod:`repro.analysis.jaxpr_audit` — tier 2, a trace-time sanitizer
  that recursively counts collective primitives in the closed jaxpr of
  an engine program (scan trip multipliers included) and cross-checks
  them against the trace-time :class:`repro.runtime.telemetry.CommLedger`
  — ledger == analytic == *structure*, without regex-parsing HLO text
  (the :func:`repro.launch.roofline.hlo_census` path this supersedes).

See ROADMAP.md "Distributed discipline" for the rule-by-rule invariant
table and the PRs that motivated each rule.
"""
from . import jaxpr_audit, lint  # noqa: F401

__all__ = ["lint", "jaxpr_audit"]
