"""AST linter for the repo's distributed disciplines (tier 1).

Why AST and not regex: the retired choke-point check
(tests/test_collectives_chokepoint.py before this module) matched the
literal text ``lax.<op>(`` — blind to ``from jax.lax import all_to_all``
and ``import jax.lax as _l`` spellings (regression fixtures under
tests/fixtures/lint/ prove both misses).  This linter resolves imports
(absolute, relative, aliased) to fully-qualified dotted names first, so
a rule fires on *what a name means*, not on how it is spelled.

Rules (ROADMAP.md "Distributed discipline" maps each to the PR whose
invariant it pins):

==== ========= ==========================================================
id   severity  invariant
==== ========= ==========================================================
RT001 error    ``jax.lax`` collectives only in ``runtime/collectives.py``
                — the telemetry/backends choke point — in any spelling.
RT002 error    ``shard_map`` (any spelling) only under ``runtime/``.
RT003 error    data-moving collective call sites in engine code
                (``core/``, ``gnn/``, ``nn/`` path segments) pass an
                explicit ``mirror=`` — the autodiff-mirror declaration
                the ledger's backward accounting is built on.
RT004 error    ``lax.scan``/``fori_loop``/``while_loop`` whose body
                invokes runtime collectives is lexically wrapped in
                ``telemetry.loop_scope`` (trip multipliers).
RT005 error    multihost discipline: ``jax.distributed.initialize`` and
                reads of the COORDINATOR_ADDRESS/NUM_PROCESSES/
                PROCESS_ID env contract only in ``runtime/distributed``.
W100  warn     seed-stub modules (``configs/*`` LLM configs,
                ``serve/engine``) referenced only from their own package
                — tracked dead code for the serving arc.
==== ========= ==========================================================

Suppression: append ``# lint-ok: <RULE>`` (or a bare ``# lint-ok``) to
the offending line, with a reason — used exactly once in-tree, for the
jaxpr audit's deliberate-violation negative test.

API: :func:`lint_paths` (files + directories → findings, file- and
tree-level rules), :func:`lint_text` (one in-memory source, file-level
rules only — the unit-test entry point).  CLI: ``scripts/lint_dist.py``.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Iterator

__all__ = [
    "LintFinding", "Rule", "FILE_RULES", "TREE_RULES", "all_rules",
    "lint_paths", "lint_text", "iter_py_files", "module_name_for",
]

# ---------------------------------------------------------------------------
# Findings and rule registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LintFinding:
    """One rule violation at one source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    severity: str = "error"          # "error" (gates CI) | "warn" (report)

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.severity}] {self.message}")

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    severity: str
    invariant: str                   # one line; ROADMAP table + --rules
    fn: Callable = None


FILE_RULES: list[Rule] = []          # fn(ctx) -> list[LintFinding]
TREE_RULES: list[Rule] = []          # fn(list[ctx]) -> list[LintFinding]


def _register(registry, rule_id, severity, invariant):
    def deco(fn):
        registry.append(Rule(rule_id, severity, invariant, fn))
        return fn
    return deco


def file_rule(rule_id, severity, invariant):
    return _register(FILE_RULES, rule_id, severity, invariant)


def tree_rule(rule_id, severity, invariant):
    return _register(TREE_RULES, rule_id, severity, invariant)


def all_rules() -> list[Rule]:
    return sorted(FILE_RULES + TREE_RULES, key=lambda r: r.id)


# ---------------------------------------------------------------------------
# Per-file context: imports resolved to fully-qualified dotted names
# ---------------------------------------------------------------------------

def module_name_for(path: str) -> str | None:
    """Dotted module name of ``path``, or None when it is not under a
    ``src/`` root (scripts and test programs import absolutely, so their
    relative imports — which need a package context — stay unresolved
    rather than guessed)."""
    parts = os.path.normpath(os.path.abspath(path)).split(os.sep)
    if "src" not in parts:
        return None
    i = len(parts) - 1 - parts[::-1].index("src")
    mods = parts[i + 1:]
    if not mods or not mods[-1].endswith(".py"):
        return None
    mods[-1] = mods[-1][:-3]
    if mods[-1] == "__init__":
        mods.pop()
    return ".".join(mods) or None


class _FileContext:
    """Parsed file + the name-resolution tables every rule shares."""

    def __init__(self, path: str, text: str, module: str | None = None):
        self.path = path
        self.parts = os.path.normpath(os.path.abspath(path)).split(os.sep)
        self.lines = text.splitlines()
        self.module = module if module is not None else \
            module_name_for(path)
        # package context for relative imports: a module's package is its
        # parent; an __init__ IS its package (module_name_for strips it)
        base = os.path.basename(path)
        self.package = self.module if base == "__init__.py" else (
            self.module.rsplit(".", 1)[0]
            if self.module and "." in self.module else None)
        self.tree = ast.parse(text, filename=path)
        self.aliases: dict[str, str] = {}       # local name -> dotted fq
        self.import_nodes: list = []            # (node, base) for rules
        self.funcs: dict[str, ast.AST] = {}     # name -> (last) FunctionDef
        self.parent: dict[ast.AST, ast.AST] = {}
        self._index()

    # -- construction ----------------------------------------------------

    def _index(self) -> None:
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parent[child] = node
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.asname:
                        self.aliases[a.asname] = a.name
                    else:   # ``import jax.lax`` binds the root name only
                        root = a.name.split(".")[0]
                        self.aliases[root] = root
                self.import_nodes.append((node, None))
            elif isinstance(node, ast.ImportFrom):
                base = self._from_base(node)
                if base is not None:
                    for a in node.names:
                        if a.name == "*":
                            continue
                        self.aliases[a.asname or a.name] = \
                            f"{base}.{a.name}" if base else a.name
                self.import_nodes.append((node, base))
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.funcs[node.name] = node

    def _from_base(self, node: ast.ImportFrom) -> str | None:
        if node.level == 0:
            return node.module or ""
        if self.package is None:
            return None                      # unknown package context
        parts = self.package.split(".")
        # level 1 = current package; each extra level climbs one parent
        parts = parts[: len(parts) - (node.level - 1)]
        if not parts:
            return None
        if node.module:
            parts += node.module.split(".")
        return ".".join(parts)

    # -- queries ---------------------------------------------------------

    def resolve(self, node) -> str | None:
        """Fully-qualified dotted name of a Name/Attribute chain, through
        the file's import aliases; None when the root is not imported."""
        parts = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        base = self.aliases.get(node.id)
        if base is None:
            return None
        parts.append(base)
        return ".".join(reversed(parts))

    def path_has_segment(self, *segments: str) -> bool:
        return any(s in self.parts for s in segments)

    def rel_endswith(self, suffix: str) -> bool:
        return os.path.join(*self.parts[-len(suffix.split("/")):]) == \
            os.path.join(*suffix.split("/"))

    def suppressed(self, finding: LintFinding) -> bool:
        if not 1 <= finding.line <= len(self.lines):
            return False
        line = self.lines[finding.line - 1]
        if "# lint-ok" not in line:
            return False
        tail = line.split("# lint-ok", 1)[1].lstrip()
        if not tail.startswith(":"):
            return True                  # bare `# lint-ok`: all rules
        spec = tail[1:].strip()
        return spec == "" or finding.rule in spec


# ---------------------------------------------------------------------------
# RT001 — jax.lax collectives only in runtime/collectives.py
# ---------------------------------------------------------------------------

#: The ops that put bytes on the wire, plus the axis introspection engine
#: bodies rely on (same vocabulary the retired regex check pinned).
LAX_COLLECTIVES = frozenset({
    "psum", "pmean", "pmax", "pmin", "all_gather", "all_to_all",
    "ppermute", "psum_scatter", "axis_index", "axis_size",
})

_RT001_ALLOWED = "runtime/collectives.py"


@file_rule("RT001", "error",
           "jax.lax collectives route through runtime/collectives.py "
           "(the telemetry/backends choke point), in any spelling")
def _rt001(ctx: _FileContext) -> list[LintFinding]:
    if ctx.rel_endswith(_RT001_ALLOWED):
        return []
    out = []
    for node, base in ctx.import_nodes:
        if isinstance(node, ast.ImportFrom) and base == "jax.lax":
            for a in node.names:
                if a.name in LAX_COLLECTIVES:
                    out.append(LintFinding(
                        "RT001", ctx.path, node.lineno, node.col_offset,
                        f"importing jax.lax.{a.name} outside "
                        f"runtime/collectives.py — route it through "
                        f"repro.runtime.collectives.{a.name} so the "
                        f"telemetry ledger sees the bytes"))
    for node in ast.walk(ctx.tree):
        if not isinstance(node, (ast.Attribute, ast.Name)):
            continue
        if not isinstance(getattr(node, "ctx", None), ast.Load):
            continue
        # only report the outermost attribute chain (jax.lax.psum once,
        # not again for its jax.lax prefix)
        if isinstance(ctx.parent.get(node), ast.Attribute):
            continue
        fq = ctx.resolve(node)
        if fq and fq.startswith("jax.lax.") and \
                fq.rsplit(".", 1)[1] in LAX_COLLECTIVES:
            out.append(LintFinding(
                "RT001", ctx.path, node.lineno, node.col_offset,
                f"direct use of {fq} outside runtime/collectives.py — "
                f"call repro.runtime.collectives.{fq.rsplit('.', 1)[1]} "
                f"instead (the choke point the CommLedger counts at)"))
    return out


# ---------------------------------------------------------------------------
# RT002 — shard_map only under runtime/
# ---------------------------------------------------------------------------

@file_rule("RT002", "error",
           "shard_map (any spelling) is entered only by the runtime "
           "layer (runtime/smap.py is the version-portable entry)")
def _rt002(ctx: _FileContext) -> list[LintFinding]:
    if ctx.path_has_segment("runtime"):
        return []
    out = []

    def hit(node, what):
        out.append(LintFinding(
            "RT002", ctx.path, node.lineno, node.col_offset,
            f"{what} outside runtime/ — sharded execution enters "
            f"through repro.runtime.engine (runtime/smap.py owns the "
            f"version-portable shard_map import)"))

    for node, base in ctx.import_nodes:
        if isinstance(node, ast.Import):
            for a in node.names:
                if "shard_map" in a.name.split("."):
                    hit(node, f"import of {a.name}")
        elif base is not None:
            if "shard_map" in base.split("."):
                hit(node, f"import from {base}")
            else:
                for a in node.names:
                    if a.name == "shard_map":
                        hit(node, f"import of {base}.shard_map")
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Attribute) and node.attr == "shard_map":
            fq = ctx.resolve(node)
            if fq and fq.startswith("jax."):
                hit(node, f"use of {fq}")
    return out


# ---------------------------------------------------------------------------
# RT003 — explicit mirror= in engine code
# ---------------------------------------------------------------------------

#: Call targets whose backward accounting depends on the caller declaring
#: mirror= (psum/psum_replicas are excluded: mirror=False is their
#: documented convention — see runtime/telemetry.py).
MIRROR_REQUIRED = frozenset({
    "repro.runtime.collectives.all_gather",
    "repro.runtime.collectives.all_to_all",
    "repro.runtime.collectives.ppermute",
    "repro.runtime.collectives.replica_gather",
    "repro.core.tp.split",
    "repro.core.tp.gather",
    "repro.core.tp.split_constraint",
    "repro.core.tp.gather_constraint",
    "repro.runtime.constraint.layout_cast",
})

#: Engine-code path segments RT003 applies to (runtime/ and sharding/
#: are the implementation layers that own the defaults).
_RT003_SEGMENTS = ("core", "gnn", "nn")


@file_rule("RT003", "error",
           "data-moving collective call sites in engine code (core/, "
           "gnn/, nn/) declare mirror= explicitly — the ledger's "
           "backward bytes are derived from that declaration")
def _rt003(ctx: _FileContext) -> list[LintFinding]:
    if not ctx.path_has_segment(*_RT003_SEGMENTS):
        return []
    if ctx.path_has_segment("runtime"):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fq = ctx.resolve(node.func)
        if fq not in MIRROR_REQUIRED:
            continue
        if any(kw.arg == "mirror" for kw in node.keywords):
            continue
        short = fq.rsplit(".", 1)[1]
        out.append(LintFinding(
            "RT003", ctx.path, node.lineno, node.col_offset,
            f"{short}(...) without an explicit mirror= — declare whether "
            f"autodiff transposes this collective (mirror=True) or the "
            f"moved data is undifferentiated (mirror=False); the ledger "
            f"counts backward bytes from this declaration (PR 4)"))
    return out


# ---------------------------------------------------------------------------
# RT004 — communicating loop bodies wrapped in telemetry.loop_scope
# ---------------------------------------------------------------------------

#: loop fn -> positional index of the body callable.
_LOOP_FNS = {"jax.lax.scan": 0, "jax.lax.fori_loop": 2,
             "jax.lax.while_loop": 1}
_BODY_KW = {"jax.lax.scan": "f", "jax.lax.fori_loop": "body_fun",
            "jax.lax.while_loop": "body_fun"}

#: Calls that put bytes on the wire from inside a loop body: the ledger-
#: recording wrappers, plus the chunk-collective helpers whose bodies
#: contain the all-to-alls (the pipelined scans' indirection).
_COMM_FNS = frozenset(
    {f"repro.runtime.collectives.{f}"
     for f in ("psum", "all_gather", "all_to_all", "ppermute",
               "replica_gather", "psum_replicas")} |
    {"repro.core.chunks.chunk_split_step",
     "repro.core.chunks.chunk_gather_step"})

_WRAPPERS = frozenset({"jax.checkpoint", "jax.remat"})


def _body_node(ctx, call, fq):
    args = call.args
    idx = _LOOP_FNS[fq]
    body = args[idx] if len(args) > idx else None
    if body is None:
        kw = _BODY_KW[fq]
        body = next((k.value for k in call.keywords if k.arg == kw), None)
    # unwrap jax.checkpoint(step) / jax.remat(step)
    while isinstance(body, ast.Call) and \
            (ctx.resolve(body.func) in _WRAPPERS) and body.args:
        body = body.args[0]
    if isinstance(body, ast.Name):
        return ctx.funcs.get(body.id)
    if isinstance(body, (ast.Lambda, ast.FunctionDef)):
        return body
    return None


def _communicates(ctx, fn_node, seen) -> bool:
    if fn_node is None or fn_node in seen:
        return False
    seen.add(fn_node)
    for node in ast.walk(fn_node):
        if not isinstance(node, ast.Call):
            continue
        fq = ctx.resolve(node.func)
        if fq in _COMM_FNS:
            return True
        if isinstance(node.func, ast.Name) and \
                node.func.id in ctx.funcs and \
                _communicates(ctx, ctx.funcs[node.func.id], seen):
            return True
    return False


def _in_loop_scope(ctx, node) -> bool:
    cur = ctx.parent.get(node)
    while cur is not None:
        if isinstance(cur, ast.With):
            for item in cur.items:
                c = item.context_expr
                if isinstance(c, ast.Call):
                    fq = ctx.resolve(c.func)
                    if fq and fq.rsplit(".", 1)[-1] == "loop_scope":
                        return True
        cur = ctx.parent.get(cur)
    return False


@file_rule("RT004", "error",
           "scan/fori_loop/while_loop bodies that invoke runtime "
           "collectives are wrapped in telemetry.loop_scope so the "
           "ledger counts in-loop collectives trip-many times")
def _rt004(ctx: _FileContext) -> list[LintFinding]:
    out = []
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        fq = ctx.resolve(node.func)
        if fq not in _LOOP_FNS:
            continue
        body = _body_node(ctx, node, fq)
        if not _communicates(ctx, body, set()):
            continue
        if _in_loop_scope(ctx, node):
            continue
        out.append(LintFinding(
            "RT004", ctx.path, node.lineno, node.col_offset,
            f"{fq.rsplit('.', 1)[1]} body communicates but the call is "
            f"not inside `with telemetry.loop_scope(trips):` — the body "
            f"traces once yet executes trip-many times, so an unscoped "
            f"loop undercounts the ledger by the trip factor (PR 4)"))
    return out


# ---------------------------------------------------------------------------
# RT005 — multihost env contract only in runtime/distributed.py
# ---------------------------------------------------------------------------

#: The launcher env contract (runtime/distributed.py constants); reads
#: anywhere else bypass env_topology()'s validation and single ownership.
MULTIHOST_ENV = frozenset({
    "COORDINATOR_ADDRESS", "NUM_PROCESSES", "PROCESS_ID",
    "DIST_INIT_TIMEOUT",
})

_RT005_ALLOWED = "runtime/distributed.py"


def _const_str(node) -> str | None:
    return node.value if isinstance(node, ast.Constant) and \
        isinstance(node.value, str) else None


@file_rule("RT005", "error",
           "jax.distributed.initialize and reads of the COORDINATOR_"
           "ADDRESS/NUM_PROCESSES/PROCESS_ID env contract happen only "
           "in runtime/distributed.py (single validated entry, PR 5)")
def _rt005(ctx: _FileContext) -> list[LintFinding]:
    if ctx.rel_endswith(_RT005_ALLOWED):
        return []
    out = []
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Call):
            fq = ctx.resolve(node.func)
            if fq == "jax.distributed.initialize":
                out.append(LintFinding(
                    "RT005", ctx.path, node.lineno, node.col_offset,
                    "direct jax.distributed.initialize — use "
                    "repro.runtime.distributed.initialize (eager "
                    "validation, actionable errors, idempotence)"))
                continue
            if fq in ("os.environ.get", "os.getenv") and node.args:
                key = _const_str(node.args[0])
                if key in MULTIHOST_ENV:
                    out.append(LintFinding(
                        "RT005", ctx.path, node.lineno, node.col_offset,
                        f"reading {key} from the environment — the "
                        f"multihost env contract is owned by "
                        f"repro.runtime.distributed (use "
                        f"dist.env_topology())"))
        elif isinstance(node, ast.Subscript) and \
                isinstance(node.ctx, ast.Load):
            if ctx.resolve(node.value) == "os.environ":
                key = _const_str(getattr(node, "slice", None))
                if key in MULTIHOST_ENV:
                    out.append(LintFinding(
                        "RT005", ctx.path, node.lineno, node.col_offset,
                        f"reading os.environ[{key!r}] — use "
                        f"repro.runtime.distributed.env_topology()"))
    return out


# ---------------------------------------------------------------------------
# W100 — seed stubs referenced only from their own package (tree rule)
# ---------------------------------------------------------------------------

def _watched_stub(ctx: _FileContext) -> bool:
    if ctx.module is None:
        return False
    if ctx.module.startswith("repro.configs.") and \
            not ctx.module.endswith("__init__"):
        return True
    return ctx.module == "repro.serve.engine"


@tree_rule("W100", "warn",
           "seed-stub modules (configs/* LLM configs, serve/engine) "
           "referenced only from their own package — tracked dead code "
           "for the serving arc")
def _w100(ctxs: list[_FileContext]) -> list[LintFinding]:
    watched = {c.module: c for c in ctxs if _watched_stub(c)}
    if not watched:
        return []
    referenced: set[str] = set()
    for ctx in ctxs:
        for mod in watched:
            if ctx.module == mod:
                continue
            pkg = mod.rsplit(".", 1)[0]
            if ctx.package == pkg or ctx.module == pkg:
                continue        # its own package (registry re-exports)
            for target in ctx.aliases.values():
                if target == mod or target.startswith(mod + "."):
                    referenced.add(mod)
                    break
    out = []
    for mod, ctx in sorted(watched.items()):
        if mod in referenced:
            continue
        out.append(LintFinding(
            "W100", ctx.path, 1, 0,
            f"seed stub {mod} is referenced only from its own package — "
            f"tracked dead code until the serving arc wires it up "
            f"(ROADMAP 'Distributed discipline')", severity="warn"))
    return out


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def iter_py_files(paths) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            if p.endswith(".py"):
                yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__",))
                for f in sorted(files):
                    if f.endswith(".py"):
                        yield os.path.join(root, f)


def _run_file_rules(ctx: _FileContext) -> list[LintFinding]:
    out = []
    for rule in FILE_RULES:
        for f in rule.fn(ctx):
            if not ctx.suppressed(f):
                out.append(f)
    return out


def lint_text(text: str, path: str = "<memory>",
              module: str | None = None) -> list[LintFinding]:
    """Lint one in-memory source file (file-level rules only)."""
    return _run_file_rules(_FileContext(path, text, module=module))


def lint_paths(paths) -> list[LintFinding]:
    """Lint files and directory trees; runs file- and tree-level rules.

    Unparseable files produce an E999 error finding instead of raising —
    a linter that dies on the first syntax error can't report the rest.
    """
    ctxs, findings = [], []
    for path in iter_py_files(paths):
        with open(path, encoding="utf-8") as fh:
            text = fh.read()
        try:
            ctxs.append(_FileContext(path, text))
        except SyntaxError as e:
            findings.append(LintFinding(
                "E999", path, e.lineno or 1, e.offset or 0,
                f"syntax error: {e.msg}"))
    for ctx in ctxs:
        findings.extend(_run_file_rules(ctx))
    for rule in TREE_RULES:
        findings.extend(rule.fn(ctxs))
    findings.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    return findings
