from .synthetic_lm import SyntheticLM  # noqa: F401
