"""Synthetic LM token pipeline (offline container — no corpora).

Sequences are generated from a sparse random Markov chain over the
vocabulary plus copy/induction segments, so cross-entropy has real,
learnable structure (loss decreases well below log V) — enough signal for
the end-to-end example runs and convergence tests.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from ..configs.base import ArchConfig


@dataclasses.dataclass
class SyntheticLM:
    vocab_size: int
    branching: int = 8           # successors per token
    induction_prob: float = 0.3  # fraction of sequence that is copied spans
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.vocab_size, 4096)   # transition table on a sub-vocab
        self.active_vocab = v
        self.successors = rng.integers(0, v, size=(v, self.branching))
        self.rng = rng

    def sample_batch(self, batch: int, seq_len: int) -> np.ndarray:
        rng = self.rng
        out = np.empty((batch, seq_len + 1), np.int32)
        for b in range(batch):
            t = int(rng.integers(0, self.active_vocab))
            seq = np.empty(seq_len + 1, np.int32)
            i = 0
            while i < seq_len + 1:
                if i > 16 and rng.random() < self.induction_prob:
                    # induction span: copy an earlier window
                    span = int(rng.integers(4, 16))
                    start = int(rng.integers(0, i - span)) if i > span \
                        else 0
                    span = min(span, seq_len + 1 - i)
                    seq[i: i + span] = seq[start: start + span]
                    i += span
                    t = int(seq[i - 1])
                else:
                    t = int(self.successors[t, rng.integers(
                        0, self.branching)])
                    seq[i] = t
                    i += 1
            out[b] = seq
        return out

    def batches(self, batch: int, seq_len: int,
                cfg: Optional[ArchConfig] = None) -> Iterator[dict]:
        """Yields {'tokens','targets'[, 'prefix']} numpy batches."""
        while True:
            seq = self.sample_batch(batch, seq_len)
            # targets[i] = tokens[i+1] (pre-shifted, same length)
            item = {"tokens": seq[:, :-1], "targets": seq[:, 1:]}
            if cfg is not None and cfg.modality:
                item["prefix"] = self.rng.normal(
                    size=(batch, cfg.num_prefix_embeddings,
                          cfg.d_model)).astype(np.float32)
            yield item
