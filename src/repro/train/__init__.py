from .loop import (make_train_step, make_loss_fn, sharded_setup,
                   batch_spec, batch_shardings)  # noqa: F401
from .state import TrainState, init_train_state  # noqa: F401
