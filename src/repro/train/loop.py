"""Distributed LM train step factory.

Builds the jitted ``train_step(state, batch) -> (state, metrics)`` with the
chosen sharding strategy applied to parameters (in_shardings) and
activations (constraint hooks inside the model).  Works identically for
real training on the host CPU (1 device) and for the 512-device dry-run
lowering (ShapeDtypeStruct inputs).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import optim
from ..configs.base import ArchConfig, InputShape
from ..models import transformer as T
from ..nn.param import split_params
from ..sharding.specs import ShardingRules, Sharder
from .state import TrainState, init_train_state


def batch_spec(cfg: ArchConfig, shape: InputShape,
               rules: ShardingRules) -> dict:
    """ShapeDtypeStructs for one global batch (dry-run input_specs)."""
    b, s = shape.global_batch, shape.seq_len
    batch = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "targets": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
    if cfg.modality:
        batch["prefix"] = jax.ShapeDtypeStruct(
            (b, cfg.num_prefix_embeddings, cfg.d_model), jnp.float32)
    return batch


def batch_shardings(cfg: ArchConfig, rules: ShardingRules,
                    mesh: Mesh) -> dict:
    d = rules.data_axes if len(rules.data_axes) > 1 else rules.data_axes[0]
    sh = {
        "tokens": NamedSharding(mesh, P(d, None)),
        "targets": NamedSharding(mesh, P(d, None)),
    }
    if cfg.modality:
        sh["prefix"] = NamedSharding(mesh, P(d, None, None))
    return sh


def make_loss_fn(cfg: ArchConfig, sharder: Optional[Sharder],
                 aux_weight: float = 0.01, remat: bool = True):
    shard = sharder if sharder is not None else (lambda x, k: x)

    def loss_fn(params, batch):
        logits, aux = T.forward(params, cfg, batch["tokens"],
                                batch.get("prefix"), shard=shard,
                                remat=remat)
        off = cfg.num_prefix_embeddings if cfg.modality else 0
        tok_logits = logits[:, off:]
        # batch["targets"] is pre-shifted: targets[i] = tokens[i+1]
        loss = T.lm_loss(tok_logits, batch["targets"])
        return loss + aux_weight * aux, (loss, aux)

    return loss_fn


def make_train_step(cfg: ArchConfig, optimizer, sharder=None,
                    aux_weight: float = 0.01, remat: bool = True,
                    donate: bool = True, in_shardings=None):
    loss_fn = make_loss_fn(cfg, sharder, aux_weight, remat)

    def train_step(state: TrainState, batch):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
        (total, (loss, aux)), grads = grad_fn(state.params, batch)
        updates, opt_state = optimizer.update(grads, state.opt_state,
                                              state.params)
        params = jax.tree.map(lambda p, u: p + u.astype(p.dtype),
                              state.params, updates)
        new_state = TrainState(params=params, opt_state=opt_state,
                               step=state.step + 1)
        metrics = {"loss": loss, "aux_loss": aux, "total_loss": total}
        return new_state, metrics

    kwargs = {}
    if in_shardings is not None:
        kwargs["in_shardings"] = in_shardings
    return jax.jit(train_step, donate_argnums=(0,) if donate else (),
                   **kwargs)


def sharded_setup(cfg: ArchConfig, shape: InputShape, mesh: Mesh,
                  rules: ShardingRules, lr: float = 3e-4,
                  sharder: Optional[Sharder] = None, remat=True):
    """Everything needed to lower (and run) a sharded train step:
    (train_step, state_shapes+shardings, batch specs+shardings)."""
    optimizer = optim.adamw(lr)
    abstract = jax.eval_shape(
        lambda k: T.init_transformer(k, cfg), jax.random.PRNGKey(0))
    p_shapes, p_names = split_params(abstract)
    p_shardings_vals = rules.param_shardings(p_names, p_shapes, mesh)
    # re-wrap to the ParamLeaf tree structure (shardings apply to .value)
    p_shardings = jax.tree.map(
        lambda leaf_sh: leaf_sh, p_shardings_vals)

    state_shapes = jax.eval_shape(
        lambda p: init_train_state(p, optimizer), abstract)
    # adam moments mirror the param tree exactly → same shardings
    rep = NamedSharding(mesh, P())
    state_shardings = TrainState(
        params=p_shardings,
        opt_state=optim.OptState(count=rep, mu=p_shardings, nu=p_shardings),
        step=rep)

    if sharder is None:
        sharder = Sharder(mesh=mesh, rules=rules)
    b_specs = batch_spec(cfg, shape, rules)
    b_shardings = batch_shardings(cfg, rules, mesh)
    step_fn = make_train_step(
        cfg, optimizer, sharder, remat=remat,
        in_shardings=(state_shardings, b_shardings))
    return dict(train_step=step_fn, optimizer=optimizer,
                state_shapes=state_shapes, state_shardings=state_shardings,
                batch_specs=b_specs, batch_shardings=b_shardings,
                sharder=sharder)
