"""Train state pytree."""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("params", "opt_state", "step"), meta_fields=())
@dataclasses.dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(params, optimizer) -> TrainState:
    return TrainState(params=params, opt_state=optimizer.init(params),
                      step=jnp.zeros((), jnp.int32))
