"""Serving engine: prefill + jitted decode steps + batched generation.

``serve_step`` (one token against a filled cache) is what the decode input
shapes (decode_32k, long_500k) lower in the dry-run.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..configs.base import ArchConfig, InputShape
from ..models import transformer as T
from ..sharding.specs import Sharder, ShardingRules


def make_serve_fns(cfg: ArchConfig, sharder=None, *,
                   long_context: bool = False, last_only: bool = False):
    """(prefill_fn, decode_fn) jit-ready closures."""
    shard = sharder if sharder is not None else (lambda x, k: x)

    def prefill_fn(params, tokens, prefix=None, *, max_len: int):
        return T.prefill(params, cfg, tokens, prefix, max_len=max_len,
                         shard=shard, long_context=long_context,
                         last_only=last_only)

    def decode_fn(params, token, caches):
        return T.decode_step(params, cfg, token, caches, shard=shard)

    return prefill_fn, decode_fn


def serve_step_spec(cfg: ArchConfig, shape: InputShape,
                    long_context: bool = False):
    """(token_spec, cache_specs) ShapeDtypeStructs for dry-run lowering of
    one decode step with a ``shape.seq_len``-deep cache."""
    b = shape.global_batch
    token = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    caches = jax.eval_shape(
        lambda: T.init_caches(cfg, b, shape.seq_len, dtype=dtype,
                              long_context=long_context))
    return token, caches


@dataclasses.dataclass
class GenerationResult:
    tokens: np.ndarray        # (B, prompt+generated)
    prefill_logits: Any


def generate(params, cfg: ArchConfig, prompt: np.ndarray, n_steps: int,
             *, prefix: Optional[np.ndarray] = None,
             temperature: float = 0.0, seed: int = 0,
             max_len: Optional[int] = None,
             long_context: bool = False) -> GenerationResult:
    """Greedy / temperature sampling for a batch of prompts (single host)."""
    b, s = prompt.shape
    off = cfg.num_prefix_embeddings if cfg.modality else 0
    max_len = max_len or (s + n_steps + off)
    prefill_fn, decode_fn = make_serve_fns(cfg, long_context=long_context)
    prefill_jit = jax.jit(partial(prefill_fn, max_len=max_len))
    decode_jit = jax.jit(decode_fn)

    logits, caches = prefill_jit(params, jnp.asarray(prompt),
                                 None if prefix is None
                                 else jnp.asarray(prefix))
    key = jax.random.PRNGKey(seed)
    last = logits[:, -1]
    out = [np.asarray(prompt)]
    tok = None
    for i in range(n_steps):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, last / temperature,
                                         axis=-1)[:, None]
        else:
            tok = jnp.argmax(last, axis=-1)[:, None]
        out.append(np.asarray(tok))
        step_logits, caches = decode_jit(params, tok.astype(jnp.int32),
                                         caches)
        last = step_logits[:, -1]
    return GenerationResult(tokens=np.concatenate(out, axis=1),
                            prefill_logits=logits)
