from .engine import make_serve_fns, serve_step_spec, generate  # noqa: F401
