"""Single-device full-graph training loop (reference + accuracy studies).

Used by the accuracy-parity benchmark (paper §5.7 / Fig. 16) to train the
coupled and decoupled variants under identical conditions, and by the
quickstart example.  Distributed training goes through
``repro.core.decouple.make_tp_train_fns`` instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from .. import optim
from ..graph.synthetic import GraphData
from . import layers as L
from . import models as M


@dataclasses.dataclass
class EpochLog:
    epoch: int
    loss: float
    train_acc: float
    val_acc: float
    test_acc: float
    seconds: float


def train_full_graph(data: GraphData, cfg: M.GNNConfig,
                     epochs: int = 100, lr: float = 1e-2,
                     weight_decay: float = 5e-4, seed: int = 0,
                     log_every: int = 10,
                     callback: Callable[[EpochLog], None] | None = None):
    """Train on the full graph; returns (params, [EpochLog])."""
    g = L.edge_list_dev(data.graph)
    x = jnp.asarray(data.features)
    labels = jnp.asarray(data.labels)
    etypes = (jnp.asarray(data.edge_types)
              if data.edge_types is not None else None)
    masks = {k: jnp.asarray(v.astype("float32")) for k, v in
             dict(train=data.train_mask, val=data.val_mask,
                  test=data.test_mask).items()}

    params = M.init_params(jax.random.PRNGKey(seed), cfg)
    opt = optim.adamw(lr, weight_decay=weight_decay)
    opt_state = opt.init(params)

    def loss_fn(p, mask):
        logits = M.forward(p, cfg, g, x, etypes)
        return M.cross_entropy(logits, labels, mask)

    @jax.jit
    def step(p, s):
        loss, grads = jax.value_and_grad(loss_fn)(p, masks["train"])
        updates, s = opt.update(grads, s, p)
        p = jax.tree.map(lambda a, u: a + u, p, updates)
        return p, s, loss

    @jax.jit
    def metrics(p):
        logits = M.forward(p, cfg, g, x, etypes)
        return tuple(M.accuracy(logits, labels, masks[k])
                     for k in ("train", "val", "test"))

    logs: list[EpochLog] = []
    for epoch in range(1, epochs + 1):
        t0 = time.perf_counter()
        params, opt_state, loss = step(params, opt_state)
        loss.block_until_ready()
        dt = time.perf_counter() - t0
        if epoch % log_every == 0 or epoch == epochs:
            tr, va, te = metrics(params)
            log = EpochLog(epoch, float(loss), float(tr), float(va),
                           float(te), dt)
            logs.append(log)
            if callback:
                callback(log)
    return params, logs
