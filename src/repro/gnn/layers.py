"""GNN aggregation / update primitives (pure jnp, device-side).

The graph lives on device as edge arrays (NamedTuple pytrees).  Aggregation
is a weighted SpMM ``out[v] = Σ_{(u,v)∈E} w_uv · h[u]`` implemented with
``segment_sum``; the TPU hot-path equivalent is the Pallas block-sparse
kernel in :mod:`repro.kernels.spmm` (same oracle).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.format import ChunkedGraph, Graph


@partial(jax.tree_util.register_dataclass,
         data_fields=("src", "dst", "weight"), meta_fields=("n",))
@dataclasses.dataclass(frozen=True)
class EdgeListDev:
    """COO edge list on device (full graph, in-edge oriented)."""
    src: jax.Array      # (E,) int32
    dst: jax.Array      # (E,) int32
    weight: jax.Array   # (E,) float32
    n: int              # static


@partial(jax.tree_util.register_dataclass,
         data_fields=("src", "dst_local", "weight", "edge_id"),
         meta_fields=("n", "chunk_size"))
@dataclasses.dataclass(frozen=True)
class ChunkedDev:
    """Chunked edges on device: leading axis scanned (paper §4.2)."""
    src: jax.Array        # (C, max_e) int32
    dst_local: jax.Array  # (C, max_e) int32 (pad = chunk_size)
    weight: jax.Array     # (C, max_e) f32 (pad = 0)
    edge_id: jax.Array    # (C, max_e) int32 (pad = E)
    n: int                # static original vertex count
    chunk_size: int       # static


def edge_list_dev(g: Graph) -> EdgeListDev:
    return EdgeListDev(src=jnp.asarray(g.src), dst=jnp.asarray(g.dst),
                       weight=jnp.asarray(g.weight), n=g.n)


def chunked_dev(cg: ChunkedGraph) -> ChunkedDev:
    return ChunkedDev(src=jnp.asarray(cg.src),
                      dst_local=jnp.asarray(cg.dst_local),
                      weight=jnp.asarray(cg.weight),
                      edge_id=jnp.asarray(cg.edge_id),
                      n=cg.n, chunk_size=cg.chunk_size)


def rechunk_edge_values(cg: ChunkedDev, values: jax.Array) -> jax.Array:
    """Map a flat per-edge vector (E,) onto the chunked layout (C, max_e);
    padding slots get 0 (numerically inert in the weighted segment-sum)."""
    ext = jnp.concatenate([values, jnp.zeros((1,), values.dtype)])
    return ext[cg.edge_id]


# ---------------------------------------------------------------------------
# Aggregation (the paper's AGG)
# ---------------------------------------------------------------------------

def aggregate(g: EdgeListDev, h: jax.Array,
              edge_weight: jax.Array | None = None) -> jax.Array:
    """Weighted in-neighbor sum: works on full features or any dim slice —
    feature-dimension slicing commutes with the SpMM (the TP property)."""
    w = g.weight if edge_weight is None else edge_weight
    msg = h[g.src] * w[:, None]
    return jax.ops.segment_sum(msg, g.dst, num_segments=h.shape[0])


def aggregate_chunked(cg: ChunkedDev, h: jax.Array,
                      edge_weight: jax.Array | None = None) -> jax.Array:
    """Chunk-scanned aggregation (paper §4.2.1): bounded working set; XLA
    double-buffers the per-chunk edge arrays HBM→VMEM."""
    cs = cg.chunk_size
    w_all = cg.weight if edge_weight is None else edge_weight

    def body(_, chunk):
        src, dst_local, w = chunk
        msg = h[src] * w[:, None]
        out = jax.ops.segment_sum(msg, dst_local, num_segments=cs + 1)
        return None, out[:cs]

    _, outs = jax.lax.scan(body, None, (cg.src, cg.dst_local, w_all))
    out = outs.reshape(-1, h.shape[1])
    return out[: h.shape[0]]


# ---------------------------------------------------------------------------
# Updates (the paper's UPDATE) and model-specific aggregators
# ---------------------------------------------------------------------------

def dense(params, x):
    return x @ params["w"] + params["b"]


def gcn_update(params, a, act=jax.nn.relu):
    return act(dense(params, a))


def sage_forward(params, g: EdgeListDev, h):
    """GraphSAGE (mean aggregator): σ(W·[h_v ‖ mean(h_u)])."""
    neigh = aggregate(g, h)  # weights pre-normalized "mean"
    return jax.nn.relu(jnp.concatenate([h, neigh], axis=-1) @ params["w"]
                       + params["b"])


def gin_forward(params, g: EdgeListDev, h, eps):
    """GIN: MLP((1+ε)·h_v + Σ h_u)."""
    agg = aggregate(g, h)  # weights must be "none" (plain sum)
    z = (1.0 + eps) * h + agg
    z = jax.nn.relu(dense(params["l0"], z))
    return dense(params["l1"], z)


def gat_edge_scores(params, h):
    """GAT per-vertex attention halves: e_uv = LeakyReLU(sl[u] + sr[v]).

    Returning the two (V,) score vectors instead of per-edge values is what
    makes the paper's edge-NN precompute cheap to share: communication is
    O(V), not O(E·D)."""
    hw = h @ params["w"]
    sl = hw @ params["a_l"]
    sr = hw @ params["a_r"]
    return hw, sl, sr


def segment_softmax(scores: jax.Array, dst: jax.Array, n: int) -> jax.Array:
    """Numerically-stable softmax over in-edge groups (grouped by dst)."""
    smax = jax.ops.segment_max(scores, dst, num_segments=n)
    ex = jnp.exp(scores - smax[dst])
    denom = jax.ops.segment_sum(ex, dst, num_segments=n)
    return ex / (denom[dst] + 1e-16)


def gat_attention(params, g: EdgeListDev, h,
                  negative_slope: float = 0.2) -> tuple[jax.Array, jax.Array]:
    """Edge attention coefficients α_uv (eq. 5) + transformed features."""
    hw, sl, sr = gat_edge_scores(params, h)
    e = jax.nn.leaky_relu(sl[g.src] + sr[g.dst], negative_slope)
    alpha = segment_softmax(e, g.dst, h.shape[0])
    return alpha, hw


def gat_forward(params, g: EdgeListDev, h):
    """Coupled single-head GAT layer (reference semantics)."""
    alpha, hw = gat_attention(params, g, h)
    agg = jax.ops.segment_sum(hw[g.src] * alpha[:, None], g.dst,
                              num_segments=h.shape[0])
    return jax.nn.elu(agg)


# ---------------------------------------------------------------------------
# R-GCN (heterogeneous graphs, paper §5.8)
# ---------------------------------------------------------------------------

def rgcn_aggregate(g: EdgeListDev, etypes: jax.Array, h: jax.Array,
                   rel_weights: jax.Array) -> jax.Array:
    """Relation-typed aggregation: out[v] += Σ_r Σ_{u∈N_r(v)} w·(h_u @ W_r).

    ``rel_weights``: (R, D, D_out).  Messages are transformed per edge type
    before summation; normalization comes from the graph weights ("mean").
    """
    msgs = h[g.src]                                 # (E, D)
    transformed = jnp.einsum("ed,rdo->ero", msgs, rel_weights)
    picked = jnp.take_along_axis(
        transformed, etypes[:, None, None], axis=1)[:, 0]
    picked = picked * g.weight[:, None]
    return jax.ops.segment_sum(picked, g.dst, num_segments=h.shape[0])


# ---------------------------------------------------------------------------
# Parameter initializers
# ---------------------------------------------------------------------------

def glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    lim = np.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, jnp.float32, -lim, lim)


def init_dense(key, d_in, d_out):
    return {"w": glorot(key, (d_in, d_out)),
            "b": jnp.zeros((d_out,), jnp.float32)}


def init_gat_layer(key, d_in, d_out):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"w": glorot(k1, (d_in, d_out)),
            "a_l": glorot(k2, (d_out,)),
            "a_r": glorot(k3, (d_out,))}
