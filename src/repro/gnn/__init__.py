from . import layers, models, dp_baseline  # noqa: F401
from .models import GNNConfig, init_params, forward  # noqa: F401
