"""GNN data parallelism baseline (DepComm, NeutronStar-style).

The comparison system for the paper's ablation (§5.4 "baseline+CS"): the
graph is partitioned into contiguous destination chunks, one per worker;
every aggregation needs the embeddings of *remote* in-neighbors, fetched by
an explicit halo exchange (dependency communication).  This is exactly the
workload whose imbalance (skewed edge counts, skewed halo sizes) motivates
tensor parallelism.

The halo exchange is a static, rectangular all-to-all built from
:func:`repro.graph.partition.halo_plan`; per-worker edge lists are padded to
the max across workers and sharded on the worker axis, so the whole model
runs inside one :func:`repro.runtime.engine` body (the repo's
version-portable shard_map entry point).

On a hybrid (data, model) mesh the partitions stay on the model axis
(halo all-to-alls unchanged) while each partition's rows additionally
shard over the data axes: the dense updates run on 1/replicas of the
rows, and the cross-replica gradient psum is the autodiff transpose of
the per-layer ``replica_gather``/``replica_slice`` pair plus the replica
loss psums.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from typing import Any

from ..core import agg as AGG
from ..graph import format as gf
from ..graph import partition as gp
from ..graph.format import Graph
from ..graph.synthetic import GraphData
from ..runtime import collectives as C
from ..runtime import constraint as K
from ..runtime import engine
from ..kernels import spmm as SP
from . import models as M


@partial(jax.tree_util.register_dataclass,
         data_fields=("send_idx_local", "recv_pos", "src", "dst", "weight",
                      "valid_rows", "bsp", "dense_adj"),
         meta_fields=("k", "m", "halo_size", "n_local_max", "e_max", "agg"))
@dataclasses.dataclass(frozen=True)
class DPGraph:
    """Per-worker partitioned graph, stacked+padded on the worker axis."""

    send_idx_local: jax.Array  # (k, k, m) int32 LOCAL row ids to send (pad -1)
    recv_pos: jax.Array        # (k, k, m) int32 halo slot (pad = halo_size)
    src: jax.Array             # (k, e_max) int32 local-coord srcs (pad 0)
    dst: jax.Array             # (k, e_max) int32 local dst (pad = n_local_max)
    weight: jax.Array          # (k, e_max) f32 (pad 0)
    valid_rows: jax.Array      # (k, n_local_max) f32 1 for real local vertices
    k: int
    m: int
    halo_size: int
    n_local_max: int
    e_max: int
    # pluggable aggregation backend (repro.core.agg): per-worker tile plans
    # ("blocksparse", stacked on the worker axis) or per-worker dense rows
    # ("dense", (k, n_local_max, n_local_max + halo_size))
    agg: str = "segment"
    bsp: Any = None
    dense_adj: Any = None


@dataclasses.dataclass(frozen=True)
class DPBundle:
    graph: DPGraph
    features: jax.Array     # (k, n_local_max, d)
    labels: jax.Array       # (k, n_local_max)
    train_mask: jax.Array   # (k, n_local_max)
    val_mask: jax.Array
    test_mask: jax.Array
    num_classes: int
    comm_rows_per_worker: np.ndarray  # analysis: rows each worker receives


def place_dp_bundle(bundle: DPBundle, mesh) -> DPBundle:
    """Commit a host-side DP bundle to ``mesh`` as global arrays: node
    arrays in the stacked (k, n_local, ·) layout (partitions on the
    model axis, rows over the data axes under a hybrid mesh), graph
    structure replicated.  The multihost counterpart of
    :func:`repro.core.decouple.place_bundle` — each process contributes
    only its local devices' shards via
    :func:`repro.runtime.distributed.put_global`."""
    from ..runtime import mesh_axes
    from ..runtime import distributed as dist
    axis, data_axes = mesh_axes(mesh)
    rows2 = _dp_row_spec(axis, data_axes, trailing=0)    # (k, n_local)
    rows3 = _dp_row_spec(axis, data_axes)                # (k, n_local, d)
    graph = jax.tree.map(lambda a: dist.put_global(a, mesh, P()),
                         bundle.graph)
    return dataclasses.replace(
        bundle, graph=graph,
        features=dist.put_global(bundle.features, mesh, rows3),
        labels=dist.put_global(bundle.labels, mesh, rows2),
        train_mask=dist.put_global(bundle.train_mask, mesh, rows2),
        val_mask=dist.put_global(bundle.val_mask, mesh, rows2),
        test_mask=dist.put_global(bundle.test_mask, mesh, rows2))


def place_dp_bundle_streamed(bundle: DPBundle, mesh, *, n_slabs: int = 4,
                             depth: int = 2) -> DPBundle:
    """Streamed drop-in for :func:`place_dp_bundle`: node arrays reach the
    mesh slab-by-slab (contiguous row ranges of every partition) through
    the double-buffered H2D prefetcher
    (:func:`repro.runtime.streaming.prefetched`), each slab's bytes
    recorded in the telemetry H2D column, with consumed buffers donated
    back to XLA.

    Honesty note: DP residency is *already* V/k rows per worker — unlike
    the TP out-of-core path (:mod:`repro.core.stream`) this does not
    shrink the steady-state footprint.  What it bounds is the *staging*
    side: no host→device transfer larger than one slab is ever in
    flight, and the placement cost shows up as measured ``h2d`` ledger
    entries instead of an invisible bulk ``device_put``.  Call with the
    host-side bundle from ``prepare_dp_bundle(mesh=None)``."""
    from jax.sharding import NamedSharding
    from ..runtime import mesh_axes
    from ..runtime import streaming as RS
    from ..runtime.mesh import as_mesh
    axis, data_axes = mesh_axes(mesh)
    amesh = as_mesh(mesh)
    # graph structure is small and replicated: one recorded staging call
    graph = RS.stage(jax.tree.map(np.asarray, bundle.graph), mesh, P(),
                     label="dp_graph")
    n_rows = bundle.graph.n_local_max
    slab = -(-n_rows // max(1, min(n_slabs, n_rows)))

    def streamed(host, spec):
        host = np.asarray(host)
        buf = RS.global_zeros(mesh, spec, host.shape, host.dtype)
        donate = ({"donate_argnums": (0,)} if RS.donation_supported()
                  else {})
        tail = (0,) * (host.ndim - 2)
        update = jax.jit(
            lambda b, s, lo: jax.lax.dynamic_update_slice(
                b, s, (0, lo) + tail),
            out_shardings=NamedSharding(amesh, spec), **donate)
        slabs = [(lo, host[:, lo:min(lo + slab, n_rows)])
                 for lo in range(0, n_rows, slab)]

        def stage_fn(item):
            lo, rows = item
            return (jnp.asarray(lo, jnp.int32),
                    RS.stage(rows, mesh, P(axis), label="dp_rows"))

        for lo_dev, slab_dev in RS.prefetched(slabs, stage_fn, depth=depth):
            buf = update(buf, slab_dev, lo_dev)
        return buf

    rows2 = _dp_row_spec(axis, data_axes, trailing=0)
    rows3 = _dp_row_spec(axis, data_axes)
    return dataclasses.replace(
        bundle, graph=graph,
        features=streamed(bundle.features, rows3),
        labels=streamed(bundle.labels, rows2),
        train_mask=streamed(bundle.train_mask, rows2),
        val_mask=streamed(bundle.val_mask, rows2),
        test_mask=streamed(bundle.test_mask, rows2))


def prepare_dp_bundle(data: GraphData, k: int | None = None,
                      balance: str = "vertex",
                      n_replicas: int | None = None,
                      mesh=None, agg: str = "segment",
                      agg_block_size: int = 128) -> DPBundle:
    """``k`` graph partitions (the model axis); under a hybrid mesh
    ``n_replicas`` pads each partition's row count so the local rows also
    shard over the data axes.

    ``agg`` selects the default aggregation backend
    (:data:`repro.core.agg.AGG_BACKENDS`): ``"blocksparse"`` builds one
    rectangular tile plan per worker (local dst rows × extended
    local+halo source rows, block size ``agg_block_size``), ``"dense"``
    the per-worker dense rows.  The segment edge lists are always built.

    ``mesh=`` derives both counts from the mesh and commits the bundle
    to it (:func:`place_dp_bundle`) — required under a multi-process
    ``jax.distributed`` job; without it the bundle stays host-local."""
    AGG.validate_backend(agg)
    if mesh is not None:
        from ..runtime import resolve_bundle_degrees
        k, n_replicas = resolve_bundle_degrees(
            mesh, k, n_replicas, caller="prepare_dp_bundle",
            worker_name="k")
    elif k is None:
        raise TypeError("prepare_dp_bundle needs k= (or mesh= to derive "
                        "it)")
    n_replicas = 1 if n_replicas is None else n_replicas
    g = data.graph
    part = gp.chunk_partition(g, k, balance=balance)
    plan = gp.halo_plan(g, part)
    n_local_max = int(plan.n_local.max())
    n_local_max = -(-n_local_max // n_replicas) * n_replicas
    e_max = max(1, max(len(s) for s in plan.local_src))

    send_local = np.full((k, k, plan.m), -1, dtype=np.int32)
    for i in range(k):
        lo = part.bounds[i]
        sel = plan.send_idx[i] >= 0
        send_local[i][sel] = plan.send_idx[i][sel] - lo

    ext = n_local_max + plan.halo_size
    src = np.zeros((k, e_max), np.int32)
    dst = np.full((k, e_max), n_local_max, np.int32)
    wgt = np.zeros((k, e_max), np.float32)
    valid = np.zeros((k, n_local_max), np.float32)
    worker_plans = [] if agg == "blocksparse" else None
    dense_rows = (np.zeros((k, n_local_max, ext), np.float32)
                  if agg == "dense" else None)
    feats = np.zeros((k, n_local_max, data.features.shape[1]), np.float32)
    labels = np.zeros((k, n_local_max), np.int32)
    masks = {name: np.zeros((k, n_local_max), np.float32)
             for name in ("train", "val", "test")}
    for i in range(k):
        e_i = len(plan.local_src[i])
        n_i = int(plan.n_local[i])
        src[i, :e_i] = plan.local_src[i]
        # clamp halo coords into the padded layout: local rows sit in
        # [0, n_local_max), halo rows in [n_local_max, n_local_max+halo)
        halo_sel = plan.local_src[i] >= n_i
        src[i, :e_i][halo_sel] += n_local_max - n_i
        dst[i, :e_i] = plan.local_dst[i]
        wgt[i, :e_i] = plan.local_w[i]
        valid[i, :n_i] = 1.0
        # per-worker aggregation plans use the same clamped coordinates
        # the segment path indexes with: dst over the padded local rows,
        # src over the extended [local | halo] rows
        if worker_plans is not None:
            worker_plans.append(gf.rect_block_sparse(
                dst[i, :e_i], src[i, :e_i], wgt[i, :e_i],
                n_rows=n_local_max, n_cols=ext, bs=agg_block_size))
        if dense_rows is not None:
            np.add.at(dense_rows[i], (dst[i, :e_i], src[i, :e_i]),
                      wgt[i, :e_i])
        lo, hi = part.bounds[i], part.bounds[i + 1]
        feats[i, :n_i] = data.features[lo:hi]
        labels[i, :n_i] = data.labels[lo:hi]
        masks["train"][i, :n_i] = data.train_mask[lo:hi]
        masks["val"][i, :n_i] = data.val_mask[lo:hi]
        masks["test"][i, :n_i] = data.test_mask[lo:hi]

    comm_rows = (plan.send_idx >= 0).sum(axis=(0, 2))
    graph = DPGraph(
        send_idx_local=jnp.asarray(send_local),
        recv_pos=jnp.asarray(plan.recv_pos),
        src=jnp.asarray(src), dst=jnp.asarray(dst), weight=jnp.asarray(wgt),
        valid_rows=jnp.asarray(valid),
        k=k, m=plan.m, halo_size=plan.halo_size,
        n_local_max=n_local_max, e_max=e_max,
        agg=agg,
        bsp=(SP.block_sparse_plan_dev(gf.stack_plans(worker_plans))
             if worker_plans is not None else None),
        dense_adj=(jnp.asarray(dense_rows)
                   if dense_rows is not None else None))
    # node arrays go straight from numpy to their global placement when
    # a mesh is given (no local-device round trip — see prepare_bundle)
    to_dev = (lambda a: a) if mesh is not None else jnp.asarray
    bundle = DPBundle(graph=graph, features=to_dev(feats),
                      labels=to_dev(labels),
                      train_mask=to_dev(masks["train"]),
                      val_mask=to_dev(masks["val"]),
                      test_mask=to_dev(masks["test"]),
                      num_classes=data.num_classes,
                      comm_rows_per_worker=comm_rows)
    return bundle if mesh is None else place_dp_bundle(bundle, mesh)


# ---------------------------------------------------------------------------
# Device-side halo exchange + aggregation (inside a runtime.engine body)
# ---------------------------------------------------------------------------

def halo_exchange(h_local: jax.Array, g: DPGraph, axis: str, *,
                  mirror: bool = True) -> jax.Array:
    """DepComm: fetch remote in-neighbor rows.  Returns (halo_size+1, D).

    ``mirror=False`` when ``h_local`` is not differentiated (layer-0
    input features) — the telemetry ledger then counts no transposed
    halo all-to-all for this call."""
    i = C.axis_index(axis)
    send_rows = g.send_idx_local[i]                      # (k, m) local ids
    take_ids = jnp.where(send_rows >= 0, send_rows, 0)
    send = jnp.take(h_local, take_ids.reshape(-1), axis=0, mode="clip")
    send = jnp.where((send_rows >= 0).reshape(-1, 1), send, 0.0)
    send = send.reshape(g.k, g.m, h_local.shape[1])
    recv = C.all_to_all(send, axis, split_axis=0, concat_axis=0,
                        mirror=mirror)
    # recv[j] = rows worker j sent me; land them in my halo buffer
    pos = g.recv_pos[i].reshape(-1)                      # (k*m,)
    halo = jnp.zeros((g.halo_size + 1, h_local.shape[1]), h_local.dtype)
    return halo.at[pos].set(recv.reshape(-1, h_local.shape[1]), mode="drop")


def dp_aggregate(h_local: jax.Array, g: DPGraph, axis: str,
                 edge_weight: jax.Array | None = None, *,
                 mirror: bool = True, agg: str = "segment") -> jax.Array:
    """One full aggregation round: halo exchange + local weighted SpMM.

    The local multiply dispatches on ``agg`` (``repro.core.agg``): the
    tile/dense backends index this worker's precomputed plan and only
    apply when no runtime ``edge_weight`` overrides the baked-in static
    weights.  The halo exchange — the only communication — is identical
    across backends."""
    i = C.axis_index(axis)
    halo = halo_exchange(h_local, g, axis, mirror=mirror)[:-1]  # drop pad
    h_ext = jnp.concatenate([h_local, halo], axis=0)
    if edge_weight is None and agg == "blocksparse":
        tiles = jax.tree.map(lambda a: a[i], g.bsp)   # this worker's plan
        return SP.aggregate_plan(tiles, h_ext)[: g.n_local_max]
    if edge_weight is None and agg == "dense":
        return g.dense_adj[i] @ h_ext
    w = g.weight[i] if edge_weight is None else edge_weight
    msg = jnp.take(h_ext, g.src[i], axis=0) * w[:, None]
    out = jax.ops.segment_sum(msg, g.dst[i],
                              num_segments=g.n_local_max + 1)
    return out[: g.n_local_max]


def dp_coupled_forward(params, cfg: M.GNNConfig, g: DPGraph, x_local,
                       axis: str = "model",
                       data_axes: tuple[str, ...] = (),
                       agg: str = "segment"):
    """Classic coupled data-parallel GNN (per-layer halo exchange).

    Hybrid DP×TP: ``x_local`` carries only this replica's block of the
    partition's rows; each layer gathers the replica shards (aggregation
    and halo exchange need every local row), then slices back so the
    dense update — the FLOPs-heavy part — runs on 1/replicas of the rows.
    All replica ops are identities for ``data_axes=()``."""
    h = x_local
    for i in range(cfg.num_layers):
        last = i == cfg.num_layers - 1
        # layer-0 moves undifferentiated input features: no transposed
        # collectives in the backward (telemetry mirror convention)
        mirror = i > 0
        h_full = C.replica_gather(h, data_axes, mirror=mirror)
        a = dp_aggregate(h_full, g, axis, mirror=mirror, agg=agg)
        a = C.replica_slice(a, data_axes)
        p = params["layers"][i]
        h = a @ p["w"] + p["b"]
        if not last:
            h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Global-view forward for the constraint backend
# ---------------------------------------------------------------------------

def _halo_exchange_constraint(h: jax.Array, g: DPGraph, axis: str, *,
                              mirror: bool = True) -> jax.Array:
    """Global-view DepComm: (k, n_local_max, D) → (k, halo_size, D).

    The explicit path's per-worker send buffers become one (k, k, m, D)
    tensor whose axis-0↔1 transpose, re-constrained onto the worker axis,
    is the halo all-to-all for XLA's partitioner to lower and schedule.
    That implied all-to-all is reported to the telemetry ledger via
    :func:`repro.runtime.constraint.note_transition` (the transposed
    array is laid out ``P(None, axis, ·, ·)`` and the constraint moves
    the worker axis back to dim 0 — a pure record, no extra anchor, so
    the lowered program is unchanged)."""
    d = h.shape[-1]
    take = jnp.where(g.send_idx_local >= 0, g.send_idx_local, 0)
    send = jax.vmap(
        lambda hj, tj: jnp.take(hj, tj.reshape(-1), axis=0, mode="clip"))(
        h, take)                                        # (k, k·m, D)
    send = jnp.where((g.send_idx_local >= 0).reshape(g.k, -1, 1), send, 0.0)
    send = K.constrain(send.reshape(g.k, g.k, g.m, d),
                       P(axis, None, None, None))       # [sender, receiver]
    recv = send.transpose(1, 0, 2, 3)                   # [receiver, sender]
    K.note_transition(recv, P(None, axis, None, None),
                      P(axis, None, None, None), mirror=mirror)
    recv = K.constrain(recv, P(axis, None, None, None))
    halo = jnp.zeros((g.k, g.halo_size + 1, d), h.dtype)
    halo = jax.vmap(lambda hb, pos, r: hb.at[pos].set(r, mode="drop"))(
        halo, g.recv_pos.reshape(g.k, -1), recv.reshape(g.k, -1, d))
    return halo[:, :-1]


def dp_coupled_forward_constraint(params, cfg: M.GNNConfig, g: DPGraph, x,
                                  axis: str = "model",
                                  data_axes: tuple[str, ...] = (),
                                  agg: str = "segment"):
    """Coupled DP-GNN in global-view semantics for
    ``engine(..., backend="constraint")``: same math as
    :func:`dp_coupled_forward` on the stacked (k, n_local_max, ·) layout
    (hybrid: the per-partition row dim is additionally anchored on the
    data axes, so the dense updates shard across replicas).

    ``agg`` dispatches the per-worker multiply: blocksparse runs the tile
    plans in a ``lax.scan`` over the worker axis (scan, not vmap — the
    Pallas call stays rank-2 and the partitioner still owns the layout),
    dense is one batched einsum; both are re-anchored by the shared
    ``K.constrain`` below, so the collective profile is unchanged."""
    row_spec = _dp_row_spec(axis, data_axes)

    def agg_one(h_ext_i, src_i, dst_i, w_i):
        msg = jnp.take(h_ext_i, src_i, axis=0) * w_i[:, None]
        return jax.ops.segment_sum(
            msg, dst_i, num_segments=g.n_local_max + 1)[: g.n_local_max]

    def aggregate(h_ext):
        if agg == "blocksparse":
            def body(_, xs):
                tiles, h_i = xs
                return None, SP.aggregate_plan(tiles, h_i)[: g.n_local_max]
            _, out = jax.lax.scan(body, None, (g.bsp, h_ext))
            return out
        if agg == "dense":
            return jnp.einsum("knm,kmd->knd", g.dense_adj, h_ext)
        return jax.vmap(agg_one)(h_ext, g.src, g.dst, g.weight)

    h = x
    for i in range(cfg.num_layers):
        h = K.constrain(h, row_spec)
        halo = _halo_exchange_constraint(h, g, axis, mirror=i > 0)
        h_ext = jnp.concatenate([h, halo], axis=1)
        a = aggregate(h_ext)
        a = K.constrain(a, row_spec)
        p = params["layers"][i]
        h = a @ p["w"] + p["b"]
        if i < cfg.num_layers - 1:
            h = jax.nn.relu(h)
    return h


def _dp_row_spec(axis: str, data_axes: tuple[str, ...],
                 trailing: int = 1) -> P:
    """Spec of the stacked DP layout (k, n_local_max, ...): partitions on
    the model axis, local rows on the data axes (hybrid) or unsharded."""
    row_entry = tuple(data_axes) if data_axes else None
    return P(axis, row_entry, *([None] * trailing))


def _make_dp_loss_and_acc(cfg: M.GNNConfig, num_classes: int, mesh,
                          axis: str, backend: str,
                          data_axes: tuple[str, ...] = (),
                          agg: str = "segment"):
    """Engine-mapped (params, g, x, labels, mask) → (loss, acc)."""
    if backend == "constraint":

        def global_loss(params, g, x, labels, mask):
            logits = dp_coupled_forward_constraint(params, cfg, g, x,
                                                   axis=axis,
                                                   data_axes=data_axes,
                                                   agg=agg)
            mask = mask * g.valid_rows
            loss_sum, correct, cnt = M.masked_loss_and_acc(
                logits, labels, mask, num_classes)
            return (loss_sum / jnp.maximum(cnt, 1.0),
                    correct / jnp.maximum(cnt, 1.0))

        body = global_loss
    else:

        def shard_loss(params, g, x_local, labels_local, mask_local):
            # sharded args arrive with a leading worker axis of size 1
            # (hybrid: and only this replica's block of the local rows)
            x_local = x_local[0]
            labels_local = labels_local[0]
            mask_local = mask_local[0]
            logits = dp_coupled_forward(params, cfg, g, x_local, axis=axis,
                                        data_axes=data_axes, agg=agg)
            valid = C.replica_slice(g.valid_rows[C.axis_index(axis)],
                                    data_axes)
            mask = mask_local * valid
            loss_sum, correct, cnt = M.masked_loss_and_acc(
                logits, labels_local, mask, num_classes)
            loss_sum = C.psum_replicas(C.psum(loss_sum, axis), data_axes)
            correct = C.psum_replicas(C.psum(correct, axis), data_axes)
            cnt = C.psum_replicas(C.psum(cnt, axis), data_axes)
            return (loss_sum / jnp.maximum(cnt, 1.0),
                    correct / jnp.maximum(cnt, 1.0))

        body = shard_loss

    return engine(
        body, mesh=mesh,
        in_specs=(P(), P(), _dp_row_spec(axis, data_axes),
                  _dp_row_spec(axis, data_axes, trailing=0),
                  _dp_row_spec(axis, data_axes, trailing=0)),
        out_specs=(P(), P()), backend=backend)


def _resolve_dp_axes(bundle: DPBundle, mesh, axis: str, data_axes):
    """Derive/validate the replica axes and the bundle's padding fit."""
    from ..runtime import data_axes_for, resolve_replicas
    if data_axes is None:
        data_axes = data_axes_for(mesh, axis)
    data_axes = tuple(data_axes)
    k, replicas = resolve_replicas(mesh, axis, data_axes)
    g = bundle.graph
    if g.k != k:
        raise ValueError(
            f"DP bundle partitioned for k={g.k} workers but mesh model "
            f"degree is {k} — re-run prepare_dp_bundle")
    if g.n_local_max % replicas:
        raise ValueError(
            f"DP bundle rows n_local_max={g.n_local_max} do not divide "
            f"the {replicas} replicas — re-run prepare_dp_bundle with "
            f"n_replicas={replicas}")
    return data_axes


def make_dp_loss_fn(cfg: M.GNNConfig, bundle: DPBundle, mesh,
                    axis: str = "model", backend: str = "explicit",
                    data_axes=None, agg: str | None = None):
    """Differentiable (params, mask) → scalar loss for a given backend.

    ``data_axes=None`` derives the replica axes from ``mesh`` (hybrid
    DP×TP); pass ``()`` to force the pure partition-parallel baseline.
    ``agg=None`` keeps the bundle's prepared aggregation backend."""
    data_axes = _resolve_dp_axes(bundle, mesh, axis, data_axes)
    agg = AGG.resolve_choice(bundle.graph, agg)
    smapped = _make_dp_loss_and_acc(cfg, bundle.num_classes, mesh, axis,
                                    backend, data_axes, agg=agg)

    def loss_fn(params, mask):
        loss, _ = smapped(params, bundle.graph, bundle.features,
                          bundle.labels, mask)
        return loss

    return loss_fn


def make_dp_value_and_grad(cfg: M.GNNConfig, bundle: DPBundle, mesh,
                           axis: str = "model", backend: str = "explicit",
                           data_axes=None, agg: str | None = None):
    """Jitted (params, mask) → (loss, grads): the multihost-safe
    value-and-grad handle (one executable per call; see
    :func:`repro.core.decouple.bundled_value_and_grad` for why eager
    autodiff is not safe on a multi-process mesh)."""
    from ..core.decouple import bundled_value_and_grad
    data_axes = _resolve_dp_axes(bundle, mesh, axis, data_axes)
    agg = AGG.resolve_choice(bundle.graph, agg)
    smapped = _make_dp_loss_and_acc(cfg, bundle.num_classes, mesh, axis,
                                    backend, data_axes, agg=agg)
    return bundled_value_and_grad(smapped, bundle.graph, bundle.features,
                                  bundle.labels)


def make_dp_train_fns(cfg: M.GNNConfig, bundle: DPBundle, mesh,
                      optimizer, axis: str = "model",
                      backend: str = "explicit", data_axes=None,
                      agg: str | None = None):
    """Jitted (train_step, evaluate) for the DP baseline (GCN).

    ``backend`` ∈ {explicit, constraint} selects the engine path;
    ``data_axes=None`` derives replica axes from ``mesh`` (hybrid DP×TP:
    partition rows shard over the data axes and the gradient psum spans
    them via the replica ops' transposes).  ``agg=None`` keeps the
    bundle's prepared aggregation backend."""
    from ..core.decouple import _bundle_masks, bundled_train_fns
    data_axes = _resolve_dp_axes(bundle, mesh, axis, data_axes)
    agg = AGG.resolve_choice(bundle.graph, agg)
    smapped = _make_dp_loss_and_acc(cfg, bundle.num_classes, mesh, axis,
                                    backend, data_axes, agg=agg)
    # bundle arrays are fed as jit ARGUMENTS, never closure constants —
    # the multihost jit discipline lives in one place (bundled_train_fns)
    return bundled_train_fns(smapped, optimizer, bundle.graph,
                             bundle.features, bundle.labels,
                             _bundle_masks(bundle))
