"""GNN model definitions: coupled (classic) and decoupled (paper §4.1).

These are the single-device reference semantics.  The distributed engines
(`repro.core.decouple` for tensor parallelism, `repro.gnn.dp_baseline` for
the data-parallel baseline) reuse the same parameter pytrees so accuracy
comparisons are apples-to-apples.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import EdgeListDev


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    model: str = "gcn"          # gcn | gat | sage | gin | rgcn
    in_dim: int = 64
    hidden_dim: int = 64
    num_classes: int = 8
    num_layers: int = 2         # L — both NN rounds and propagation rounds
    decoupled: bool = True      # paper's DT mode
    gamma: float = 1.0          # propagation edge weight γ ∈ (0,1] (§4.1.3)
    num_edge_types: int = 1     # rgcn only
    dropout: float = 0.0


def init_params(key: jax.Array, cfg: GNNConfig) -> Any:
    keys = jax.random.split(key, cfg.num_layers + 2)
    dims = ([cfg.in_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1)
            + [cfg.num_classes])
    if cfg.model == "gcn":
        return {"layers": [L.init_dense(keys[i], dims[i], dims[i + 1])
                           for i in range(cfg.num_layers)]}
    if cfg.model == "sage":
        return {"layers": [L.init_dense(keys[i], 2 * dims[i], dims[i + 1])
                           for i in range(cfg.num_layers)]}
    if cfg.model == "gin":
        return {"layers": [
            {"l0": L.init_dense(jax.random.fold_in(keys[i], 0),
                                dims[i], dims[i + 1]),
             "l1": L.init_dense(jax.random.fold_in(keys[i], 1),
                                dims[i + 1], dims[i + 1]),
             "eps": jnp.zeros(())}
            for i in range(cfg.num_layers)]}
    if cfg.model == "gat":
        return {"layers": [L.init_gat_layer(keys[i], dims[i], dims[i + 1])
                           for i in range(cfg.num_layers)]}
    if cfg.model == "rgcn":
        return {
            "rel": [L.glorot(keys[i],
                             (cfg.num_edge_types, dims[i], dims[i + 1]))
                    for i in range(cfg.num_layers)],
            "self": [L.init_dense(jax.random.fold_in(keys[i], 7),
                                  dims[i], dims[i + 1])
                     for i in range(cfg.num_layers)],
        }
    raise ValueError(cfg.model)


# ---------------------------------------------------------------------------
# Coupled forward (classic per-layer AGG→UPDATE; eqs. 1–6)
# ---------------------------------------------------------------------------

def coupled_forward(params, cfg: GNNConfig, g: EdgeListDev, x,
                    etypes: jax.Array | None = None):
    h = x
    n_layers = cfg.num_layers
    for i in range(n_layers):
        last = i == n_layers - 1
        act = (lambda v: v) if last else jax.nn.relu
        if cfg.model == "gcn":
            a = L.aggregate(g, h)
            h = L.gcn_update(params["layers"][i], a, act=act)
        elif cfg.model == "sage":
            h = L.sage_forward(params["layers"][i], g, h)
        elif cfg.model == "gin":
            p = params["layers"][i]
            h = L.gin_forward(p, g, h, p["eps"])
        elif cfg.model == "gat":
            alpha, hw = L.gat_attention(params["layers"][i], g, h)
            h = jax.ops.segment_sum(hw[g.src] * alpha[:, None], g.dst,
                                    num_segments=h.shape[0])
            h = h if last else jax.nn.elu(h)
        elif cfg.model == "rgcn":
            a = L.rgcn_aggregate(g, etypes, h, params["rel"][i])
            h = act(a + L.dense(params["self"][i], h))
        else:
            raise ValueError(cfg.model)
    return h


# ---------------------------------------------------------------------------
# Decoupled forward (paper §4.1.2): L NN rounds → L propagation rounds
# ---------------------------------------------------------------------------

def mlp_phase(params, cfg: GNNConfig, x):
    """The vertex-sharded NN phase: UPDATE applied L times (eq. 7)."""
    h = x
    n = cfg.num_layers
    if cfg.model == "gcn":
        for i, p in enumerate(params["layers"]):
            h = L.dense(p, h)
            if i < n - 1:
                h = jax.nn.relu(h)
    elif cfg.model == "sage":
        for i, p in enumerate(params["layers"]):
            # decoupled SAGE degenerates to dense on [h‖h] (self=neigh input)
            h = jnp.concatenate([h, h], axis=-1) @ p["w"] + p["b"]
            if i < n - 1:
                h = jax.nn.relu(h)
    elif cfg.model == "gin":
        for i, p in enumerate(params["layers"]):
            h = jax.nn.relu(L.dense(p["l0"], h))
            h = L.dense(p["l1"], h)
    elif cfg.model == "gat":
        for i, p in enumerate(params["layers"]):
            h = h @ p["w"]
            if i < n - 1:
                h = jax.nn.elu(h)
    elif cfg.model == "rgcn":
        for i in range(n):
            h = L.dense(params["self"][i], h)
            if i < n - 1:
                h = jax.nn.relu(h)
    else:
        raise ValueError(cfg.model)
    return h


def propagation_edge_weights(params, cfg: GNNConfig, g: EdgeListDev, h):
    """Edge weights for the propagation phase.

    GCN/SAGE/GIN: the (pre-normalized) structural weights, scaled by γ.
    GAT: the generalized decoupling — precompute attention α from the final
    embeddings (edge-associated NN op pulled in front of aggregation, §4.1.1).
    """
    if cfg.model == "gat":
        p = params["layers"][-1]
        sl = h @ p["a_l"]
        sr = h @ p["a_r"]
        e = jax.nn.leaky_relu(sl[g.src] + sr[g.dst], 0.2)
        alpha = L.segment_softmax(e, g.dst, h.shape[0])
        return cfg.gamma * alpha
    return cfg.gamma * g.weight


def decoupled_forward(params, cfg: GNNConfig, g: EdgeListDev, x,
                      etypes: jax.Array | None = None):
    """Reference (single-device) decoupled semantics: eqs. 7–9."""
    h = mlp_phase(params, cfg, x)
    w = propagation_edge_weights(params, cfg, g, h)
    z = h
    for _ in range(cfg.num_layers):
        z = L.aggregate(g, z, edge_weight=w)
    return z


def forward(params, cfg: GNNConfig, g: EdgeListDev, x,
            etypes: jax.Array | None = None):
    if cfg.decoupled:
        return decoupled_forward(params, cfg, g, x, etypes)
    return coupled_forward(params, cfg, g, x, etypes)


def masked_loss_and_acc(logits, labels, mask, num_classes):
    """Masked NLL sum, correct count, and mask count over the trailing
    class dim (padded classes beyond ``num_classes`` are nulled with a
    -1e9 offset).  Works on (V, C) and stacked (k, n_local, C) layouts;
    the distributed engines either psum the three sums per shard
    (explicit backend) or take them globally (constraint backend)."""
    c_pad = logits.shape[-1]
    if c_pad > num_classes:
        logits = logits.at[..., num_classes:].add(-1e9)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss_sum = jnp.sum(nll * mask)
    pred = jnp.argmax(logits, axis=-1)
    correct = jnp.sum((pred == labels).astype(jnp.float32) * mask)
    return loss_sum, correct, jnp.sum(mask)


def cross_entropy(logits, labels, mask):
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    mask = mask.astype(logits.dtype)
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1.0)


def accuracy(logits, labels, mask):
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32) * mask
    return correct.sum() / jnp.maximum(mask.sum(), 1.0)
