"""Out-of-core chunk streaming: host-resident features, two-buffer device.

The §4.2 chunk scheduler in :mod:`repro.core.decouple` keeps every input
device-resident and walks chunks with ``lax.scan`` — the graph must fit
on the devices.  This module is the out-of-core spelling of the same
epoch: the feature matrix and the per-chunk aggregation inputs live in
HOST numpy (:class:`repro.graph.format.HostFeatureStore`, the host-side
builders in :mod:`repro.core.chunks`), and the epoch walks them through
a double-buffered host→device prefetch
(:mod:`repro.runtime.streaming`): while the device consumes staged item
``c``, item ``c+1``'s async ``device_put`` is in flight, and consumed
buffers are donated back to XLA — so device residency of the streamed
data is bounded by TWO staged items (plus the O(V·C/N)-per-device
working buffers TP inherently needs) no matter how large V grows.

One epoch dispatches a short pipeline of jitted programs instead of one
monolithic executable:

  stripe_fwd ×S   — NN phase on feature stripes → vertex-sharded H
  split           — the paper's all-to-all (vertex- → dim-sharded)
  chunk_fwd ×L·C  — per-chunk aggregation into a donated double buffer
  loss            — gather all-to-all + masked loss (+ psums), grads
                    w.r.t. the dim-sharded embeddings by autodiff
  chunk_bwd ×L·C  — hand-written transpose of each aggregation chunk
                    (the decoupled propagation is linear in z, so the
                    backward streams Âᵀ chunks with no stored
                    activations)
  splitᵀ          — transpose of the split (operationally the gather
                    all-to-all applied to the cotangent)
  stripe_bwd ×S   — per-stripe VJP of the NN phase, accumulated into
                    the parameter grads

Telemetry: the collective schedule is byte-identical to the in-memory
UNPIPELINED decoupled epoch — one split + one gather (each with its
declared autodiff mirror) + the three loss psums.  The forward split
declares ``mirror=True`` as usual; since this driver *materializes*
that mirror itself (the splitᵀ program), the splitᵀ call is wrapped in
:func:`repro.runtime.telemetry.mirror_scope` so the bytes are not
counted twice.  Staged bytes land in the execution-time ``h2d`` ledger
column, asserted against :func:`expected_h2d_bytes`.

``decoupled_pipelined`` is accepted as an alias of ``decoupled``: the
manual §4.2.2 chunk-task interleaving exists to overlap communication
with compute, and under streaming that overlap is provided by the async
H2D prefetch instead — there is no separate program to write (the same
collapse the constraint backend documents for XLA scheduling).

Scope gates (actionable errors, not silent fallbacks): GAT (its runtime
attention needs the full embedding matrix before the split — stream the
GCN-family models, or use the in-memory path), ``mode="naive"`` (the
coupled baseline re-splits per layer; nothing to stream), and hybrid
DP×TP meshes (the streamed stripe contract is pure-TP vertex-sharded).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..gnn import models as M
from ..graph import format as gf
from ..graph.synthetic import GraphData
from ..runtime import collectives as C
from ..runtime import engine
from ..runtime import streaming as RS
from ..runtime import telemetry as T
from ..runtime.mesh import as_mesh, mesh_axes, padded_size, tp_mesh
from . import agg as AGG
from . import chunks as CH
from . import decouple as DC
from . import tp

STREAM_MODES = ("decoupled", "decoupled_pipelined")


# ---------------------------------------------------------------------------
# Host-side preparation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StreamBundle:
    """Host-resident training bundle for the out-of-core path.

    Unlike :class:`repro.core.decouple.TPBundle` this is NOT a pytree and
    never enters a traced program whole: the big members (``store``,
    ``chunked``, ``bsp``, ``dense_rows``) are host numpy that the epoch
    driver slices and stages one item at a time.  Only the O(V) label and
    mask vectors are committed to the mesh up front (vertex-sharded —
    their device footprint is V/N int32/f32 per device, not V·D)."""

    store: gf.HostFeatureStore     # (n_padded, in_dim_padded) host f32
    chunked: gf.ChunkedGraph       # host numpy per-chunk edge arrays
    bsp: gf.BlockSparsePlan | None  # host stacked tile plans | None
    dense_rows: np.ndarray | None  # (C, chunk_size, n_padded) f32 | None
    labels: jax.Array              # (n_padded,) int32, P(axis)
    train_mask: jax.Array          # (n_padded,) f32, P(axis)
    val_mask: jax.Array
    test_mask: jax.Array
    mesh: Any
    axis: str
    n: int
    n_padded: int
    n_workers: int
    n_chunks: int
    n_stripes: int
    num_classes: int
    c_padded: int
    in_dim_padded: int
    agg: str

    @property
    def chunk_size(self) -> int:
        return self.chunked.chunk_size

    @property
    def stripe_rows(self) -> int:
        return self.store.stripe_rows

    def masks(self) -> dict:
        return {"train": self.train_mask, "val": self.val_mask,
                "test": self.test_mask}


def prepare_stream_bundle(data: GraphData, mesh=None,
                          n_workers: int | None = None,
                          n_chunks: int = 4,
                          n_stripes: int | None = None,
                          agg: str = "segment",
                          agg_block_size: int = 128) -> StreamBundle:
    """Host-side prep for streaming: pad, chunk, build the host stores.

    ``n_stripes`` (default ``n_chunks``) slices the NN phase; the vertex
    dim pads to a multiple of ``n_workers · lcm(n_chunks, n_stripes)``
    so both the chunk and the stripe grids are rectangular — with the
    default it is exactly the in-memory ``prepare_bundle`` padding,
    which is what makes streamed and in-memory epochs bit-comparable.
    Graph structure and features stay in host numpy; only labels/masks
    are committed to ``mesh`` (vertex-sharded).
    """
    mesh = tp_mesh() if mesh is None else mesh
    axis, data_axes = mesh_axes(mesh)
    if data_axes:
        raise ValueError(
            f"prepare_stream_bundle: hybrid DP×TP meshes (data axes "
            f"{data_axes}) are not streamable — the stripe slicing "
            f"contract is pure-TP vertex-sharded.  Use a 1-D model mesh "
            f"(runtime.tp_mesh) or the in-memory prepare_bundle path.")
    if n_workers is None:
        n_workers = as_mesh(mesh).shape[axis]
    elif n_workers != as_mesh(mesh).shape[axis]:
        raise ValueError(
            f"prepare_stream_bundle: n_workers={n_workers} but the mesh "
            f"model degree is {as_mesh(mesh).shape[axis]}")
    n_stripes = n_chunks if n_stripes is None else n_stripes
    if n_stripes < 1 or n_chunks < 1:
        raise ValueError("n_chunks and n_stripes must be >= 1")
    AGG.validate_backend(agg)

    g = data.graph
    n_padded = padded_size(
        g.n, n_workers * math.lcm(n_chunks, n_stripes))
    gp = DC._pad_graph(g, n_padded)
    cg = gf.chunk_graph(gp, n_chunks)
    assert cg.n_chunks * cg.chunk_size == n_padded

    bsp = dense_rows = None
    if agg == "blocksparse":
        bsp = gf.chunk_block_sparse(gp, n_chunks, bs=agg_block_size)
    elif agg == "dense":
        cs = cg.chunk_size
        a = gp.dense_adjacency()
        dense_rows = np.zeros((n_chunks, cs, n_padded), np.float32)
        for c in range(n_chunks):
            lo, hi = min(gp.n, c * cs), min(gp.n, (c + 1) * cs)
            dense_rows[c, : hi - lo] = a[lo:hi]

    in_dim = data.features.shape[1]
    in_dim_padded = tp.padded_size(in_dim, n_workers)
    c_padded = tp.padded_size(data.num_classes, n_workers)

    feats = np.zeros((n_padded, in_dim_padded), np.float32)
    feats[: g.n, :in_dim] = data.features
    store = gf.HostFeatureStore(feats, n_workers, n_stripes)

    labels = np.zeros((n_padded,), np.int32)
    labels[: g.n] = data.labels

    from ..runtime import distributed as dist

    def pad_mask(m):
        out = np.zeros((n_padded,), np.float32)
        out[: g.n] = m.astype(np.float32)
        return dist.put_global(out, mesh, P(axis))

    return StreamBundle(
        store=store, chunked=cg, bsp=bsp, dense_rows=dense_rows,
        labels=dist.put_global(labels, mesh, P(axis)),
        train_mask=pad_mask(data.train_mask),
        val_mask=pad_mask(data.val_mask),
        test_mask=pad_mask(data.test_mask),
        mesh=mesh, axis=axis,
        n=g.n, n_padded=n_padded, n_workers=n_workers,
        n_chunks=n_chunks, n_stripes=n_stripes,
        num_classes=data.num_classes, c_padded=c_padded,
        in_dim_padded=in_dim_padded, agg=agg)


# ---------------------------------------------------------------------------
# H2D accounting (the analytic side of the telemetry h2d column)
# ---------------------------------------------------------------------------

def _tree_nbytes(tree) -> int:
    return sum(int(l.nbytes) for l in jax.tree.leaves(tree))


def chunk_input_nbytes(sb: StreamBundle, *, transposed: bool = False,
                       gamma: float = 1.0) -> list[int]:
    """Host bytes of each chunk's staged (forward or transposed) inputs."""
    build = CH.host_chunk_inputs_t if transposed else CH.host_chunk_inputs
    return [_tree_nbytes(build(sb.agg, c, chunked=sb.chunked, plan=sb.bsp,
                               dense_rows=sb.dense_rows, gamma=gamma))
            for c in range(sb.n_chunks)]


def expected_h2d_bytes(sb: StreamBundle, cfg: M.GNNConfig) -> int:
    """Analytic staged bytes of ONE epoch (forward + backward):

    * every feature stripe twice — once for the NN phase, once
      recomputed for the per-stripe VJP — = 2 · store bytes;
    * every chunk's forward aggregation inputs, once per round (L);
    * every chunk's transposed inputs, once per backward round (L).

    Labels/masks are committed at prepare time, not per epoch; the z/H
    buffers are allocated device-side (``global_zeros``) and never cross
    the host link.  The telemetry ``h2d`` column of a post-warmup epoch
    must equal this exactly (collectives are trace-time and already
    cached; h2d records per execution)."""
    gamma = 1.0 if cfg.model == "gat" else cfg.gamma
    return (2 * sb.store.nbytes
            + cfg.num_layers * sum(chunk_input_nbytes(sb, gamma=gamma))
            + cfg.num_layers * sum(chunk_input_nbytes(sb, transposed=True,
                                                      gamma=gamma)))


def device_resident_bytes(sb: StreamBundle, cfg: M.GNNConfig,
                          depth: int = 2) -> dict:
    """The footprint contract, itemized (bytes, whole-mesh totals):

    * ``staged_stripe`` / ``staged_chunk`` — the ≤``depth`` staged items
      alive at once (the double buffer), INDEPENDENT of V per item count;
    * ``working`` — the two (V, C_pad) embedding buffers (current +
      donated next) plus labels/masks: the O(V·C/N)-per-device state TP
      itself requires.  The bench shows staged bytes constant while V
      scales; working bytes are reported honestly, not hidden."""
    stripe = sb.store.stripe_nbytes
    fwd = max(chunk_input_nbytes(sb), default=0)
    bwd = max(chunk_input_nbytes(sb, transposed=True), default=0)
    cp = sb.c_padded
    return {
        "staged_stripe_bytes": depth * stripe,
        "staged_chunk_bytes": depth * max(fwd, bwd),
        "working_bytes": 2 * sb.n_padded * cp * 4
        + sb.n_padded * (4 + 3 * 4),
    }


# ---------------------------------------------------------------------------
# Program builders (both engine backends)
# ---------------------------------------------------------------------------

def _resolve_stream_agg(sb: StreamBundle, agg: str | None) -> str:
    if agg is None:
        return sb.agg
    AGG.validate_backend(agg)
    if agg == "blocksparse" and sb.bsp is None:
        raise ValueError(
            'agg="blocksparse" requested but the stream bundle carries '
            'no tile plans — re-run prepare_stream_bundle with '
            'agg="blocksparse"')
    if agg == "dense" and sb.dense_rows is None:
        raise ValueError(
            'agg="dense" requested but the stream bundle carries no '
            'dense rows — re-run prepare_stream_bundle with agg="dense"')
    return agg


def _check_streamable(cfg: M.GNNConfig, sb: StreamBundle,
                      mode: str) -> None:
    if cfg.model == "gat":
        raise ValueError(
            "streaming does not support GAT: its attention weights are "
            "computed at runtime from the full embedding matrix before "
            "the split (an O(V) all-gather the stripe loop cannot see), "
            "so the per-stripe NN phase is not independent.  Use the "
            "in-memory path (core.decouple) for GAT.")
    if mode not in STREAM_MODES:
        raise ValueError(
            f"stream mode must be one of {STREAM_MODES} (got {mode!r}); "
            f"the coupled 'naive' baseline re-splits every layer and has "
            f"no host-resident phase to stream — use core.decouple for "
            f"it.  'decoupled_pipelined' is an alias of 'decoupled' "
            f"here: the async H2D prefetch provides the overlap §4.2.2's "
            f"manual chunk interleaving exists for.")
    if cfg.num_classes != sb.c_padded:
        raise ValueError(
            f"cfg.num_classes={cfg.num_classes} must equal the bundle's "
            f"padded class dim {sb.c_padded} (build cfg via "
            f"stream_gnn_config / decouple.padded_gnn_config)")
    if cfg.in_dim != sb.in_dim_padded:
        raise ValueError(
            f"cfg.in_dim={cfg.in_dim} must equal the bundle's padded "
            f"input dim {sb.in_dim_padded}")


def stream_gnn_config(data: GraphData, sb: StreamBundle,
                      model: str = "gcn", hidden_dim: int = 64,
                      num_layers: int = 2,
                      gamma: float = 1.0) -> M.GNNConfig:
    """GNN config padded for the stream bundle's TP degree."""
    return M.GNNConfig(
        model=model, in_dim=sb.in_dim_padded,
        hidden_dim=tp.padded_size(hidden_dim, sb.n_workers),
        num_classes=sb.c_padded, num_layers=num_layers,
        decoupled=True, gamma=gamma)


def _maybe_donate(fn, donate: tuple, **jit_kwargs):
    """jit with buffer donation where the backend honors it (CPU does
    not — ``runtime.streaming.donation_supported``); the program is
    identical either way, only the aliasing hint differs."""
    if donate and RS.donation_supported():
        return jax.jit(fn, donate_argnums=donate, **jit_kwargs)
    return jax.jit(fn, **jit_kwargs)


def _build_programs(cfg: M.GNNConfig, sb: StreamBundle, mesh, axis: str,
                    backend: str, agg: str):
    """The seven jitted programs of one streamed epoch (module docstring).

    Only stripe_fwd/split/loss/splitT/stripe_bwd differ between engine
    backends (per-shard bodies + explicit collectives vs global-view
    bodies + layout constraints).  The per-chunk aggregation programs
    contain no collectives at all, so both backends share one jit
    spelling with the shardings carried by the operands."""
    mesh = as_mesh(mesh)
    V, N, S = sb.n_padded, sb.n_workers, sb.n_stripes
    cs, rs, Cp = sb.chunk_size, sb.stripe_rows, cfg.num_classes
    scale = 1.0 if agg == "segment" else cfg.gamma
    vspec, zspec = P(axis, None), P(None, axis)

    if backend == "explicit":
        def stripe_fwd_body(params, x_s, H, s):
            h = M.mlp_phase(params, cfg, x_s)        # (rs, Cp) this shard
            return jax.lax.dynamic_update_slice(H, h, (s * rs, 0))

        def loss_body(z, labels, mask):
            out = tp.gather(z, axis, mirror=True)    # (V/N, Cp)
            ls, cr, cnt = M.masked_loss_and_acc(out, labels, mask,
                                                sb.num_classes)
            ls, cr, cnt = (C.psum(t, axis) for t in (ls, cr, cnt))
            return ls / jnp.maximum(cnt, 1.0), cr / jnp.maximum(cnt, 1.0)

        def stripe_bwd_body(params, x_s, ct_h, s):
            ct_s = jax.lax.dynamic_slice(
                ct_h, (s * rs, 0), (rs, ct_h.shape[1]))
            _, vjp = jax.vjp(lambda p: M.mlp_phase(p, cfg, x_s), params)
            (gp,) = vjp(ct_s)
            # leading length-1 axis; out_specs P(axis) stacks the N
            # per-shard partials for the wrapper's cross-worker sum
            return jax.tree.map(lambda g: g[None], gp)

        split_fn = partial(tp.split, axis=axis, mirror=True)
        # the forward split's mirror declaration already carries these
        # bytes; the call site suppresses recording (mirror_scope)
        splitT_fn = partial(tp.gather, axis=axis, mirror=False)
        bwd_out = P(axis)
    else:
        if backend != "constraint":
            raise ValueError(
                f"stream backend must be 'explicit' or 'constraint', "
                f"got {backend!r}")

        from ..runtime import constraint as K

        def stripe_fwd_body(params, x_s, H, s):
            h = M.mlp_phase(params, cfg, x_s)        # (N·rs, Cp) global
            h = K.constrain(h, vspec)
            # stripe s is worker-major: worker i's rows sit at global
            # offset i·(V/N) + s·rs — one strided update via the
            # (N, S, rs, Cp) view, local under the vertex sharding
            H4 = jax.lax.dynamic_update_slice(
                H.reshape(N, S, rs, Cp), h.reshape(N, 1, rs, Cp),
                (0, s, 0, 0))
            return K.constrain(H4.reshape(V, Cp), vspec)

        def loss_body(z, labels, mask):
            out = tp.gather_constraint(z, axis, (), mirror=True)
            ls, cr, cnt = M.masked_loss_and_acc(out, labels, mask,
                                                sb.num_classes)
            return ls / jnp.maximum(cnt, 1.0), cr / jnp.maximum(cnt, 1.0)

        def stripe_bwd_body(params, x_s, ct_h, s):
            ct_s = jax.lax.dynamic_slice(
                ct_h.reshape(N, S, rs, Cp), (0, s, 0, 0),
                (N, 1, rs, Cp)).reshape(N * rs, Cp)
            _, vjp = jax.vjp(lambda p: M.mlp_phase(p, cfg, x_s), params)
            (gp,) = vjp(ct_s)
            return gp                                # partitioner reduces

        split_fn = partial(tp.split_constraint, axis=axis, data_axes=(),
                           mirror=True)
        splitT_fn = partial(tp.gather_constraint, axis=axis,
                            data_axes=(), mirror=False)
        bwd_out = P()

    stripe_fwd = engine(stripe_fwd_body,
                        in_specs=(P(), vspec, vspec, P()),
                        out_specs=vspec, mesh=mesh, backend=backend)
    split_p = engine(split_fn, in_specs=(vspec,), out_specs=zspec,
                     mesh=mesh, backend=backend)
    splitT_p = engine(splitT_fn, in_specs=(zspec,), out_specs=vspec,
                      mesh=mesh, backend=backend)
    lossmap = engine(loss_body, in_specs=(zspec, P(axis), P(axis)),
                     out_specs=(P(), P()), mesh=mesh, backend=backend)
    stripe_bwd = engine(stripe_bwd_body,
                        in_specs=(P(), vspec, vspec, P()),
                        out_specs=bwd_out, mesh=mesh, backend=backend)

    # --- per-chunk aggregation: collective-free, shared across backends
    def chunk_fwd_fn(z, xs, z_next, c):
        out = AGG.chunk_agg(agg, z, xs, cs, scale)   # (cs, width)
        return jax.lax.dynamic_update_slice(z_next, out, (c * cs, 0))

    def chunk_bwd_fn(ct, xs_t, g, c):
        ct_c = jax.lax.dynamic_slice(ct, (c * cs, 0), (cs, ct.shape[1]))
        if agg == "segment":
            src, dst_local, w = xs_t
            # pad edges carry dst_local == cs → the appended zero row,
            # and w == 0: numerically inert, exactly as in the forward
            ct_ext = jnp.concatenate(
                [ct_c, jnp.zeros((1, ct_c.shape[1]), ct_c.dtype)])
            msg = jnp.take(ct_ext, dst_local, axis=0) * w[:, None]
            contrib = jax.ops.segment_sum(msg, src, num_segments=V)
        elif agg == "blocksparse":
            from ..kernels import spmm as SP
            contrib = SP.aggregate_plan(xs_t, ct_c)[:V]
            contrib = contrib if scale == 1.0 else scale * contrib
        else:
            contrib = xs_t.T @ ct_c
            contrib = contrib if scale == 1.0 else scale * contrib
        return g + contrib

    zsh = NamedSharding(mesh, zspec)
    rep = NamedSharding(mesh, P())

    def scalar_loss(z, labels, mask):
        return lossmap(z, labels, mask)

    def sum_stripe_grads(params, x_s, ct_h, s, acc):
        g = stripe_bwd(params, x_s, ct_h, s)
        if backend == "explicit":
            return jax.tree.map(lambda a, st: a + jnp.sum(st, 0), acc, g)
        return jax.tree.map(lambda a, gg: a + gg, acc, g)

    return {
        "stripe_fwd": _maybe_donate(
            lambda params, x_s, H, s: stripe_fwd(params, x_s, H, s),
            donate=(2,)),
        "split": jax.jit(lambda H: split_p(H), out_shardings=zsh),
        "chunk_fwd": _maybe_donate(chunk_fwd_fn, donate=(2,)),
        "loss_vg": jax.jit(
            jax.value_and_grad(scalar_loss, has_aux=True)),
        "chunk_bwd": _maybe_donate(chunk_bwd_fn, donate=(2,)),
        "splitT": jax.jit(lambda ct: splitT_p(ct),
                          out_shardings=NamedSharding(mesh, vspec)),
        # grads come back replicated whichever backend produced the
        # per-stripe partials (the cross-worker reduction this forces is
        # the parameter-gradient all-reduce the ledger documents as out
        # of scope, matching the in-memory shard_map transpose)
        "stripe_bwd": _maybe_donate(sum_stripe_grads, donate=(4,),
                                    out_shardings=rep),
    }


# ---------------------------------------------------------------------------
# Epoch driver + public factory
# ---------------------------------------------------------------------------

def make_stream_value_and_grad(cfg: M.GNNConfig, sb: StreamBundle,
                               mesh=None, axis: str | None = None,
                               mode: str = "decoupled",
                               backend: str = "explicit",
                               agg: str | None = None):
    """Out-of-core (params, mask) → (loss, grads): the streaming analog
    of :func:`repro.core.decouple.make_tp_value_and_grad`.

    Numerics match the in-memory decoupled epoch to float tolerance and
    the collective ledger matches the UNPIPELINED in-memory one exactly
    (module docstring).  ``mask`` must be vertex-sharded on the bundle's
    mesh (use ``sb.train_mask`` etc.); ``params`` replicated.  Device
    residency: two staged stripes/chunks + the O(V·C_pad) embedding
    double buffer (``device_resident_bytes``)."""
    mesh = sb.mesh if mesh is None else mesh
    axis = sb.axis if axis is None else axis
    agg = _resolve_stream_agg(sb, agg)
    _check_streamable(cfg, sb, mode)
    progs = _build_programs(cfg, sb, mesh, axis, backend, agg)
    m = as_mesh(mesh)
    V, S, Cnk = sb.n_padded, sb.n_stripes, sb.n_chunks
    Cp = cfg.num_classes
    gamma = cfg.gamma
    vspec, zspec = P(axis, None), P(None, axis)

    def stage_stripe(s):
        return (jnp.asarray(s, jnp.int32),
                RS.stage(sb.store.stripe(s), m, vspec, label="stripe"))

    def stage_chunk(c, transposed):
        build = CH.host_chunk_inputs_t if transposed \
            else CH.host_chunk_inputs
        xs = build(agg, c, chunked=sb.chunked, plan=sb.bsp,
                   dense_rows=sb.dense_rows, gamma=gamma)
        return (jnp.asarray(c, jnp.int32),
                RS.stage(xs, m, P(),
                         label="chunk_t" if transposed else "chunk"))

    def stripes():
        return RS.prefetched(range(S), stage_stripe)

    def chunks(transposed):
        return RS.prefetched(
            range(Cnk), partial(stage_chunk, transposed=transposed))

    def value_and_grad_fn(params, mask):
        # ---- forward: NN phase over stripes, then L streamed rounds
        H = RS.global_zeros(m, vspec, (V, Cp))
        for s, x_dev in stripes():
            H = progs["stripe_fwd"](params, x_dev, H, s)
        RS.sync_for_collectives(H)
        z = progs["split"](H)
        RS.sync_for_collectives(z)
        for _ in range(cfg.num_layers):
            z_next = RS.global_zeros(m, zspec, (V, Cp))
            for c, xs_dev in chunks(transposed=False):
                z_next = progs["chunk_fwd"](z, xs_dev, z_next, c)
            z = z_next
        RS.sync_for_collectives(z)

        # ---- loss + dz by autodiff (gather a2a + psums live here)
        (loss, _acc), ct = progs["loss_vg"](z, sb.labels, mask)
        RS.sync_for_collectives(ct)

        # ---- backward: L transposed rounds, then splitᵀ, then stripes
        for _ in range(cfg.num_layers):
            g = RS.global_zeros(m, zspec, (V, Cp))
            for c, xs_dev in chunks(transposed=True):
                g = progs["chunk_bwd"](ct, xs_dev, g, c)
            ct = g
        RS.sync_for_collectives(ct)
        with T.mirror_scope():
            # materialized autodiff mirror of the forward split — its
            # bytes are already declared by the split's mirror=True
            ct_h = progs["splitT"](ct)
        RS.sync_for_collectives(ct_h)
        grads = jax.tree.map(
            lambda p: RS.global_zeros(m, P(), jnp.shape(p),
                                      jnp.result_type(p)), params)
        for s, x_dev in stripes():
            grads = progs["stripe_bwd"](params, x_dev, ct_h, s, grads)
        RS.sync_for_collectives(grads)
        return loss, grads

    return value_and_grad_fn
