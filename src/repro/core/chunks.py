"""Chunk-level communication plans + the pipelined chunk scheduler (§4.2.2).

The decoupled epoch needs one *split* (vertex-sharded → dim-sharded) before
the L aggregation rounds and one *gather* after them.  Inter-chunk
pipelining partitions those two collectives into per-chunk tasks so they can
overlap with per-chunk aggregation compute, **without** changing the bytes
moved:

* split task of chunk c  — move the feature slices of the src vertices whose
  *first use* is chunk c (the paper's dedup: a src shared by several chunks
  is communicated once, by the earliest chunk).
* gather task of chunk c — collect the complete embeddings of chunk c's
  destination vertices as soon as its last aggregation finishes.

Plans are static, rectangular (padded) index tables so each task is a single
`all_to_all`; padding rows are dropped via out-of-range scatter indices.

Telemetry: the per-chunk all-to-alls below run inside ``jax.lax.scan``
bodies that trace once but execute n_chunks× — the scan call sites in
``core/decouple.py`` wrap them in
:func:`repro.runtime.telemetry.loop_scope` so a collecting ledger counts
them trip× (cross-checked byte-for-byte against the HLO census's
while-loop trip constants by tests/dist_progs/check_telemetry.py).  Note
the padded tables mean the pipelined bytes are an upper bound on the
dedup'd ideal — the analytic-exactness asserts use the *unpipelined*
decoupled mode, where no padding is in play.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..graph.format import BlockSparsePlan, ChunkedGraph
from ..kernels import spmm as SP
from ..runtime import collectives as C


@partial(jax.tree_util.register_dataclass,
         data_fields=("split_rows", "gather_rows"),
         meta_fields=("n_workers", "n_padded", "m_split", "m_gather"))
@dataclasses.dataclass(frozen=True)
class ChunkCommPlan:
    """Static per-chunk all-to-all row tables.

    split_rows[c, i, m]  — global vertex id whose owner is worker i and whose
                           feature slices must be broadcast for chunk c
                           (pad = -1).
    gather_rows[c, i, m] — global dst vertex id (owned by worker i in the
                           vertex-sharded layout) collected after chunk c
                           (pad = -1).
    """

    split_rows: jax.Array   # (C, N, m_split) int32
    gather_rows: jax.Array  # (C, N, m_gather) int32
    n_workers: int
    n_padded: int           # padded vertex count (multiple of n_workers)
    m_split: int
    m_gather: int


def build_chunk_comm_plan(cg: ChunkedGraph, n_workers: int,
                          n_padded: int) -> ChunkCommPlan:
    shard = n_padded // n_workers
    c_rows_split: list[list[np.ndarray]] = []
    c_rows_gather: list[list[np.ndarray]] = []
    m_split, m_gather = 1, 1
    for c in range(cg.n_chunks):
        fresh = cg.new_src[c][: cg.new_src_count[c]]
        split_by_owner = [fresh[fresh // shard == i] for i in range(n_workers)]
        lo = c * cg.chunk_size
        hi = min(cg.n, (c + 1) * cg.chunk_size)
        dsts = np.arange(lo, hi, dtype=np.int32)
        gather_by_owner = [dsts[dsts // shard == i] for i in range(n_workers)]
        c_rows_split.append(split_by_owner)
        c_rows_gather.append(gather_by_owner)
        m_split = max(m_split, max(len(r) for r in split_by_owner))
        m_gather = max(m_gather, max(len(r) for r in gather_by_owner))

    def table(rows, m):
        out = np.full((cg.n_chunks, n_workers, m), -1, dtype=np.int32)
        for c, per_owner in enumerate(rows):
            for i, r in enumerate(per_owner):
                out[c, i, : len(r)] = r
        return out

    return ChunkCommPlan(
        split_rows=jnp.asarray(table(c_rows_split, m_split)),
        gather_rows=jnp.asarray(table(c_rows_gather, m_gather)),
        n_workers=n_workers, n_padded=n_padded,
        m_split=m_split, m_gather=m_gather)


# ---------------------------------------------------------------------------
# Device-side chunk collectives (inside a runtime.engine body)
# ---------------------------------------------------------------------------

def chunk_split_step(h_local: jax.Array, rows_c: jax.Array,
                     zbuf: jax.Array, axis: str) -> jax.Array:
    """Move feature slices of ``rows_c`` into the dim-sharded buffer.

    h_local : (V/N, D)   vertex-sharded embeddings (this worker's rows)
    rows_c  : (N, M)     global ids; rows_c[i] are owned by worker i (pad -1)
    zbuf    : (V, D/N)   dim-sharded destination buffer (carried by the scan)
    """
    n = C.axis_size(axis)
    i = C.axis_index(axis)
    shard = zbuf.shape[0] // n
    ds = zbuf.shape[1]
    mine = rows_c[i]                              # (M,) rows I own
    local = jnp.where(mine >= 0, mine - i * shard, 0)
    rows = jnp.take(h_local, local, axis=0, mode="clip")
    rows = jnp.where((mine >= 0)[:, None], rows, 0.0)     # (M, D)
    send = rows.reshape(rows.shape[0], n, ds).transpose(1, 0, 2)  # (N, M, Ds)
    recv = C.all_to_all(send, axis, split_axis=0, concat_axis=0,
                        mirror=True)
    # recv[j] = slices (this worker's dims) of rows owned by worker j
    ids = rows_c.reshape(-1)
    ids = jnp.where(ids >= 0, ids, zbuf.shape[0])          # pad → dropped
    return zbuf.at[ids].set(recv.reshape(-1, ds), mode="drop")


def chunk_gather_step(z_chunk: jax.Array, rows_c: jax.Array,
                      chunk_start: jax.Array, h_out: jax.Array,
                      axis: str) -> jax.Array:
    """Collect complete embeddings of chunk destinations.

    z_chunk : (chunk_size, D/N)  this chunk's aggregation output (dim slice)
    rows_c  : (N, M)             global dst ids grouped by owner (pad -1)
    h_out   : (V/N, D)           vertex-sharded output buffer
    """
    n = C.axis_size(axis)
    i = C.axis_index(axis)
    shard = h_out.shape[0]          # h_out is already the per-device shard
    ds = z_chunk.shape[1]
    # send[j] = my dim-slice of the rows worker j owns
    in_chunk = jnp.where(rows_c >= 0, rows_c - chunk_start, 0)
    send = jnp.take(z_chunk, in_chunk.reshape(-1), axis=0, mode="clip")
    send = jnp.where((rows_c >= 0).reshape(-1, 1), send, 0.0)
    send = send.reshape(n, rows_c.shape[1], ds)
    recv = C.all_to_all(send, axis, split_axis=0, concat_axis=0,
                        mirror=True)
    # recv[j] = worker j's dim-slice of MY rows → concat along features
    full = recv.transpose(1, 0, 2).reshape(rows_c.shape[1], n * ds)  # (M, D)
    mine = rows_c[i]
    ids = jnp.where(mine >= 0, mine - i * shard, h_out.shape[0])
    return h_out.at[ids].set(full, mode="drop")


# ---------------------------------------------------------------------------
# Host-side per-chunk inputs (out-of-core streaming, repro.core.stream)
# ---------------------------------------------------------------------------
#
# The in-memory chunk scan threads *device-resident* per-chunk inputs
# through ``lax.scan`` (core.agg.chunk_xs).  The out-of-core path instead
# slices one chunk's inputs out of HOST numpy, stages them, consumes them,
# and lets the buffer go — so these builders return host pytrees whose
# leaves are numpy views/copies, never device arrays.  They are the single
# place the "what does chunk c need on device" contract is written:
#
# * segment     — (src, dst_local, w) edge arrays of chunk c, with the
#                 decoupled γ baked into w (exactly what
#                 ``rechunk_edge_values`` hands the in-memory scan).
# * blocksparse — a HALF plan: a BlockSparsePlanDev carrying chunk c's
#                 forward tiles and zero-size ``*_t`` placeholders.  The
#                 streaming engine never differentiates through the
#                 kernel (it multiplies the cotangent through the
#                 transposed half plan itself), so staging the unused
#                 direction would double the H2D bytes for nothing.
# * dense       — chunk c's (chunk_size, V) adjacency rows.
#
# The backward builders return the inputs of the hand-written transpose
# of the same chunk: segment reuses the identical edge arrays (the
# transpose scatters by src instead of dst), blocksparse views the ``*_t``
# tiles as a forward plan of the transposed rectangle, dense reuses the
# rows (the transpose is ``rowsᵀ @ ct``).  Zero-size placeholder shapes
# are identical across chunks, so every staged pytree of a sweep has one
# jit signature (one trace per program, no retrace per chunk).


def _half_plan_dev(plan: BlockSparsePlan, c: int,
                   transposed: bool) -> "SP.BlockSparsePlanDev":
    """Chunk ``c`` of a stacked host plan as a single-direction device
    plan (host numpy leaves; the caller stages them)."""
    bs = plan.bs
    zero_tiles = np.zeros((0, bs, bs), np.float32)
    zero_idx = np.zeros((0,), np.int32)
    if transposed:
        # forward-run the Âᵀ tiles: out rows = the fwd plan's source side
        return SP.BlockSparsePlanDev(
            blocks=plan.blocks_t[c], block_rows=plan.block_rows_t[c],
            block_cols=plan.block_cols_t[c], row_first=plan.row_first_t[c],
            blocks_t=zero_tiles, block_rows_t=zero_idx,
            block_cols_t=zero_idx, row_first_t=zero_idx,
            n_rows=plan.n_cols, n_cols=plan.n_rows,
            rows_padded=plan.cols_padded, cols_padded=plan.rows_padded,
            bs=bs)
    return SP.BlockSparsePlanDev(
        blocks=plan.blocks[c], block_rows=plan.block_rows[c],
        block_cols=plan.block_cols[c], row_first=plan.row_first[c],
        blocks_t=zero_tiles, block_rows_t=zero_idx,
        block_cols_t=zero_idx, row_first_t=zero_idx,
        n_rows=plan.n_rows, n_cols=plan.n_cols,
        rows_padded=plan.rows_padded, cols_padded=plan.cols_padded,
        bs=bs)


def host_chunk_inputs(agg: str, c: int, *,
                      chunked: ChunkedGraph | None = None,
                      plan: BlockSparsePlan | None = None,
                      dense_rows: np.ndarray | None = None,
                      gamma: float = 1.0):
    """Host pytree of chunk ``c``'s FORWARD aggregation inputs."""
    if agg == "blocksparse":
        return _half_plan_dev(plan, c, transposed=False)
    if agg == "dense":
        return dense_rows[c]
    w = chunked.weight[c]
    return (chunked.src[c], chunked.dst_local[c],
            w if gamma == 1.0 else np.float32(gamma) * w)


def host_chunk_inputs_t(agg: str, c: int, *,
                        chunked: ChunkedGraph | None = None,
                        plan: BlockSparsePlan | None = None,
                        dense_rows: np.ndarray | None = None,
                        gamma: float = 1.0):
    """Host pytree feeding the hand-written TRANSPOSE of chunk ``c``'s
    aggregation (``ct_z += Â_cᵀ @ ct_out[c]``)."""
    if agg == "blocksparse":
        return _half_plan_dev(plan, c, transposed=True)
    if agg == "dense":
        return dense_rows[c]
    return host_chunk_inputs("segment", c, chunked=chunked, gamma=gamma)
