"""Distributed decoupled GNN tensor parallelism (paper §3 + §4.1 + §4.2).

This is the execution engine behind Algorithm 1:

  vertex-sharded NN phase (L UPDATE rounds)
    → [GAT only: data-parallel edge-attention precompute, shared O(V) scores]
    → split (all-to-all)                      ┐
    → L chunk-scanned aggregation rounds      ├ dim-sharded, zero vertex deps
    → gather (all-to-all)                     ┘
    → masked softmax loss on local vertices (+ psum)

Three execution modes:
  * ``decoupled``            — one split + one gather per epoch (paper's DT)
  * ``decoupled_pipelined``  — split/gather partitioned into per-chunk tasks
                               interleaved with aggregation (paper's DT+IP)
  * ``naive``                — coupled layers with gather/split per layer
                               (paper's "TP" baseline, Figs. 8/10)

Everything enters sharded execution through :func:`repro.runtime.engine`;
the ``mesh`` argument of :func:`make_tp_train_fns` may be a
:class:`repro.runtime.TPMesh` or a raw jax Mesh — 1-D ``("model",)`` for
the paper's pure TP, or a multi-axis ``hybrid_mesh`` for hybrid DP×TP.
Under a hybrid (data, model) / (pod, data, model) mesh the vertex
dimension shards over *every* device (``P(("model",) + data_axes)``,
model-major): the NN phase runs data-parallel across replicas, the
replica shards are gathered (``collectives.replica_gather``) into each
model worker's contiguous pure-TP block, the gather/split all-to-alls
stay on the model axis, and the loss/metric psums span
``("model",) + data_axes`` — whose autodiff transpose is exactly the
cross-replica gradient all-reduce.  Backward passes are derived by
autodiff, which emits the mirrored split/gather collectives of
Algorithm 1's lines 15–24 plus the data-axis psum-scatter.

Every mode runs on either engine backend (``backend="explicit"`` |
``"constraint"``).  The explicit backend maps the per-shard bodies below
through shard_map; the constraint backend traces the global-view
``*_constraint`` forwards under jit, where the same transitions are
sharding constraints XLA lowers to identical all-to-alls but may overlap.
Under the constraint backend ``decoupled_pipelined`` is an alias of
``decoupled``: §4.2.2's manual chunk interleaving exists to overlap comm
with compute, which is exactly the scheduling freedom the constraint
lowering hands to XLA, so there is no separate program to write.

Everything here assumes the bundle is *device-resident*: features,
chunk edge lists / tile plans, and the scan carries live on the mesh
for the whole epoch.  When the feature matrix does not fit,
:mod:`repro.core.stream` re-expresses the decoupled epoch as an
out-of-core schedule over the same math — host-resident
:class:`repro.graph.format.HostFeatureStore` + per-chunk plans, staged
through a double-buffered H2D prefetch, with byte-identical collective
ledgers to the unpipelined decoupled mode here (its equivalence tests
diff against this module's losses and grads at 1e-5).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..gnn import layers as L
from ..gnn import models as M
from ..graph import format as gf
from ..graph.synthetic import GraphData
from ..runtime import collectives as C
from ..runtime import constraint as K
from ..runtime import engine
from ..runtime import telemetry as T
from . import agg as AGG
from . import chunks as CH
from . import tp


# ---------------------------------------------------------------------------
# Host-side preparation
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("edges", "chunked", "comm_plan", "bsp", "dense_adj"),
         meta_fields=("n", "n_padded", "n_workers", "num_classes",
                      "c_padded", "in_dim_padded", "agg"))
@dataclasses.dataclass(frozen=True)
class TPGraph:
    """Replicated graph structure + comm plans (one shard_map argument)."""

    edges: L.EdgeListDev          # full graph (replicated)
    chunked: L.ChunkedDev         # chunk-scheduled view (replicated)
    comm_plan: CH.ChunkCommPlan   # per-chunk a2a tables (replicated)
    n: int
    n_padded: int
    n_workers: int
    num_classes: int
    c_padded: int                 # class dim padded to multiple of workers
    in_dim_padded: int
    # pluggable aggregation backend (repro.core.agg): "segment" needs no
    # extra data; "blocksparse" carries the per-chunk tile plans;
    # "dense" the per-chunk dense adjacency rows
    agg: str = "segment"
    bsp: Any = None               # SP.BlockSparsePlanDev | None
    dense_adj: Any = None         # (C, chunk_size, n_padded) f32 | None


@dataclasses.dataclass(frozen=True)
class TPBundle:
    """Host-side training bundle: replicated graph + sharded node arrays."""

    graph: TPGraph
    features: jax.Array           # (n_padded, in_dim_padded)
    labels: jax.Array             # (n_padded,) int32 (pad 0)
    train_mask: jax.Array         # (n_padded,) f32
    val_mask: jax.Array
    test_mask: jax.Array

    @property
    def n(self):
        return self.graph.n

    @property
    def n_padded(self):
        return self.graph.n_padded

    @property
    def n_workers(self):
        return self.graph.n_workers

    @property
    def num_classes(self):
        return self.graph.num_classes

    @property
    def c_padded(self):
        return self.graph.c_padded

    @property
    def in_dim_padded(self):
        return self.graph.in_dim_padded


def _pad_graph(g: gf.Graph, n_padded: int) -> gf.Graph:
    if n_padded == g.n:
        return g
    indptr = np.concatenate(
        [g.indptr, np.full(n_padded - g.n, g.indptr[-1], g.indptr.dtype)])
    return gf.Graph(n=n_padded, src=g.src, dst=g.dst, weight=g.weight,
                    indptr=indptr)


def place_bundle(bundle: TPBundle, mesh) -> TPBundle:
    """Commit a host-side bundle to ``mesh`` as global arrays.

    Node arrays take the vertex-sharded layout (``P(vertex_axes)`` —
    over every device under a hybrid mesh), the graph structure is
    replicated.  Under a ``jax.distributed`` job each process
    contributes only the shards its local devices hold
    (:func:`repro.runtime.distributed.put_global`), which is what lets
    the engine-mapped train steps run unchanged when no process owns
    the whole mesh.  Single-process this is a plain sharded placement.
    """
    from ..runtime import mesh_axes
    from ..runtime import distributed as dist
    axis, data_axes = mesh_axes(mesh)
    vspec = tp.vertex_spec(axis, data_axes)             # (V, ·) leading dim
    v1 = P(tp.vertex_axes(axis, data_axes))             # (V,) vectors
    rep = lambda t: jax.tree.map(                       # noqa: E731
        lambda a: dist.put_global(a, mesh, P()), t)
    return dataclasses.replace(
        bundle,
        graph=rep(bundle.graph),
        features=dist.put_global(bundle.features, mesh, vspec),
        labels=dist.put_global(bundle.labels, mesh, v1),
        train_mask=dist.put_global(bundle.train_mask, mesh, v1),
        val_mask=dist.put_global(bundle.val_mask, mesh, v1),
        test_mask=dist.put_global(bundle.test_mask, mesh, v1))


def prepare_bundle(data: GraphData, n_workers: int | None = None,
                   n_chunks: int = 4, n_replicas: int | None = None,
                   mesh=None, agg: str = "segment",
                   agg_block_size: int = 128) -> TPBundle:
    """Host-side prep.  ``n_workers`` is the model (TP) degree; under a
    hybrid mesh ``n_replicas`` is the replica-group count (``data_size``)
    so the vertex dim pads to a multiple of every device.

    ``agg`` selects the default aggregation backend
    (:data:`repro.core.agg.AGG_BACKENDS`) and builds its per-chunk data:
    tile plans of block size ``agg_block_size`` for ``"blocksparse"``,
    dense adjacency rows (O(V²) memory — small graphs) for ``"dense"``.
    The chunked segment view is always built, so loss/train factories may
    select ``agg="segment"`` on any bundle; the reverse needs re-prep.

    ``mesh=`` derives both degrees from the mesh and commits the bundle
    to it as global arrays (:func:`place_bundle`) — required under a
    multi-process ``jax.distributed`` job, where each process holds only
    a slice of the mesh and host-local arrays cannot enter the engine.
    Without a mesh the bundle stays host-local (single-process
    behaviour, unchanged)."""
    if mesh is not None:
        from ..runtime import resolve_bundle_degrees
        n_workers, n_replicas = resolve_bundle_degrees(
            mesh, n_workers, n_replicas)
    elif n_workers is None:
        raise TypeError("prepare_bundle needs n_workers= (or mesh= to "
                        "derive it)")
    n_replicas = 1 if n_replicas is None else n_replicas
    g = data.graph
    n_padded = tp.padded_size(g.n, n_workers * n_chunks * n_replicas)
    gp = _pad_graph(g, n_padded)
    cg = gf.chunk_graph(gp, n_chunks)
    assert cg.n_chunks * cg.chunk_size == n_padded
    plan = CH.build_chunk_comm_plan(cg, n_workers, n_padded)
    bsp, dense_adj = AGG.build_chunk_plans(gp, n_chunks, agg,
                                           bs=agg_block_size)

    in_dim = data.features.shape[1]
    in_dim_padded = tp.padded_size(in_dim, n_workers)
    c_padded = tp.padded_size(data.num_classes, n_workers)

    feats = np.zeros((n_padded, in_dim_padded), np.float32)
    feats[: g.n, :in_dim] = data.features
    labels = np.zeros((n_padded,), np.int32)
    labels[: g.n] = data.labels

    # with a mesh the node arrays go straight from numpy to their global
    # placement (place_bundle) — committing them to the local default
    # device first would be a wasted host→device→host round trip
    to_dev = (lambda a: a) if mesh is not None else jnp.asarray

    def pad_mask(m):
        out = np.zeros((n_padded,), np.float32)
        out[: g.n] = m.astype(np.float32)
        return to_dev(out)

    graph = TPGraph(
        edges=L.edge_list_dev(gp), chunked=L.chunked_dev(cg),
        comm_plan=plan,
        n=g.n, n_padded=n_padded, n_workers=n_workers,
        num_classes=data.num_classes, c_padded=c_padded,
        in_dim_padded=in_dim_padded,
        agg=agg, bsp=bsp, dense_adj=dense_adj)
    bundle = TPBundle(
        graph=graph,
        features=to_dev(feats), labels=to_dev(labels),
        train_mask=pad_mask(data.train_mask),
        val_mask=pad_mask(data.val_mask),
        test_mask=pad_mask(data.test_mask))
    return bundle if mesh is None else place_bundle(bundle, mesh)


def padded_gnn_config(data: GraphData, bundle: TPBundle,
                      model: str = "gcn", hidden_dim: int = 64,
                      num_layers: int = 2, decoupled: bool = True,
                      gamma: float = 1.0) -> M.GNNConfig:
    """GNN config whose dims are padded for N-way TP divisibility."""
    return M.GNNConfig(
        model=model, in_dim=bundle.in_dim_padded,
        hidden_dim=tp.padded_size(hidden_dim, bundle.n_workers),
        num_classes=bundle.c_padded, num_layers=num_layers,
        decoupled=decoupled, gamma=gamma)


# ---------------------------------------------------------------------------
# Dim-sharded propagation rounds (run on feature slices)
# ---------------------------------------------------------------------------
#
# Every round is pure per-worker compute on the feature slice — zero
# collectives — so the aggregation backend (repro.core.agg: segment /
# blocksparse / dense) dispatches *inside* the chunk scans without touching
# the split/gather schedule, the telemetry ledger, or the jaxpr audit.

def _aggregate_once(graph: TPGraph, z, agg: str, w_chunk, scale: float):
    """One full aggregation round: chunk-scan the selected backend."""
    if agg == "segment":
        return L.aggregate_chunked(graph.chunked, z, edge_weight=w_chunk)
    cs = graph.chunked.chunk_size

    def body(_, ax):
        return None, AGG.chunk_agg(agg, z, ax, cs, scale)

    _, outs = jax.lax.scan(body, None, AGG.chunk_xs(graph, agg, w_chunk))
    return outs.reshape(-1, z.shape[1])[: z.shape[0]]


def _propagate_plain(graph: TPGraph, z, w_chunk, rounds: int,
                     agg: str = "segment", scale: float = 1.0):
    for _ in range(rounds):
        z = _aggregate_once(graph, z, agg, w_chunk, scale)
    return z


def _round_split_pipelined(h_local, graph: TPGraph, w_chunk, axis: str,
                           agg: str = "segment", scale: float = 1.0):
    """First propagation round with per-chunk split interleaved (§4.2.2)."""
    cg, plan = graph.chunked, graph.comm_plan
    n = C.axis_size(axis)
    ds = h_local.shape[1] // n
    zbuf0 = jnp.zeros((plan.n_padded, ds), h_local.dtype)
    agg_xs = AGG.chunk_xs(graph, agg, w_chunk)

    def body(zbuf, xs):
        rows_c, ax = xs
        zbuf = CH.chunk_split_step(h_local, rows_c, zbuf, axis)
        out = AGG.chunk_agg(agg, zbuf, ax, cg.chunk_size, scale)
        return zbuf, out

    # the scan body traces once but runs n_chunks×; the loop_scope makes
    # the in-scan all-to-all count trip× in any collecting telemetry
    # ledger (the undercount the HLO census re-derives from while-loop
    # trip constants)
    with T.loop_scope(plan.split_rows.shape[0]):
        _, outs = jax.lax.scan(body, zbuf0, (plan.split_rows, agg_xs))
    return outs.reshape(-1, ds)[: plan.n_padded]


def _round_gather_pipelined(z, graph: TPGraph, w_chunk, d_full: int,
                            axis: str, agg: str = "segment",
                            scale: float = 1.0):
    """Last propagation round with per-chunk gather interleaved."""
    cg, plan = graph.chunked, graph.comm_plan
    n = C.axis_size(axis)
    h_out0 = jnp.zeros((plan.n_padded // n, d_full), z.dtype)
    starts = jnp.arange(plan.gather_rows.shape[0], dtype=jnp.int32) \
        * cg.chunk_size
    agg_xs = AGG.chunk_xs(graph, agg, w_chunk)

    def body(h_out, xs):
        rows_c, ax, start = xs
        out_c = AGG.chunk_agg(agg, z, ax, cg.chunk_size, scale)
        h_out = CH.chunk_gather_step(out_c, rows_c, start, h_out, axis)
        return h_out, None

    with T.loop_scope(plan.gather_rows.shape[0]):
        h_out, _ = jax.lax.scan(
            body, h_out0, (plan.gather_rows, agg_xs, starts))
    return h_out


def _round_split_gather_pipelined(h_local, graph: TPGraph, w_chunk,
                                  d_full: int, axis: str,
                                  agg: str = "segment", scale: float = 1.0):
    """Single-round case: split, aggregate, gather all chunk-interleaved."""
    cg, plan = graph.chunked, graph.comm_plan
    n = C.axis_size(axis)
    ds = h_local.shape[1] // n
    zbuf0 = jnp.zeros((plan.n_padded, ds), h_local.dtype)
    h_out0 = jnp.zeros((plan.n_padded // n, d_full), h_local.dtype)
    starts = jnp.arange(plan.gather_rows.shape[0], dtype=jnp.int32) \
        * cg.chunk_size
    agg_xs = AGG.chunk_xs(graph, agg, w_chunk)

    def body(carry, xs):
        zbuf, h_out = carry
        srows, grows, ax, start = xs
        zbuf = CH.chunk_split_step(h_local, srows, zbuf, axis)
        out_c = AGG.chunk_agg(agg, zbuf, ax, cg.chunk_size, scale)
        h_out = CH.chunk_gather_step(out_c, grows, start, h_out, axis)
        return (zbuf, h_out), None

    with T.loop_scope(plan.split_rows.shape[0]):
        (zbuf, h_out), _ = jax.lax.scan(
            body, (zbuf0, h_out0),
            (plan.split_rows, plan.gather_rows, agg_xs, starts))
    return h_out


# ---------------------------------------------------------------------------
# Edge weights for propagation (shared across workers)
# ---------------------------------------------------------------------------

def _edge_weights_tp(params, cfg: M.GNNConfig, edges: L.EdgeListDev,
                     h_local, axis: str):
    """γ·w for GCN-like models; precomputed attention α for GAT.

    The GAT path is the paper's generalized decoupling: per-vertex score
    halves are computed data-parallel (vertex-sharded), then *shared* via an
    all-gather of two (V,) vectors — O(V) communication, not O(E·D)."""
    if cfg.model == "gat":
        p = params["layers"][-1]
        sl = C.all_gather(h_local @ p["a_l"], axis, mirror=True)
        sr = C.all_gather(h_local @ p["a_r"], axis, mirror=True)
        e = jax.nn.leaky_relu(sl[edges.src] + sr[edges.dst], 0.2)
        alpha = L.segment_softmax(e, edges.dst, sl.shape[0])
        return cfg.gamma * alpha
    return cfg.gamma * edges.weight


# ---------------------------------------------------------------------------
# Forward passes (inside shard_map)
# ---------------------------------------------------------------------------

def _effective_agg(cfg: M.GNNConfig, agg: str) -> tuple[str, float]:
    """(backend, scale) actually used by a forward.

    GAT always aggregates via segment-sum: its edge weights α are computed
    at runtime from the layer's features (data-dependent), so they cannot
    be baked into the precomputed blocksparse tiles / dense rows.  For the
    tile-based backends the static γ factor of the propagation weights
    (γ·Â in ``_edge_weights_tp``) becomes a scalar post-multiplier, since
    γ·(Â@z) = (γÂ)@z."""
    if cfg.model == "gat":
        return "segment", 1.0
    return agg, cfg.gamma


def tp_decoupled_forward(params, cfg: M.GNNConfig, graph: TPGraph,
                         x_local, axis: str = "model",
                         pipelined: bool = True,
                         data_axes: tuple[str, ...] = (),
                         agg: str = "segment"):
    """Decoupled TP forward: returns vertex-sharded logits.

    Pure TP (``data_axes=()``): ``x_local`` is this model worker's
    (V/N, D) block and the result is (V/N, C_pad).  Hybrid DP×TP:
    ``x_local`` carries only this replica's rows (V/(N·R), D), the NN
    phase — the FLOPs-heavy dense part — runs on them *before* the
    replica shards are gathered into the model worker's contiguous
    block (exact: the MLP is row-wise, so it commutes with the gather),
    and the result is sliced back to this replica's (V/(N·R), C_pad)
    rows, whose autodiff transpose psum-scatters the data-axis grads.

    ``agg`` selects the aggregation backend for the propagation rounds
    (``repro.core.agg``; GAT is pinned to segment — ``_effective_agg``).
    """
    cg = graph.chunked
    agg, scale = _effective_agg(cfg, agg)
    h = M.mlp_phase(params, cfg, x_local)              # NN phase, local rows
    h = C.replica_gather(h, data_axes, mirror=True)    # (V/N, C)
    w_flat = _edge_weights_tp(params, cfg, graph.edges, h, axis)
    w_chunk = L.rechunk_edge_values(cg, w_flat)
    n_rounds = cfg.num_layers
    d_full = h.shape[1]

    if not pipelined:
        z = tp.split(h, axis, mirror=True)             # (V, C/N)
        z = _propagate_plain(graph, z, w_chunk, n_rounds, agg, scale)
        out = tp.gather(z, axis, mirror=True)          # (V/N, C)
    elif n_rounds == 1:
        out = _round_split_gather_pipelined(
            h, graph, w_chunk, d_full, axis, agg, scale)
    else:
        z = _round_split_pipelined(h, graph, w_chunk, axis, agg, scale)
        z = _propagate_plain(graph, z, w_chunk, n_rounds - 2, agg, scale) \
            if n_rounds > 2 else z
        out = _round_gather_pipelined(z, graph, w_chunk, d_full, axis,
                                      agg, scale)
    return C.replica_slice(out, data_axes)


def tp_naive_forward(params, cfg: M.GNNConfig, graph: TPGraph,
                     x_local, axis: str = "model",
                     data_axes: tuple[str, ...] = (),
                     agg: str = "segment"):
    """Coupled ("naive") TP: gather/split per layer — 2L+ collectives/epoch
    (Fig. 8's baseline).  GCN and GAT supported.

    Hybrid DP×TP: like :func:`dp_coupled_forward`, each layer keeps only
    this replica's rows between layers, gathering the replica shards
    for the graph-aggregation phase (which needs the model worker's full
    block) and slicing back before the dense update so the matmuls
    divide over every device.

    ``agg`` selects the aggregation backend for the per-layer aggregation
    (GAT layers are pinned to segment — see :func:`_effective_agg`; the
    naive mode applies no γ scaling, so ``scale=1``).
    """
    cg = graph.chunked
    agg, _ = _effective_agg(cfg, agg)
    h = x_local                                        # local rows, D feats
    n_layers = cfg.num_layers
    for i in range(n_layers):
        if cfg.model == "gat":
            p = params["layers"][i]
            hw = h @ p["w"]                            # dense on local rows
            hw = C.replica_gather(hw, data_axes, mirror=True)  # (V/N, D')
            sl = C.all_gather(hw @ p["a_l"], axis, mirror=True)
            sr = C.all_gather(hw @ p["a_r"], axis, mirror=True)
            e = jax.nn.leaky_relu(sl[graph.edges.src] + sr[graph.edges.dst],
                                  0.2)
            alpha = L.segment_softmax(e, graph.edges.dst, sl.shape[0])
            w_chunk = L.rechunk_edge_values(cg, alpha)
            z = tp.split(hw, axis, mirror=True)
            z = L.aggregate_chunked(cg, z, edge_weight=w_chunk)
            h = C.replica_slice(tp.gather(z, axis, mirror=True), data_axes)
            if i < n_layers - 1:
                h = jax.nn.elu(h)
        else:
            # layer 0 moves the *input features*, which are never
            # differentiated (the backward stops at this layer's weight
            # matmul), so autodiff emits no mirrored collectives for it —
            # mirror=False keeps the telemetry ledger byte-exact with the
            # compiled HLO (2L fwd + 2(L−1) bwd a2a per step, not 4L)
            mirror = i > 0
            hf = C.replica_gather(h, data_axes,
                                  mirror=mirror)       # (V/N, D) block
            z = tp.split(hf, axis, mirror=mirror)      # dim-sharded
            z = _aggregate_once(graph, z, agg, None, 1.0)
            a = tp.gather(z, axis, mirror=mirror)      # vertex-sharded
            a = C.replica_slice(a, data_axes)          # this replica's rows
            p = params["layers"][i]
            h = a @ p["w"] + p["b"]                    # dense on local rows
            if i < n_layers - 1:
                h = jax.nn.relu(h)
    return h


# ---------------------------------------------------------------------------
# Global-view forwards for the constraint backend
# ---------------------------------------------------------------------------

def _aggregate_chunked_constraint(graph: TPGraph, z, w_chunk, axis: str,
                                  agg: str = "segment",
                                  scale: float = 1.0):
    """Chunk-scanned aggregation with the dim-sharded layout anchored
    inside the scan body.

    Without the in-scan anchors the SPMD partitioner is free to pick its
    own shardings for the per-chunk intermediates, and in multi-layer
    programs it drifts into replicate-everything plans (all-gathers +
    "involuntary full rematerialization") that break the wire-byte parity
    with the explicit backend.  Constraints are free when already
    satisfied, so this is the same program when the partitioner behaves.

    Non-segment backends (``repro.core.agg``) run the same scan over
    their own per-chunk inputs, anchoring only the chunk output: under
    this backend the partitioner owns how the tile multiply itself is
    partitioned (the interpreter-lowered pallas_call is ordinary HLO to
    it), and the dim-sharded out anchor states the layout the engine's
    gather expects.  ``K.constrain`` records nothing, so the telemetry
    ledger stays byte-identical across backends.
    """
    cg = graph.chunked
    cs = cg.chunk_size

    if agg == "segment":
        def body(_, chunk):
            src, dst_local, w = chunk
            msg = z[src] * w[:, None]
            msg = K.constrain(msg, P(None, axis))
            out = jax.ops.segment_sum(msg, dst_local, num_segments=cs + 1)
            out = K.constrain(out, P(None, axis))
            return None, out[:cs]

        _, outs = jax.lax.scan(body, None, (cg.src, cg.dst_local, w_chunk))
    else:
        def body(_, ax):
            out = AGG.chunk_agg(agg, z, ax, cs, scale)
            return None, K.constrain(out, P(None, axis))

        _, outs = jax.lax.scan(body, None, AGG.chunk_xs(graph, agg,
                                                        w_chunk))
    outs = K.constrain(outs, P(None, None, axis))
    out = outs.reshape(-1, z.shape[1])[: z.shape[0]]
    return K.constrain(out, P(None, axis))


def _edge_weights_constraint(params, cfg: M.GNNConfig, edges: L.EdgeListDev,
                             h, axis: str):
    """Global-view analog of :func:`_edge_weights_tp`: the GAT score
    vectors are constrained replicated — the explicit backend's O(V)
    all-gather share, as a layout fact the partitioner must realize —
    before the O(E) per-edge indexing."""
    if cfg.model == "gat":
        p = params["layers"][-1]
        sl = K.constrain(h @ p["a_l"], P(None))
        sr = K.constrain(h @ p["a_r"], P(None))
        e = jax.nn.leaky_relu(sl[edges.src] + sr[edges.dst], 0.2)
        alpha = L.segment_softmax(e, edges.dst, sl.shape[0])
        return cfg.gamma * alpha
    return cfg.gamma * edges.weight


def tp_decoupled_forward_constraint(params, cfg: M.GNNConfig, graph: TPGraph,
                                    x, axis: str = "model",
                                    data_axes: tuple[str, ...] = (),
                                    agg: str = "segment"):
    """Decoupled TP forward in global-view semantics for
    ``engine(..., backend="constraint")``: same math as
    :func:`tp_decoupled_forward`, with the split/gather all-to-alls
    expressed as layout constraints.  Returns (V, C_pad) logits laid out
    vertex-sharded ``P(vertex_axes(axis, data_axes), None)`` — under a
    hybrid mesh the NN phase shards over the data axes too.  ``agg``
    dispatches inside the chunk scan (GAT pinned to segment)."""
    cg = graph.chunked
    agg, scale = _effective_agg(cfg, agg)
    vspec = tp.vertex_spec(axis, data_axes)
    h = M.mlp_phase(params, cfg, x)                    # NN phase (V, C)
    h = K.constrain(h, vspec)                          # anchor: vertex-sharded
    w_flat = _edge_weights_constraint(params, cfg, graph.edges, h, axis)
    w_chunk = L.rechunk_edge_values(cg, w_flat)
    z = tp.split_constraint(h, axis, data_axes, mirror=True)
    for _ in range(cfg.num_layers):
        z = _aggregate_chunked_constraint(graph, z, w_chunk, axis,
                                          agg, scale)
    return tp.gather_constraint(z, axis, data_axes, mirror=True)


def tp_naive_forward_constraint(params, cfg: M.GNNConfig, graph: TPGraph,
                                x, axis: str = "model",
                                data_axes: tuple[str, ...] = (),
                                agg: str = "segment"):
    """Coupled ("naive") TP in global-view semantics: gather/split
    constraints per layer — the same 2L all-to-alls per forward as
    :func:`tp_naive_forward`, scheduled by XLA (hybrid: per-layer dense
    compute shards over the data axes too).  ``agg`` dispatches inside
    the chunk scan (GAT layers pinned to segment, no γ scaling here)."""
    cg = graph.chunked
    agg, _ = _effective_agg(cfg, agg)
    vspec = tp.vertex_spec(axis, data_axes)
    h = K.constrain(x, vspec)                          # (V, D) vertex-sharded
    n_layers = cfg.num_layers
    for i in range(n_layers):
        if cfg.model == "gat":
            p = params["layers"][i]
            hw = K.constrain(h @ p["w"], vspec)
            sl = K.constrain(hw @ p["a_l"], P(None))   # O(V) score share
            sr = K.constrain(hw @ p["a_r"], P(None))
            e = jax.nn.leaky_relu(sl[graph.edges.src] + sr[graph.edges.dst],
                                  0.2)
            alpha = L.segment_softmax(e, graph.edges.dst, sl.shape[0])
            w_chunk = L.rechunk_edge_values(cg, alpha)
            z = tp.split_constraint(hw, axis, data_axes, mirror=True)
            z = _aggregate_chunked_constraint(graph, z, w_chunk, axis)
            h = tp.gather_constraint(z, axis, data_axes, mirror=True)
            if i < n_layers - 1:
                h = jax.nn.elu(h)
        else:
            # telemetry mirror convention as in tp_naive_forward: the
            # layer-0 transitions move undifferentiated input features
            mirror = i > 0
            z = tp.split_constraint(h, axis, data_axes,
                                    mirror=mirror)       # dim-sharded
            z = _aggregate_chunked_constraint(graph, z, cg.weight, axis,
                                              agg, 1.0)
            a = tp.gather_constraint(z, axis, data_axes,
                                     mirror=mirror)      # vertex-sharded
            p = params["layers"][i]
            h = a @ p["w"] + p["b"]
            if i < n_layers - 1:
                # relu spelled multiplicatively: select-form relu transposes
                # to select(mask, ct, 0) whose literal-zero branch the SPMD
                # partitioner materializes dim-sharded and re-shards — a
                # whole extra all-to-all of zeros.  h·(h>0) is the same
                # function with a multiplicative transpose (ct·mask): no
                # zero branch, and the backward matches the explicit
                # path's collective schedule byte for byte.
                h = h * (h > 0)
            h = K.constrain(h, vspec)
    return h


# ---------------------------------------------------------------------------
# Loss / metrics / train-step factory
# ---------------------------------------------------------------------------

def _resolve_data_axes(mesh, axis: str, data_axes):
    """``data_axes=None`` → derive the replica axes from the mesh (the
    strict :func:`repro.runtime.data_axes_for`); a tuple passes through."""
    from ..runtime import data_axes_for
    if data_axes is None:
        return data_axes_for(mesh, axis)
    return tuple(data_axes)


def _make_tp_loss_and_acc(cfg: M.GNNConfig, mesh, axis: str, mode: str,
                          backend: str, data_axes: tuple[str, ...] = (),
                          agg: str = "segment"):
    """Engine-mapped (params, graph, x, labels, mask) → (loss, acc).

    The one place both backends are built: per-shard body + psums under
    ``"explicit"``, global-view body + constraint forwards under
    ``"constraint"`` (identical numerics, see test_constraint_backend).
    ``data_axes`` non-empty turns either backend hybrid DP×TP: vertices
    (and labels/masks) shard over ``(axis,) + data_axes``, the NN phase
    runs on every device, and reductions span all axes.  ``agg`` is the
    aggregation backend threaded into the forwards (pure local compute —
    identical collective schedule across choices)."""
    if backend == "constraint":
        fwd_c = {
            "decoupled": tp_decoupled_forward_constraint,
            # XLA owns the comm schedule under this backend — manual chunk
            # interleaving has nothing left to pipeline (module docstring).
            "decoupled_pipelined": tp_decoupled_forward_constraint,
            "naive": tp_naive_forward_constraint,
        }[mode]

        def global_loss(params, graph, x, labels, mask):
            logits = fwd_c(params, cfg, graph, x, axis=axis,
                           data_axes=data_axes, agg=agg)
            loss_sum, correct, cnt = M.masked_loss_and_acc(
                logits, labels, mask, graph.num_classes)
            return (loss_sum / jnp.maximum(cnt, 1.0),
                    correct / jnp.maximum(cnt, 1.0))

        body = global_loss
    else:
        fwd = {
            "decoupled": partial(tp_decoupled_forward, pipelined=False),
            "decoupled_pipelined": partial(tp_decoupled_forward,
                                           pipelined=True),
            "naive": tp_naive_forward,
        }[mode]

        def shard_loss(params, graph, x_local, labels_local, mask_local):
            # hybrid: vertex rows arrive sharded over (axis,)+data_axes
            # (model-major) and the forward keeps its dense phases on
            # this replica's rows, returning replica-local logits — so
            # every vertex is scored once across the full psum and the
            # replica ops' transposes carry the data-axis grad psum.
            logits = fwd(params, cfg, graph, x_local, axis=axis,
                         data_axes=data_axes, agg=agg)
            loss_sum, correct, cnt = M.masked_loss_and_acc(
                logits, labels_local, mask_local, graph.num_classes)
            loss_sum = C.psum_replicas(C.psum(loss_sum, axis), data_axes)
            correct = C.psum_replicas(C.psum(correct, axis), data_axes)
            cnt = C.psum_replicas(C.psum(cnt, axis), data_axes)
            return (loss_sum / jnp.maximum(cnt, 1.0),
                    correct / jnp.maximum(cnt, 1.0))

        body = shard_loss

    v = tp.vertex_axes(axis, data_axes)
    return engine(
        body, mesh=mesh,
        in_specs=(P(), P(), P(v, None), P(v), P(v)),
        out_specs=(P(), P()), backend=backend)


def _check_bundle_fits(bundle: TPBundle, mesh, axis: str,
                       data_axes: tuple[str, ...]) -> None:
    """Fail early with a padding hint when the bundle was prepared for a
    different (model, data) shape than the execution will use.

    The replica count comes from the *resolved* ``data_axes``, not the
    mesh's own bookkeeping — ``data_axes=()`` on a hybrid mesh is the
    documented pure-TP escape hatch and must validate against the model
    degree alone (``validate_divisible(..., replicas=...)`` keeps the
    divisibility rule and its padding hints single-sourced)."""
    from ..runtime import TPMesh, as_mesh, resolve_replicas
    n, replicas = resolve_replicas(mesh, axis, data_axes)
    tpm = mesh if isinstance(mesh, TPMesh) else TPMesh(
        as_mesh(mesh), axis=axis)
    try:
        tpm.validate_divisible(n_vertices=bundle.n_padded,
                               dim=bundle.in_dim_padded, replicas=replicas)
    except ValueError as e:
        raise ValueError(
            f"{e} Re-run prepare_bundle with n_workers={n}, "
            f"n_replicas={replicas}.") from None
    if bundle.n_workers != n:
        raise ValueError(
            f"bundle prepared for n_workers={bundle.n_workers} but mesh "
            f"model degree is {n} — re-run prepare_bundle with the "
            f"mesh's model degree (and n_replicas={replicas})")


def make_tp_loss_fn(cfg: M.GNNConfig, bundle: TPBundle, mesh,
                    axis: str = "model", mode: str = "decoupled_pipelined",
                    backend: str = "explicit", data_axes=None, agg=None):
    """Differentiable (params, mask) → scalar loss for a given backend.

    The handle backend-equivalence tests take grads through.
    ``data_axes=None`` derives the replica axes from ``mesh`` (hybrid
    DP×TP on multi-axis meshes); pass ``()`` to force pure TP.
    ``agg=None`` uses the backend the bundle was prepared with; an
    explicit choice must be available on the bundle
    (:func:`repro.core.agg.resolve_choice`)."""
    data_axes = _resolve_data_axes(mesh, axis, data_axes)
    _check_bundle_fits(bundle, mesh, axis, data_axes)
    smapped = _make_tp_loss_and_acc(cfg, mesh, axis, mode, backend,
                                    data_axes,
                                    AGG.resolve_choice(bundle.graph, agg))

    def loss_fn(params, mask):
        loss, _ = smapped(params, bundle.graph, bundle.features,
                          bundle.labels, mask)
        return loss

    return loss_fn


def bundled_value_and_grad(smapped, graph, x, labels):
    """Jitted (params, mask) → (loss, grads) over an engine-mapped
    ``smapped(params, graph, x, labels, mask) → (loss, acc)`` — one
    executable per call, bundle arrays fed as jit arguments.

    This is the one place the multihost jit discipline for grads is
    written (used by both the TP and DP factories): eager autodiff
    dispatches the forward and transposed backward as *separate*
    in-flight executables, and on a multi-process mesh concurrently
    in-flight executables race their collectives on the shared
    cross-process transport (observed as gloo ``op.preamble.length <=
    op.nbytes`` aborts on the forced-host CPU topology).  Jitting the
    whole value-and-grad keeps every collective inside one executable,
    where XLA orders them; argument (not closure) feeding is required
    for the same reason as in :func:`bundled_train_fns`.
    """
    @jax.jit
    def _vg(params, graph, x, labels, mask):
        def loss_fn(p):
            loss, _ = smapped(p, graph, x, labels, mask)
            return loss

        return jax.value_and_grad(loss_fn)(params)

    def value_and_grad_fn(params, mask):
        return _vg(params, graph, x, labels, mask)

    return value_and_grad_fn


def bundled_train_fns(smapped, optimizer, graph, x, labels, masks):
    """Jitted (train_step, evaluate) over an engine-mapped ``smapped``
    — the shared back half of :func:`make_tp_train_fns` and
    :func:`repro.gnn.dp_baseline.make_dp_train_fns`.

    The bundle's arrays enter the jitted steps as ARGUMENTS, not
    closure constants: under a multi-process mesh a traced function may
    not close over arrays spanning non-addressable devices (each
    process holds only its local shards), and argument passing is also
    what keeps the data host-feedable — the jit cache keys on shape,
    not identity, so the public (params, opt_state) signature below
    costs nothing single-process.  ``masks`` maps split name
    ("train"/"val"/"test") to its mask array.
    """
    @jax.jit
    def _step(params, opt_state, graph, x, labels, mask):
        def loss_fn(p):
            loss, _ = smapped(p, graph, x, labels, mask)
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
        return params, opt_state, loss

    def train_step(params, opt_state):
        return _step(params, opt_state, graph, x, labels, masks["train"])

    # benches/telemetry wrap the first trace: keep .lower() reachable
    train_step.lower = lambda params, opt_state: _step.lower(
        params, opt_state, graph, x, labels, masks["train"])

    @jax.jit
    def _eval(params, graph, x, labels, mask):
        return smapped(params, graph, x, labels, mask)

    def evaluate(params, split: str = "val"):
        return _eval(params, graph, x, labels, masks[split])

    return train_step, evaluate


def _bundle_masks(bundle) -> dict:
    return {"train": bundle.train_mask, "val": bundle.val_mask,
            "test": bundle.test_mask}


def make_tp_value_and_grad(cfg: M.GNNConfig, bundle: TPBundle, mesh,
                           axis: str = "model",
                           mode: str = "decoupled_pipelined",
                           backend: str = "explicit", data_axes=None,
                           agg=None):
    """Jitted (params, mask) → (loss, grads) — the multihost-safe
    spelling of ``jax.value_and_grad(make_tp_loss_fn(...))`` (one
    executable per call; see :func:`bundled_value_and_grad` for why
    eager autodiff is not safe on a multi-process mesh).  ``agg=None``
    uses the bundle's prepared aggregation backend."""
    data_axes = _resolve_data_axes(mesh, axis, data_axes)
    _check_bundle_fits(bundle, mesh, axis, data_axes)
    smapped = _make_tp_loss_and_acc(cfg, mesh, axis, mode, backend,
                                    data_axes,
                                    AGG.resolve_choice(bundle.graph, agg))
    return bundled_value_and_grad(smapped, bundle.graph, bundle.features,
                                  bundle.labels)


def make_tp_train_fns(cfg: M.GNNConfig, bundle: TPBundle, mesh,
                      optimizer, axis: str = "model",
                      mode: str = "decoupled_pipelined",
                      backend: str = "explicit", data_axes=None, agg=None):
    """Build jitted (train_step, eval_fn) for TP training.

    ``mode`` ∈ {decoupled, decoupled_pipelined, naive};
    ``backend`` ∈ {explicit, constraint} selects the engine path.
    Params are replicated; activations/labels are vertex-sharded on
    ``axis`` — or over ``(axis,) + data_axes`` under a hybrid mesh
    (``data_axes=None`` derives them from ``mesh``), in which case the
    gradient all-reduce over the data axes is the autodiff transpose of
    the replica psums/gathers in the loss body.  ``agg=None`` uses the
    bundle's prepared aggregation backend (``repro.core.agg``).
    """
    data_axes = _resolve_data_axes(mesh, axis, data_axes)
    _check_bundle_fits(bundle, mesh, axis, data_axes)
    smapped = _make_tp_loss_and_acc(cfg, mesh, axis, mode, backend,
                                    data_axes,
                                    AGG.resolve_choice(bundle.graph, agg))
    return bundled_train_fns(smapped, optimizer, bundle.graph,
                             bundle.features, bundle.labels,
                             _bundle_masks(bundle))
