# The paper's primary contribution: GNN tensor parallelism (feature-dim
# sharding + gather/split all-to-alls), the generalized decoupled training
# engine, and the chunk-based task scheduler with inter-chunk pipelining.
from . import tp, chunks, decouple, stream  # noqa: F401
from .stream import (StreamBundle, prepare_stream_bundle,
                     make_stream_value_and_grad,
                     stream_gnn_config)  # noqa: F401
from .decouple import (TPBundle, TPGraph, prepare_bundle, padded_gnn_config,
                       make_tp_loss_fn, make_tp_train_fns,
                       make_tp_value_and_grad,
                       tp_decoupled_forward, tp_decoupled_forward_constraint,
                       tp_naive_forward,
                       tp_naive_forward_constraint)  # noqa: F401
