"""GNN tensor parallelism: the gather/split layout collectives (paper §3.1).

Two activation layouts exist for an (V, D) embedding matrix on an N-way
tensor-parallel axis:

* **vertex-sharded**  ``(V/N, D)`` per device — NN (UPDATE) phase layout;
  complete feature vectors, a 1/N share of vertices.
* **dim-sharded**     ``(V, D/N)`` per device — graph-aggregation phase
  layout; complete vertex set, a 1/N slice of features.

``split``  : vertex-sharded → dim-sharded   (paper's "split")
``gather`` : dim-sharded  → vertex-sharded  (paper's "gather")

Both are single all-to-all collectives moving ``V·D/N`` elements per
device regardless of graph topology — the paper's load-balance argument.
Each transition exists in two spellings, one per engine backend:

* :func:`split` / :func:`gather` — explicit all-to-alls from
  :mod:`repro.runtime.collectives`; must run inside a per-shard body
  entered via ``runtime.engine(..., backend="explicit")`` (or
  :func:`repro.runtime.smap`) with ``axis`` bound on the mesh.
* :func:`split_constraint` / :func:`gather_constraint` — the same
  transitions as layout re-shardings (``P(axis, None) ↔ P(None, axis)``)
  for global-view bodies traced by ``runtime.engine(...,
  backend="constraint")`` (:mod:`repro.runtime.constraint`).  XLA lowers
  each to an identical all-to-all HLO — same wire bytes, verified by
  ``benchmarks.bench_comm_volume`` — but is free to schedule and overlap
  it with compute.

On TPU the all-to-all runs over ICI instead of NCCL/Ethernet.

Hybrid DP×TP (multi-axis meshes) adds a third layout, **vertex-sharded
over every device** — ``P((axis,) + data_axes)`` on the vertex dim,
model-major (:func:`vertex_axes` / :func:`vertex_spec`) — used by the NN
phase so its dense compute also divides over the replica axes.  The
transitions into/out of it are the replica ops
(:func:`repro.runtime.collectives.replica_gather` /
``replica_slice``) on the explicit backend and the staged
``data → model → dim`` constraint hops here on the constraint backend;
the paper's gather/split all-to-alls always stay on the model axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime import collectives as C
from ..runtime import constraint as K
from ..runtime.mesh import padded_size  # noqa: F401  (canonical home)


def vertex_axes(axis: str = "model",
                data_axes: tuple[str, ...] = ()):
    """The mesh axes the vertex dimension shards over.

    Pure TP: just ``axis``.  Hybrid DP×TP: ``(axis,) + data_axes`` —
    model-major, so gathering the replica shards back together
    (:func:`repro.runtime.collectives.replica_gather`) reconstructs each
    model worker's contiguous pure-TP vertex block.
    """
    return (axis,) + tuple(data_axes) if data_axes else axis


def vertex_spec(axis: str = "model", data_axes: tuple[str, ...] = (),
                trailing: int = 1) -> P:
    """PartitionSpec of the vertex-sharded layout: the leading (vertex)
    dim over :func:`vertex_axes`, ``trailing`` unsharded dims after it."""
    return P(vertex_axes(axis, data_axes), *([None] * trailing))


def split(h: jax.Array, axis: str = "model", *,
          mirror: bool = True) -> jax.Array:
    """vertex-sharded (V/N, D) → dim-sharded (V, D/N).

    ``mirror=False`` tells the telemetry ledger that ``h`` carries no
    gradient (e.g. the coupled forward's layer-0 input features), so
    autodiff emits no transposed all-to-all here."""
    return C.all_to_all(h, axis, split_axis=1, concat_axis=0, tiled=True,
                        mirror=mirror)


def gather(z: jax.Array, axis: str = "model", *,
           mirror: bool = True) -> jax.Array:
    """dim-sharded (V, D/N) → vertex-sharded (V/N, D)."""
    return C.all_to_all(z, axis, split_axis=0, concat_axis=1, tiled=True,
                        mirror=mirror)


def split_constraint(h: jax.Array, axis: str = "model",
                     data_axes: tuple[str, ...] = (), *,
                     mirror: bool = True) -> jax.Array:
    """Constraint-backend split: global (V, D) re-laid P(axis,·) → P(·,axis).

    Must run inside a body traced by ``runtime.engine(...,
    backend="constraint")``; a no-op outside one (single-device reference).
    Both sides of the transition are anchored so the transposed constraint
    pair reshards the cotangent exactly where autodiff of the explicit
    :func:`split` puts its mirrored all-to-all (see
    :func:`repro.runtime.constraint.layout_cast`).

    Under hybrid DP×TP the source layout also shards vertices over the
    ``data_axes`` (the NN phase runs on every device).  The transition is
    staged through the model-only vertex layout — first the data-axis
    all-gather (replica shards rejoin, same dim), then the known
    vertex↔dim all-to-all — because the SPMD partitioner cannot lower the
    combined ``P((axis,)+data, ·) → P(·, axis)`` hop in one step and
    falls back to involuntary full rematerialization.  This mirrors the
    explicit backend's replica_gather + split exactly.
    """
    if data_axes:
        h = K.layout_cast(h, P(axis, None),
                          src_spec=vertex_spec(axis, data_axes),
                          mirror=mirror)
    return K.layout_cast(h, P(None, axis), src_spec=P(axis, None),
                         mirror=mirror)


def gather_constraint(z: jax.Array, axis: str = "model",
                      data_axes: tuple[str, ...] = (), *,
                      mirror: bool = True) -> jax.Array:
    """Constraint-backend gather: global (V, D) re-laid P(·,axis) → P(axis,·)
    (hybrid: staged on to the full ``P((axis,)+data_axes, ·)`` vertex
    layout — the mirrored dynamic-slice of the explicit backend's
    replica_slice, see :func:`split_constraint` for why two hops)."""
    z = K.layout_cast(z, P(axis, None), src_spec=P(None, axis),
                      mirror=mirror)
    if data_axes:
        z = K.layout_cast(z, vertex_spec(axis, data_axes),
                          src_spec=P(axis, None), mirror=mirror)
    return z


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0) -> jax.Array:
    """Pad ``axis`` up to a multiple (vertex count and feature dim must both
    divide by the TP degree for rectangular all-to-alls)."""
    size = x.shape[axis]
    target = padded_size(size, multiple)
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad)


def local_slice(n: int, axis: str = "model") -> tuple[jax.Array, jax.Array]:
    """(start, size) of this device's vertex range in vertex-sharded layout."""
    idx = C.axis_index(axis)
    num = C.axis_size(axis)
    size = n // num
    return idx * size, size
