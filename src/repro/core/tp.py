"""GNN tensor parallelism: the gather/split layout collectives (paper §3.1).

Two activation layouts exist for an (V, D) embedding matrix on an N-way
tensor-parallel axis:

* **vertex-sharded**  ``(V/N, D)`` per device — NN (UPDATE) phase layout;
  complete feature vectors, a 1/N share of vertices.
* **dim-sharded**     ``(V, D/N)`` per device — graph-aggregation phase
  layout; complete vertex set, a 1/N slice of features.

``split``  : vertex-sharded → dim-sharded   (paper's "split")
``gather`` : dim-sharded  → vertex-sharded  (paper's "gather")

Both are single all-to-all collectives moving ``V·D/N`` elements per
device regardless of graph topology — the paper's load-balance argument.
These functions must run inside a body entered via
:func:`repro.runtime.engine` (or :func:`repro.runtime.smap`) with ``axis``
bound on the mesh; the collectives themselves come from
:mod:`repro.runtime.collectives`, the repo's single communication layer.

On TPU the all-to-all runs over ICI instead of NCCL/Ethernet; under ``pjit``
the same transition can be expressed as a sharding constraint
``P(None, axis) → P(axis, None)`` which lowers to an identical all-to-all HLO
(used by the fused "beyond-paper" path so XLA may overlap it).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..runtime import collectives as C
from ..runtime.mesh import padded_size  # noqa: F401  (canonical home)


def split(h: jax.Array, axis: str = "model") -> jax.Array:
    """vertex-sharded (V/N, D) → dim-sharded (V, D/N)."""
    return C.all_to_all(h, axis, split_axis=1, concat_axis=0, tiled=True)


def gather(z: jax.Array, axis: str = "model") -> jax.Array:
    """dim-sharded (V, D/N) → vertex-sharded (V/N, D)."""
    return C.all_to_all(z, axis, split_axis=0, concat_axis=1, tiled=True)


def pad_to_multiple(x: jax.Array, multiple: int, axis: int = 0) -> jax.Array:
    """Pad ``axis`` up to a multiple (vertex count and feature dim must both
    divide by the TP degree for rectangular all-to-alls)."""
    size = x.shape[axis]
    target = padded_size(size, multiple)
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad)


def local_slice(n: int, axis: str = "model") -> tuple[jax.Array, jax.Array]:
    """(start, size) of this device's vertex range in vertex-sharded layout."""
    idx = C.axis_index(axis)
    num = C.axis_size(axis)
    size = n // num
    return idx * size, size
