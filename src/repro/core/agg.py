"""Pluggable aggregation backends for the TP and DP engines.

The per-worker compute hot spot is full-graph aggregation ``Â @ Z`` on the
feature slice (§3.1, §4.2).  NeutronTP's tensor layer does *all* its
communication in the split/gather all-to-alls around that multiply, so the
backend choice is pure local compute: the CommLedger, the §3.2 analytic
formulas and the jaxpr collective audit are byte-identical across backends
(asserted by ``tests/dist_progs/check_agg_backends.py``).

Backends (selected in ``prepare_bundle``/``prepare_dp_bundle`` and
overridable per loss/train factory):

* ``"segment"``     — gather/scatter ``jax.ops.segment_sum`` (baseline).
                      The only backend valid for GAT: its edge weights α
                      are computed at runtime from the layer's features and
                      cannot be baked into precomputed tiles, so the
                      engines silently keep GAT on this path.
* ``"blocksparse"`` — blocked-CSR Pallas SpMM (``repro.kernels.spmm``) on
                      precomputed (bs × bs) tiles, with an exact custom VJP
                      that multiplies the cotangent through the Âᵀ tiles.
* ``"dense"``       — per-chunk dense (chunk_size × n) adjacency matmul.
                      O(V²) memory: small graphs only, the upper anchor
                      for the kernel benches.

Static edge weights (GCN's normalized Â, scaled by γ in the decoupled
propagation) are baked into the tiles / dense rows at prepare time; the γ
scaling is applied as a scalar post-multiplier since γ·(Â@z) = (γÂ)@z.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..graph import format as gf
from ..kernels import spmm as SP

AGG_BACKENDS = ("segment", "blocksparse", "dense")


def validate_backend(agg: str) -> str:
    if agg not in AGG_BACKENDS:
        raise ValueError(
            f"unknown aggregation backend {agg!r}; expected one of "
            f"{AGG_BACKENDS}")
    return agg


def resolve_choice(graph, agg: str | None) -> str:
    """Factory-level backend choice against a prepared bundle's graph.

    ``None`` → the backend the bundle was prepared with.  An explicit
    choice must be satisfiable: ``"segment"`` always is (the chunked view
    is always built); ``"blocksparse"``/``"dense"`` need the plans that
    only ``prepare_*bundle(agg=...)`` builds."""
    if agg is None:
        return graph.agg
    validate_backend(agg)
    if agg == "blocksparse" and graph.bsp is None:
        raise ValueError(
            'agg="blocksparse" requested but the bundle carries no tile '
            'plans — re-run prepare_bundle/prepare_dp_bundle with '
            'agg="blocksparse"')
    if agg == "dense" and graph.dense_adj is None:
        raise ValueError(
            'agg="dense" requested but the bundle carries no dense '
            'adjacency — re-run prepare_bundle/prepare_dp_bundle with '
            'agg="dense"')
    return agg


def build_chunk_plans(gp: gf.Graph, n_chunks: int, agg: str,
                      bs: int):
    """Host-side backend data for the TP chunk scan: per-chunk tile plans
    (``"blocksparse"``) or per-chunk dense adjacency rows (``"dense"``).
    Returns ``(bsp, dense_adj)`` with the unused slot ``None``."""
    validate_backend(agg)
    bsp = dense = None
    if agg == "blocksparse":
        bsp = SP.block_sparse_plan_dev(
            gf.chunk_block_sparse(gp, n_chunks, bs=bs))
    elif agg == "dense":
        cs = -(-gp.n // n_chunks)
        a = gp.dense_adjacency()
        rows = np.zeros((n_chunks, cs, gp.n), np.float32)
        for c in range(n_chunks):
            lo, hi = min(gp.n, c * cs), min(gp.n, (c + 1) * cs)
            rows[c, : hi - lo] = a[lo:hi]
        dense = jnp.asarray(rows)
    return bsp, dense


def chunk_xs(graph, agg: str, w_chunk):
    """The per-chunk ``lax.scan`` inputs for the chosen backend.

    Segment threads the (src, dst_local, w) edge arrays; blocksparse
    threads the stacked tile plan (the scan unstacks one plan instance
    per chunk); dense threads the (C, chunk_size, n) adjacency rows."""
    if agg == "blocksparse":
        return graph.bsp
    if agg == "dense":
        return graph.dense_adj
    cg = graph.chunked
    return (cg.src, cg.dst_local,
            cg.weight if w_chunk is None else w_chunk)


def chunk_agg(agg: str, z, xs, chunk_size: int, scale: float = 1.0):
    """One chunk's aggregation rows ``(chunk_size, d)`` for backend ``agg``.

    ``scale`` is a static scalar post-multiplier (γ for the decoupled GCN
    propagation: γ·(Â@z) = (γÂ)@z).  The segment backend ignores it —
    its per-edge weights already carry any scaling."""
    if agg == "blocksparse":
        out = SP.aggregate_plan(xs, z)[:chunk_size]
    elif agg == "dense":
        out = xs @ z
    else:
        src, dst_local, w = xs
        msg = jnp.take(z, src, axis=0) * w[:, None]
        return jax.ops.segment_sum(msg, dst_local,
                                   num_segments=chunk_size + 1)[:chunk_size]
    return out if scale == 1.0 else scale * out
