"""Explicit-collective sharder: the paper's gather/split as real
all-to-all collectives inside :func:`repro.runtime.smap` bodies
(§Perf hillclimbs).

The baseline ``Sharder`` expresses NeutronTP's layout transitions as pjit
sharding *constraints* and lets XLA's SPMD partitioner pick the collective.
The §Roofline baseline shows the partitioner frequently picks
all-gather(+slice) — g× the wire bytes of the paper's all-to-all — and
lowers the data-dependent MoE scatter into an all-reduce storm.

``ExplicitSharder`` overrides the two hot transitions with hand-scheduled
collectives, exactly the paper's design:

* ``explicit_a2a``  — the attention mixing phase.  q (and k/v when head
  counts divide) move seq-sharded → head-sharded via ONE all-to-all of
  V·D/N per device (paper §3.1 "split"), and back via one more
  ("gather").  GQA with kv_heads < N keeps k/v via an all-gather plus a
  local static slice of the kv group the device's q heads need.
* ``ep_moe``        — expert-parallel MoE dispatch.  Tokens are routed
  locally, packed into per-expert-shard send buffers, exchanged with ONE
  all-to-all over the model axis, processed by the local expert slice,
  and returned with one more all-to-all.  This is gather/split with
  "vertex set" = the routed token set.

Both paths are differentiable (the runtime's sharded-execution entry and
its collectives all have transposes) and fall back to the constraint path
when divisibility fails, so every architecture still lowers.  All sharded
execution here enters through :func:`repro.runtime.smap` — never a raw,
version-pinned ``shard_map`` import — and the collectives come from
:mod:`repro.runtime.collectives`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..runtime import collectives as C
from ..runtime import smap
from .specs import Sharder


def _data_spec_axis(rules):
    return rules.data_axes if len(rules.data_axes) > 1 else rules.data_axes[0]


@dataclasses.dataclass
class ExplicitSharder(Sharder):
    """Sharder whose mixing/MoE transitions are explicit collectives.

    Flags allow the hillclimb to enable each mechanism independently so
    §Perf can attribute deltas to one change at a time."""

    use_a2a_mixing: bool = True
    use_ep_moe: bool = True
    use_ring: bool = True     # ring attention when heads % n != 0

    # ------------------------------------------------------------------
    # paper's gather/split for the attention mixing phase
    # ------------------------------------------------------------------

    @property
    def explicit_a2a(self):
        return self._a2a_mixing if self.use_a2a_mixing else None

    def _a2a_mixing(self, cfg, q, k, v, *, window=None, scale=None):
        """q: (B,S,Hq,hd) seq-sharded over the model axis → attention
        output in the same layout, using all-to-all layout transitions.
        Returns None when inapplicable (caller falls back)."""
        from ..nn.attention import attention_blockwise, attention_core, \
            _causal_mask, _window_mask

        mesh, rules = self.mesh, self.rules
        m = rules.model_axis
        if rules.strategy != "neutron_tp" or m not in mesh.axis_names:
            return None
        n = mesh.shape[m]
        b, s, hq, hd = q.shape
        hkv = k.shape[2]
        hdv = v.shape[-1]
        if n == 1 or s % n:
            return None             # transition undefined — constraint path
        if hq % n:
            # heads don't divide the TP degree (qwen 20H, internvl 14H on
            # 16): the paper's head-sharded mixing is undefined.  Ring
            # attention keeps the sequence sharded and rotates K/V chunks
            # — the §4.2.2 inter-chunk pipeline applied to attention.
            if not self.use_ring:
                return None
            from ..nn.ring_attention import ring_attention_local
            d = _data_spec_axis(rules)
            io_spec = P(d, m, None, None)
            fn = smap(
                lambda ql, kl, vl: ring_attention_local(
                    ql, kl, vl, m, causal=True, window=window,
                    softcap=cfg.attn_softcap, scale=scale),
                mesh, in_specs=(io_spec, io_spec, io_spec),
                out_specs=io_spec)
            return fn(q, k, v)
        hq_l = hq // n
        # static kv slice width per device: the kv groups covered by the
        # device's contiguous hq_l q heads.  Aligned iff hq_l divides the
        # GQA group size (or kv heads divide n, where we a2a k/v too).
        g = hq // hkv
        kv_a2a = hkv % n == 0
        if not kv_a2a:
            if g % hq_l and hq_l % g:
                return None
            nkv_l = max(1, (hq_l + g - 1) // g)

        d = _data_spec_axis(rules)
        io_spec = P(d, m, None, None)

        def local_fn(ql, kl, vl):
            # ql: (B_l, S/n, Hq, hd) → (B_l, S, Hq/n, hd): paper's split
            qg = C.all_to_all(ql, m, split_axis=2, concat_axis=1,
                              tiled=True)
            if kv_a2a:
                kg = C.all_to_all(kl, m, split_axis=2, concat_axis=1,
                                  tiled=True)
                vg = C.all_to_all(vl, m, split_axis=2, concat_axis=1,
                                  tiled=True)
            else:
                # GQA: kv heads don't divide n — gather seq, slice the
                # kv group(s) this device's q heads attend to.
                kg = C.all_gather(kl, m, gather_axis=1)
                vg = C.all_gather(vl, m, gather_axis=1)
                idx = C.axis_index(m)
                start = (idx * hq_l) // g
                kg = jax.lax.dynamic_slice_in_dim(kg, start, nkv_l, axis=2)
                vg = jax.lax.dynamic_slice_in_dim(vg, start, nkv_l, axis=2)
            if cfg.attn_impl == "flash":
                from ..kernels.flash_attn import flash_attention
                out = flash_attention(
                    qg, kg, vg, causal=True, window=window,
                    softcap=cfg.attn_softcap, scale=scale,
                    block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv,
                    interpret=jax.default_backend() != "tpu")
            elif cfg.attn_impl == "blockwise":
                out = attention_blockwise(
                    qg, kg, vg, causal=True, window=window,
                    softcap=cfg.attn_softcap, scale=scale,
                    block_q=cfg.attn_block_q, block_kv=cfg.attn_block_kv)
            else:
                sq = qg.shape[1]
                mask = (_window_mask(sq, sq, 0, window) if window
                        else _causal_mask(sq, sq, 0))[None]
                out = attention_core(qg, kg, vg, mask,
                                     softcap=cfg.attn_softcap, scale=scale)
            # (B_l, S, Hq/n, hdv) → (B_l, S/n, Hq, hdv): paper's gather
            return C.all_to_all(out, m, split_axis=1, concat_axis=2,
                                tiled=True)

        fn = smap(local_fn, mesh,
                  in_specs=(io_spec, io_spec, io_spec),
                  out_specs=io_spec)
        return fn(q, k, v)

    # ------------------------------------------------------------------
    # expert-parallel MoE dispatch (gather/split over the routed tokens)
    # ------------------------------------------------------------------

    @property
    def ep_moe(self):
        return self._ep_moe if self.use_ep_moe else None

    def _ep_moe(self, p: dict, cfg, x: jax.Array, top_e: jax.Array,
                top_p: jax.Array, capacity_factor: float):
        """x: (B,S,D) token-sharded (data×model); top_e/top_p: (B,S,k)
        routing decisions (computed globally by the caller — aux loss and
        router semantics identical to the baseline).  Returns combined
        expert output (B,S,D), or None when inapplicable."""
        mesh, rules = self.mesh, self.rules
        m = rules.model_axis
        if rules.strategy != "neutron_tp" or m not in mesh.axis_names:
            return None
        n = mesh.shape[m]
        b, s, dm = x.shape
        e, kk = cfg.num_experts, cfg.num_experts_per_tok
        if n == 1 or e % n or s % n:
            return None
        e_l = e // n

        d = _data_spec_axis(rules)
        tok_spec = P(d, m, None)
        w_spec = P(m, None, None)   # (E, D, F): experts over model; pjit
        #                              reshards (FSDP embed gather) outside

        def local_fn(xl, tel, tpl, gate, up, down):
            b_l, s_l, _ = xl.shape
            t_l = b_l * s_l
            cap = int(max(1, -(-t_l * kk // e) * capacity_factor))
            xf = xl.reshape(t_l, dm)
            fe = tel.reshape(-1)                         # (t_l·k,)
            ft = jnp.repeat(jnp.arange(t_l), kk)
            fp = tpl.reshape(-1)
            order = jnp.argsort(fe, stable=True)
            se, st, sp = fe[order], ft[order], fp[order]
            first = jnp.searchsorted(se, jnp.arange(e))
            pos = jnp.arange(t_l * kk) - first[se]
            keep = pos < cap
            pos_c = jnp.where(keep, pos, cap - 1)

            # local send buffer (E, cap, D), expert-major
            buf = jnp.zeros((e, cap, dm), xl.dtype)
            buf = buf.at[se, pos_c].add(
                jnp.where(keep[:, None], xf[st], 0).astype(xl.dtype))

            # ---- paper's split: ONE all-to-all to the expert owners ----
            sendb = buf.reshape(n, e_l, cap, dm)
            recv = C.all_to_all(sendb, m, split_axis=0, concat_axis=0)
            # recv: (n_senders, e_l, cap, D) → (e_l, n·cap, D)
            work = recv.transpose(1, 0, 2, 3).reshape(e_l, n * cap, dm)

            # ---- local expert FFN ----
            from ..nn import layers as nl
            act = nl.activation(cfg.act)
            h = act(jnp.einsum("ecd,edf->ecf", work,
                               gate.astype(xl.dtype))) \
                * jnp.einsum("ecd,edf->ecf", work, up.astype(xl.dtype))
            y = jnp.einsum("ecf,efd->ecd", h, down.astype(xl.dtype))

            # ---- paper's gather: ONE all-to-all back to the senders ----
            yb = y.reshape(e_l, n, cap, dm).transpose(1, 0, 2, 3)
            back = C.all_to_all(yb, m, split_axis=0, concat_axis=0)
            y_buf = back.reshape(e, cap, dm)

            # ---- local combine ----
            gathered = y_buf[se, pos_c] * (sp * keep)[:, None].astype(
                xl.dtype)
            yf = jnp.zeros((t_l, dm), xl.dtype).at[st].add(gathered)
            return yf.reshape(b_l, s_l, dm)

        fn = smap(
            local_fn, mesh,
            in_specs=(tok_spec, tok_spec, tok_spec, w_spec, w_spec,
                      P(m, None, None)),
            out_specs=tok_spec)
        return fn(x, top_e, top_p, p["gate"], p["up"], p["down"])
