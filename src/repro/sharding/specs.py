"""Distribution strategies: NeutronTP-style tensor parallelism for
transformers, plus Megatron TP and pure data parallelism as baselines.

The paper's scheme maps onto sequence models as (DESIGN §3):

  tokens = vertices, attention/SSM mixing = graph aggregation,
  MLP/MoE = vertex-associated NN ops.

``neutron_tp``  — NN phase runs **token-sharded** on the model axis
                  (``act_tokens``: P(data, model, None)); the mixing phase
                  runs **head-sharded** with the full sequence per device
                  (``act_heads``: P(data, None, model, None)).  The
                  transitions between the two constraints lower to
                  all-to-alls of V·D/N per device — exactly the paper's
                  gather/split, with identical load-balance properties.
``megatron``    — activations sequence-replicated on the model axis; heads
                  and FFN columns sharded; transitions lower to all-reduces
                  (2 per layer).  The comparison point for §Perf.
``dp``          — model axis unused (pure data parallelism; only fits small
                  archs — the paper's baseline regime).

Parameters are laid out identically in all strategies (single source of
truth): logical axis → mesh axis with a divisibility guard, giving
FSDP-style sharding of the d_model dim over ``data`` and tensor sharding of
heads/FFN/experts/vocab over ``model``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..nn.param import ParamLeaf

# logical param axis → model-parallel mesh axis candidates
_MODEL_AXES = {"vocab", "heads", "kv_heads", "mlp", "experts", "inner",
               "ssm_heads"}
_FSDP_AXES = {"embed"}


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    strategy: str = "neutron_tp"       # neutron_tp | megatron | dp
    data_axes: tuple[str, ...] = ("data",)   # ("pod","data") multi-pod
    model_axis: str = "model"
    # KV-cache sequence sharding: False → cache seq replicated (heads on
    # model); True → seq over data (long_500k, batch=1); "model" → seq
    # over the model axis (§Perf HC1 iter 3 — the fix for GQA archs whose
    # head counts don't divide the model axis, e.g. qwen 20H on 16).
    seq_shard_cache: bool | str = False
    fsdp: bool = True                  # shard embed dim over data axes

    # ---- parameters ----------------------------------------------------

    def param_axis(self, logical: Optional[str], dim: int,
                   mesh: Mesh) -> Optional[str | tuple]:
        if logical in _MODEL_AXES and self.strategy != "dp":
            n = mesh.shape[self.model_axis]
            if dim % n == 0:
                return self.model_axis
            return None
        if logical in _FSDP_AXES and self.fsdp:
            n = int(np.prod([mesh.shape[a] for a in self.data_axes]))
            if dim % n == 0:
                return self.data_axes if len(self.data_axes) > 1 \
                    else self.data_axes[0]
            # try innermost data axis alone
            n1 = mesh.shape[self.data_axes[-1]]
            if dim % n1 == 0:
                return self.data_axes[-1]
        return None

    def param_spec(self, names: tuple, shape: tuple, mesh: Mesh) -> P:
        used: set = set()
        axes = []
        for logical, dim in zip(names, shape):
            ax = self.param_axis(logical, dim, mesh)
            key = tuple(ax) if isinstance(ax, tuple) else ax
            if ax is not None and key not in used:
                axes.append(ax)
                used.add(key)
            else:
                axes.append(None)
        return P(*axes)

    def param_shardings(self, names_tree, shapes_tree, mesh: Mesh):
        def one(names, shape_leaf):
            spec = self.param_spec(names, shape_leaf.shape, mesh)
            return NamedSharding(mesh, spec)
        return jax.tree.map(one, names_tree, shapes_tree,
                            is_leaf=lambda x: isinstance(x, tuple))

    # ---- activations ---------------------------------------------------

    def act_spec(self, kind: str, ndim: int) -> Optional[P]:
        d = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
        m = self.model_axis
        if self.strategy == "dp":
            m = None
        table = {
            # (B, S, D): NN phase.  neutron_tp shards the sequence (vertex
            # dim) over the model axis; megatron replicates it.
            "act_tokens": P(d, m if self.strategy == "neutron_tp" else None,
                            None),
            # (B, S, H, hd): mixing phase — full sequence, heads sharded
            "act_heads": P(d, None, m, None),
            "act_kv_heads": P(d, None, m, None),
            "act_ssm_heads": P(d, None, m, None),
            # (B, S, V): vocab-sharded logits
            "act_vocab": P(d, None, m),
            # (E, C, D): expert-major MoE buffer
            "expert_buf": P(m, None, None),
            # (B, S, H, hd) decode cache
            "cache_seq": _cache_kv_spec(self.seq_shard_cache, d, m),
            # (B, S, r) MLA latent cache
            "cache_seq_latent": _cache_latent_spec(self.seq_shard_cache, d),
        }
        return table.get(kind)


def _cache_kv_spec(seq_mode, d, m) -> P:
    """(B, S, H, hd) cache layout for the three seq-sharding modes."""
    if seq_mode == "model":
        return P(d, m, None, None)
    if seq_mode:
        return P(None, d, m, None)
    return P(d, None, m, None)


def _cache_latent_spec(seq_mode, d, m="model") -> P:
    """(B, S, r) MLA latent cache layout."""
    if seq_mode == "model":
        return P(d, m, None)
    if seq_mode:
        return P(None, d, None)
    return P(d, None, None)


@dataclasses.dataclass
class Sharder:
    """Callable activation-constraint hook passed through the model."""

    mesh: Mesh
    rules: ShardingRules

    def __call__(self, x: jax.Array, kind: str) -> jax.Array:
        spec = self.rules.act_spec(kind, x.ndim)
        if spec is None:
            return x
        spec = _fit_spec(spec, x.shape, self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


def _fit_spec(spec: P, shape: tuple, mesh: Mesh) -> P:
    """Drop axes that don't divide the corresponding dim (e.g. kv_heads=8
    on a 16-way model axis → replicate, per DESIGN's GQA note)."""
    fitted = []
    for i, ax in enumerate(spec):
        if ax is None or i >= len(shape):
            fitted.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        n = int(np.prod([mesh.shape[a] for a in axes]))
        fitted.append(ax if shape[i] % n == 0 else None)
    return P(*fitted)


def make_sharder(mesh: Mesh, rules: ShardingRules) -> Sharder:
    return Sharder(mesh=mesh, rules=rules)


def cache_shardings(rules: ShardingRules, mesh: Mesh, cache_shapes):
    """NamedShardings for a decode-cache pytree (possibly with leading
    scan-stack axes).  Leaves are identified by their attribute name in the
    cache dataclasses; trailing-dim specs are right-aligned so stacked
    caches (extra leading axes) inherit the same layout."""
    d = rules.data_axes if len(rules.data_axes) > 1 else rules.data_axes[0]
    m = rules.model_axis if rules.strategy != "dp" else None
    kv = tuple(_cache_kv_spec(rules.seq_shard_cache, d, m))
    lat = tuple(_cache_latent_spec(rules.seq_shard_cache, d, m))
    by_name = {
        # (B, S, H, hd)
        "k": kv,
        "v": kv,
        # (B, S, r)
        "c_kv": lat,
        "k_rope": lat,
        # (B, K-1, conv_dim)
        "conv_state": (d, None, m),
        # (B, H, P, N)
        "ssm_state": (d, m, None, None),
        "length": (),
    }

    def one(path, leaf):
        name = None
        for entry in reversed(path):
            if hasattr(entry, "name"):
                name = entry.name
                break
        spec = by_name.get(name)
        if spec is None:
            return NamedSharding(mesh, P())
        nd = len(leaf.shape)
        full = (None,) * (nd - len(spec)) + tuple(spec)
        fitted = _fit_spec(P(*full), leaf.shape, mesh)
        return NamedSharding(mesh, fitted)

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def abstract_params(init_fn, *args):
    """eval_shape an init function, returning (shapes_tree, names_tree)."""
    from ..nn.param import split_params
    leaves = jax.eval_shape(init_fn, *args)
    return split_params(leaves)
