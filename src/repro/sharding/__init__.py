from .specs import (ShardingRules, Sharder, make_sharder,
                    cache_shardings)  # noqa: F401
