import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh).

The two lines above run before ANY other import (jax pins the device count
at first init).  Do not import this module from test/bench processes —
invoke it as a script or module:

    PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b \
        --shape train_4k --mesh single --out results/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --out results/dryrun

Per combo it records memory_analysis (proves fit), cost_analysis (FLOPs /
bytes for §Roofline), and the collective-byte census parsed from the
compiled HLO.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from ..configs import INPUT_SHAPES, get_config, list_archs  # noqa: E402
from ..models import transformer as T  # noqa: E402
from ..serve import engine  # noqa: E402
from ..sharding.specs import (ShardingRules, Sharder,  # noqa: E402
                              cache_shardings)
from ..train import loop as train_loop  # noqa: E402
from . import roofline  # noqa: E402
from .mesh import data_axes_for, make_production_mesh  # noqa: E402

# long_500k runs only for sub-quadratic-capable archs (DESIGN §3)
LONG_CONTEXT_ARCHS = {"mamba2-1.3b", "zamba2-2.7b", "gemma2-9b"}


def applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def lower_combo(arch: str, shape_name: str, *, multi_pod: bool,
                strategy: str = "neutron_tp", fsdp: bool = True,
                remat="full", attn_impl: str | None = None,
                logits_last: bool = False, mixing: str = "constraint",
                moe: str = "spmd", cache_seq: str | None = None):
    """Returns (lowered, compiled, meta) for one combination.

    §Perf knobs (default = paper-faithful baseline):
      attn_impl   — override cfg.attn_impl ("blockwise" = flash schedule)
      logits_last — prefill unembeds only the final position
      mixing      — "a2a": explicit runtime.smap all-to-alls for the
                    seq↔heads transitions (the paper's gather/split)
      moe         — "ep": expert-parallel dispatch via all-to-all
      cache_seq   — "model"/"data": shard the KV cache sequence dim
    """
    cfg = get_config(arch)
    if attn_impl:
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq_cache = cache_seq if cache_seq else (shape_name == "long_500k")
    rules = ShardingRules(
        strategy=strategy,
        data_axes=data_axes_for(mesh),
        seq_shard_cache=seq_cache,
        fsdp=fsdp)
    if mixing == "a2a" or moe == "ep":
        from ..sharding.explicit import ExplicitSharder
        sharder = ExplicitSharder(mesh=mesh, rules=rules,
                                  use_a2a_mixing=(mixing == "a2a"),
                                  use_ep_moe=(moe == "ep"))
    else:
        sharder = Sharder(mesh=mesh, rules=rules)
    long_ctx = shape_name == "long_500k"

    with mesh:
        if shape.kind == "train":
            setup = train_loop.sharded_setup(
                cfg, shape, mesh, rules, sharder=sharder,
                remat={"full": True, "dots": "dots", "none": False}.get(
                    remat, remat))
            lowered = setup["train_step"].lower(setup["state_shapes"],
                                                setup["batch_specs"])
        elif shape.kind == "prefill":
            prefill_fn, _ = engine.make_serve_fns(cfg, sharder,
                                                  long_context=long_ctx,
                                                  last_only=logits_last)
            b, s = shape.global_batch, shape.seq_len
            tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
            abstract = jax.eval_shape(
                lambda k: T.init_transformer(k, cfg), jax.random.PRNGKey(0))
            from ..nn.param import split_params
            p_shapes, p_names = split_params(abstract)
            p_sh = rules.param_shardings(p_names, p_shapes, mesh)
            d = rules.data_axes if len(rules.data_axes) > 1 \
                else rules.data_axes[0]
            tok_sh = NamedSharding(mesh, P(d, None))
            args = [abstract, tokens]
            in_sh = [p_sh, tok_sh]
            # round up so the cache seq dim stays shardable (s+1 would
            # break divisibility and silently drop the sharding axis)
            max_len = -(-(s + 1) // 256) * 256
            if cfg.modality:
                args.append(jax.ShapeDtypeStruct(
                    (b, cfg.num_prefix_embeddings, cfg.d_model),
                    jnp.float32))
                in_sh.append(NamedSharding(mesh, P(d, None, None)))
                max_len += cfg.num_prefix_embeddings
            lowered = jax.jit(
                lambda p, t, *pre: prefill_fn(p, t, *pre, max_len=max_len),
                in_shardings=tuple(in_sh)).lower(*args)
        else:  # decode
            _, decode_fn = engine.make_serve_fns(cfg, sharder,
                                                 long_context=long_ctx)
            token, cache_shapes = engine.serve_step_spec(
                cfg, shape, long_context=long_ctx)
            abstract = jax.eval_shape(
                lambda k: T.init_transformer(k, cfg), jax.random.PRNGKey(0))
            from ..nn.param import split_params
            p_shapes, p_names = split_params(abstract)
            p_sh = rules.param_shardings(p_names, p_shapes, mesh)
            c_sh = cache_shardings(rules, mesh, cache_shapes)
            d = rules.data_axes if len(rules.data_axes) > 1 \
                else rules.data_axes[0]
            tok_sh = NamedSharding(
                mesh, P(d, None) if shape.global_batch > 1 else P())
            lowered = jax.jit(
                decode_fn, in_shardings=(p_sh, tok_sh, c_sh),
                donate_argnums=(2,)).lower(abstract, token, cache_shapes)

    compiled = lowered.compile()
    return lowered, compiled, dict(mesh=mesh, rules=rules, cfg=cfg,
                                   shape=shape)


def run_combo(arch: str, shape_name: str, *, multi_pod: bool,
              strategy: str = "neutron_tp", fsdp: bool = True,
              variant: str = "baseline", **knobs) -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()
    lowered, compiled, meta = lower_combo(arch, shape_name,
                                          multi_pod=multi_pod,
                                          strategy=strategy, fsdp=fsdp,
                                          **knobs)
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    census = roofline.hlo_census(hlo)
    coll = census["collectives"]
    cfg, shape = meta["cfg"], meta["shape"]
    terms = roofline.derive_terms(
        arch, shape_name, mesh_name, chips, census,
        roofline.model_flops_for(cfg, shape))
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "strategy": strategy, "variant": variant, "knobs": knobs,
        "chips": chips,
        "compile_seconds": compile_s,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "peak_bytes": (mem.argument_size_in_bytes
                           + mem.output_size_in_bytes
                           + mem.temp_size_in_bytes),
        },
        "cost": {k: cost.get(k, 0.0)
                 for k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": coll,
        "roofline": terms.as_dict(),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--strategy", default="neutron_tp")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    # §Perf knobs
    ap.add_argument("--variant", default="baseline",
                    help="tag for the output JSON name")
    ap.add_argument("--attn", default=None,
                    choices=[None, "naive", "blockwise"])
    ap.add_argument("--logits-last", action="store_true")
    ap.add_argument("--mixing", default="constraint",
                    choices=["constraint", "a2a"])
    ap.add_argument("--moe", default="spmd", choices=["spmd", "ep"])
    ap.add_argument("--cache-seq", default=None,
                    choices=[None, "model", "data"])
    ap.add_argument("--remat", default="full",
                    choices=["full", "dots", "none"])
    args = ap.parse_args()
    knobs = dict(attn_impl=args.attn, logits_last=args.logits_last,
                 mixing=args.mixing, moe=args.moe,
                 cache_seq=args.cache_seq, remat=args.remat)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    n_devices = len(jax.devices())
    print(f"dry-run on {n_devices} placeholder devices")
    failures = []
    for arch in archs:
        for shape in shapes:
            if not applicable(arch, shape):
                print(f"SKIP {arch} × {shape} (documented in DESIGN.md)")
                continue
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}" \
                    f"__{args.strategy}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                try:
                    rec = run_combo(arch, shape, multi_pod=mp,
                                    strategy=args.strategy,
                                    fsdp=not args.no_fsdp,
                                    variant=args.variant, **knobs)
                    with open(os.path.join(args.out, tag + ".json"),
                              "w") as f:
                        json.dump(rec, f, indent=2)
                    r = rec["roofline"]
                    print(f"OK   {tag}: compile {rec['compile_seconds']:.1f}s"
                          f" peak/dev {rec['memory']['peak_bytes']/2**30:.2f}"
                          f" GiB  dominant={r['dominant']}"
                          f" (c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s"
                          f" coll={r['collective_s']:.2e}s)")
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(" ", tag, err)
        raise SystemExit(1)
    print("\nall dry-run combinations lowered and compiled")


if __name__ == "__main__":
    main()
