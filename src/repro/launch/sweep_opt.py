import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Optimized-variant dry-run sweep: every applicable (arch × shape) with
the per-shape-kind §Perf knobs that won the hillclimbs.

    PYTHONPATH=src python -m repro.launch.sweep_opt \
        [--mesh single|multi|both] [--out results/dryrun_opt]

Knob selection (EXPERIMENTS.md §Perf):
  train    → mixing=a2a, moe=ep                (naive attn: blockwise
             refuted at 4k; heads%16≠0 archs take the ring path)
  prefill  → mixing=a2a, moe=ep, logits_last, cache_seq=model,
             attn=blockwise (peak is binding at 32k)
  decode   → cache_seq=model (flash-decode-style seq-sharded cache;
             EP/ring don't apply to the 1-token step)
  long_500k→ baseline knobs (cache already seq-sharded over data)
"""
import argparse  # noqa: E402
import json  # noqa: E402
import traceback  # noqa: E402

from ..configs import INPUT_SHAPES, list_archs  # noqa: E402
from .dryrun import applicable, run_combo  # noqa: E402

KNOBS = {
    "train": dict(mixing="a2a", moe="ep"),
    "prefill": dict(mixing="a2a", moe="ep", logits_last=True,
                    cache_seq="model", attn_impl="blockwise"),
    "decode": dict(cache_seq="model"),
    "long": {},
}


def knobs_for(shape_name: str) -> dict:
    if shape_name == "long_500k":
        return KNOBS["long"]
    return KNOBS[INPUT_SHAPES[shape_name].kind]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--out", default="results/dryrun_opt")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    args = ap.parse_args()
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = []
    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    for arch in archs:
        for shape in shapes:
            if not applicable(arch, shape):
                continue
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                tag = f"{arch}__{shape}__{mesh_name}__neutron_tp__opt"
                try:
                    rec = run_combo(arch, shape, multi_pod=mp,
                                    variant="opt", **knobs_for(shape))
                    with open(os.path.join(args.out, tag + ".json"),
                              "w") as f:
                        json.dump(rec, f, indent=2)
                    r = rec["roofline"]
                    print(f"OK   {tag}: peak "
                          f"{rec['memory']['peak_bytes']/2**30:.2f} GiB "
                          f"c={r['compute_s']:.2e} m={r['memory_s']:.2e} "
                          f"coll={r['collective_s']:.2e} "
                          f"dom={r['dominant']}", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\noptimized sweep complete")


if __name__ == "__main__":
    main()
