# NOTE: do not import .dryrun here — it force-sets the XLA device count and
# must only run as a dedicated process (python -m repro.launch.dryrun).
from . import mesh, roofline  # noqa: F401
