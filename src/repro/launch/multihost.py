"""Real multi-host full-graph GNN training via ``jax.distributed``.

One program, run once per process; every process executes the same
code on the same seed and owns only its ``jax.local_devices()`` slice of
one global mesh.  The flow is exactly the single-host path — mesh from
``runtime.mesh`` (:func:`~repro.runtime.tp_mesh` /
:func:`~repro.runtime.hybrid_mesh` over the *global* ``jax.devices()``),
bundle from ``prepare_bundle``/``prepare_dp_bundle`` (now committed
per-host via ``mesh=``), train step from ``make_tp_train_fns`` /
``make_dp_train_fns`` through ``runtime.engine`` — with exactly one new
step in front: :func:`repro.runtime.distributed.initialize`.  No
forward/backward code forks for multihost.

Process topology — env contract (CLI flags override)
----------------------------------------------------

Every process of the job exports::

    COORDINATOR_ADDRESS=<host:port>   # the rank-0 host; all connect to it
    NUM_PROCESSES=<N>                 # identical on every process
    PROCESS_ID=<i>                    # distinct, 0..N-1; 0 = coordinator
    DIST_INIT_TIMEOUT=<seconds>       # optional connect timeout (60)

and runs ``python -m repro.launch.multihost <workload args>``.

Supported CI topology: N processes × M fake devices on ONE machine —
each process additionally pins
``XLA_FLAGS=--xla_force_host_platform_device_count=M`` and the
coordinator address is ``127.0.0.1:<free port>``.  The cross-process
collectives are real (gloo over TCP), so the whole launcher path is
exercisable without a cluster; ``scripts/launch_multihost.sh`` spawns
this topology, and ``tests/dist_progs/harness.py`` pins it for the test
suite.  On a real cluster nothing changes except the address and the
absence of forced devices.

Output is coordinator-only: process 0 prints one CSV row per epoch and
a final ``RESULT {json}`` line; the other processes run the identical
SPMD program silently.
"""
from __future__ import annotations

import argparse
import json
import time


def parse_args(argv=None) -> argparse.Namespace:
    ap = argparse.ArgumentParser(
        description="multi-host full-graph GNN training "
                    "(jax.distributed; env contract in module docstring)")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 (default: "
                         "$COORDINATOR_ADDRESS)")
    ap.add_argument("--num-processes", type=int, default=None,
                    help="total processes in the job (default: "
                         "$NUM_PROCESSES, else 1)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank (default: $PROCESS_ID)")
    ap.add_argument("--timeout", type=float, default=None,
                    help="distributed-init timeout seconds (default: "
                         "$DIST_INIT_TIMEOUT, else 60)")
    ap.add_argument("--mode", default="decoupled_pipelined",
                    choices=["decoupled", "decoupled_pipelined", "naive",
                             "dp"])
    ap.add_argument("--backend", default="explicit",
                    choices=["explicit", "constraint"])
    ap.add_argument("--model", default="gcn", choices=["gcn", "gat"])
    ap.add_argument("--data", type=int, default=1,
                    help="replica-group count: hybrid (data, model) mesh "
                         "with model = global_devices/data; 1 = pure TP")
    ap.add_argument("--pod", type=int, default=1,
                    help="pod axis degree for 3-axis meshes")
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--feat-dim", type=int, default=128)
    ap.add_argument("--classes", type=int, default=16)
    ap.add_argument("--avg-degree", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--chunks", type=int, default=4)
    ap.add_argument("--epochs", type=int, default=5)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=7,
                    help="graph/param seed — identical on every process "
                         "(each materializes the same host data and "
                         "contributes only its local shards)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = parse_args(argv)

    from repro.runtime import distributed as dist

    ctx = dist.initialize(coordinator_address=args.coordinator,
                          num_processes=args.num_processes,
                          process_id=args.process_id,
                          timeout=args.timeout)

    import jax

    from repro import optim
    from repro.core import decouple as D
    from repro.gnn import dp_baseline as DP
    from repro.gnn import models as M
    from repro.graph import sbm_power_law
    from repro.runtime import hybrid_mesh, tp_mesh

    if args.data > 1 or args.pod > 1:
        mesh = hybrid_mesh(data=args.data, pod=args.pod)
    else:
        mesh = tp_mesh()
    say = print if ctx.is_coordinator else (lambda *a, **k: None)
    say(f"# multihost: {ctx.num_processes} processes × "
        f"{ctx.local_device_count} local devices = "
        f"{ctx.global_device_count} global; mesh "
        f"{dict(mesh.mesh.shape)} mode={args.mode} backend={args.backend}",
        flush=True)

    data = sbm_power_law(n=args.n, num_classes=args.classes,
                         feat_dim=args.feat_dim,
                         avg_degree=args.avg_degree, seed=args.seed)
    opt = optim.adamw(args.lr)
    if args.mode == "dp":
        bundle = DP.prepare_dp_bundle(data, mesh=mesh)
        cfg = M.GNNConfig(model=args.model, in_dim=args.feat_dim,
                          hidden_dim=args.hidden,
                          num_classes=args.classes,
                          num_layers=args.layers, decoupled=False)
        params = dist.replicate(
            M.init_params(jax.random.PRNGKey(args.seed), cfg), mesh)
        step, evaluate = DP.make_dp_train_fns(cfg, bundle, mesh, opt,
                                              backend=args.backend)
    else:
        bundle = D.prepare_bundle(data, n_chunks=args.chunks, mesh=mesh)
        cfg = D.padded_gnn_config(data, bundle, model=args.model,
                                  hidden_dim=args.hidden,
                                  num_layers=args.layers)
        params = dist.replicate(
            M.init_params(jax.random.PRNGKey(args.seed), cfg), mesh)
        step, evaluate = D.make_tp_train_fns(cfg, bundle, mesh, opt,
                                             mode=args.mode,
                                             backend=args.backend)

    p, o = params, dist.replicate(opt.init(params), mesh)
    losses = []
    t0 = time.perf_counter()
    for epoch in range(args.epochs):
        te = time.perf_counter()
        p, o, loss = step(p, o)
        jax.block_until_ready(loss)
        losses.append(float(loss))
        say(f"epoch,{epoch},{losses[-1]:.6f},"
            f"{(time.perf_counter() - te) * 1e3:.1f}ms", flush=True)
    wall = time.perf_counter() - t0
    _, acc = evaluate(p, "train")
    result = {
        "processes": ctx.num_processes,
        "local_devices": ctx.local_device_count,
        "global_devices": ctx.global_device_count,
        "mesh": dict(mesh.mesh.shape), "mode": args.mode,
        "backend": args.backend, "model": args.model,
        "epochs": args.epochs, "loss_first": losses[0],
        "loss_last": losses[-1], "train_acc": float(acc),
        "wall_s": wall,
    }
    say("RESULT " + json.dumps(result), flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
