"""Roofline-term derivation from compiled dry-run artifacts.

Per (arch × shape × mesh):
  compute    = HLO_FLOPs / (chips × 197e12)           [bf16 MXU peak]
  memory     = HLO_bytes / (chips × 819e9)            [HBM bandwidth]
  collective = Σ collective operand bytes / (chips × 50e9)   [ICI/link]

FLOPs/bytes come from ``compiled.cost_analysis()`` (whole-program, already
per-partition under SPMD — we document the convention below); collective
bytes are parsed from the compiled HLO text since cost_analysis omits them.

DEPRECATED for the distributed-GNN benches: the HLO census is neither
the primary wire-byte measurement (:mod:`repro.runtime.telemetry` counts
bytes at the runtime choke point at trace time) nor the primary
structural check (:mod:`repro.analysis.jaxpr_audit` diffs the jaxpr's
collective primitives against the ledger per (op, axis, dtype), through
scan/while sub-jaxprs).  The census survives only as a demoted,
opt-in HLO-text cross-check (``benchmarks/_dist_gnn.py --hlo-census``,
which emits a DeprecationWarning), still asserted byte-for-byte against
the ledger because this file has shipped two silent-zero parser bugs:
tuple-result ``/*index=N*/`` comments breaking ``_DEF_RE``, and literal
``replica_groups={{...}}`` falling back to group size 1.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (~ per-chip usable)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
# (the old _OP_RE collective matcher is gone: _DEF_RE + the _COLLECTIVES
# base-name check in hlo_census are the single parsing path, pinned by
# tests/test_roofline_census.py)
# replica_groups appears in three spellings: the compact iota form
# `replica_groups=[G,S]<=[N]` (G groups of size S), the literal form
# `replica_groups={{0,1,...},{...}}` (size = ids in the first group), and
# the empty literal `replica_groups={}` (one group of ALL participants —
# resolved from the module's num_partitions).
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIT_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_EMPTY_RE = re.compile(r"replica_groups=\{\s*\}")
_NUM_PARTITIONS_RE = re.compile(r"num_partitions=(\d+)")


def _group_size(line: str, all_participants: int = 1) -> int:
    gm = _GROUPS_RE.search(line)
    if gm:
        return int(gm.group(2))
    gm = _GROUPS_LIT_RE.search(line)
    if gm:
        return len(gm.group(1).split(","))
    if _GROUPS_EMPTY_RE.search(line):
        return all_participants
    return 1


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


# computation headers sit at column 0: `%name (params...) -> type {` — the
# param list may contain nested parens (tuple types), so match greedily.
_COMP_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*"
                           r".*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?condition=%?([\w\.\-]+),\s*"
                       r"body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"=\s+s32\[\]\s+constant\((\d+)\)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
# Result type is a plain shape or a tuple type; tuple types contain no
# nested parens but DO contain `/*index=5*/` comments (with `=` and `*`),
# so the tuple branch must run to the first `)`, not stop at `=`.
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*"
                     r"((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\]\S*))\s+"
                     r"([\w\-]+)\(")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _split_computations(hlo_text: str):
    """→ (comps: name → lines, entry_name)."""
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if line and not line[0].isspace():        # headers at column 0
            m = _COMP_HEAD_RE.match(line)
            if m:
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
                continue
        if cur is not None:
            if line.strip() == "}":
                cur = None
                continue
            comps[cur].append(line)
    return comps, entry


def _multipliers(comps: dict, entry: str | None) -> dict[str, float]:
    """Execution-count multiplier per computation: while bodies count trip×
    (trip = largest s32 constant in the loop condition); fusions/calls 1×."""
    edges: dict[str, list[tuple[str, float]]] = {}   # parent → [(child, f)]
    for parent, lines in comps.items():
        for line in lines:
            wm = _WHILE_RE.search(line)
            if wm:
                cond, body = wm.group(1), wm.group(2)
                consts = [int(c) for cl in comps.get(cond, [])
                          for c in _CONST_RE.findall(cl)]
                trip = float(max(consts)) if consts else 1.0
                edges.setdefault(parent, []).append((body, trip))
                edges.setdefault(parent, []).append((cond, trip))
                continue
            for callee in _CALLS_RE.findall(line):
                edges.setdefault(parent, []).append((callee, 1.0))

    mult: dict[str, float] = {}

    def visit(name: str, factor: float, depth=0):
        if depth > 32:
            return
        mult[name] = mult.get(name, 0.0) + factor
        for child, f in edges.get(name, []):
            visit(child, factor * f, depth + 1)

    if entry is not None:
        visit(entry, 1.0)
    else:  # fallback: everything once
        for name in comps:
            mult[name] = 1.0
    return mult


def hlo_census(hlo_text: str) -> dict:
    """Trip-count-aware FLOP / byte / collective census of compiled HLO.

    .. deprecated:: superseded by :mod:`repro.analysis.jaxpr_audit` as
       the structural collective check for the distributed-GNN benches
       (jaxprs carry typed primitives; HLO text is a moving target).
       Retained for the roofline terms and as the opt-in
       ``--hlo-census`` cross-check.

    XLA's ``cost_analysis()`` visits while bodies once; layer scans would
    undercount by ~num_layers.  This census multiplies each computation by
    its execution count from the call graph.

    flops — 2·|result|·contraction for every ``dot`` (convolutions and
    elementwise transcendentals are ignored: negligible next to matmuls).
    bytes — result + resolvable operand bytes of materialized ops
    (fusion/dot/copy/slice/collective), a post-fusion buffer-traffic model.
    """
    comps, entry = _split_computations(hlo_text)
    mult = _multipliers(comps, entry)
    pm = _NUM_PARTITIONS_RE.search(hlo_text)
    num_partitions = int(pm.group(1)) if pm else 1

    flops = 0.0
    bytes_accessed = 0.0
    coll = {k: 0.0 for k in _COLLECTIVES}
    coll_counts = {k: 0.0 for k in _COLLECTIVES}

    # byte-traffic model per op kind (post-fusion buffer reads+writes):
    #   exact  — result + true operand buffer sizes (dot, reduce, concat)
    #   capped — result + Σ min(operand, result): elementwise-ish fusions;
    #            prevents counting a whole scan-stacked buffer for the
    #            slice-fusions inside while bodies (they read 1/trip of it)
    #   double — 2×result (copy/convert/slice/gather: read≈write≈result)
    #   single — 1×result (broadcast/iota/pad writes)
    exact_ops = {"dot", "reduce", "concatenate", "convolution", "sort",
                 "scatter", "select-and-scatter"}
    capped_ops = {"fusion"}
    double_ops = {"copy", "convert", "transpose", "slice", "dynamic-slice",
                  "gather", "dynamic-update-slice", "rng-bit-generator"}
    single_ops = {"broadcast", "iota", "pad"}

    for name, lines in comps.items():
        f_comp = mult.get(name, 0.0)
        if f_comp == 0.0:
            continue
        shapes: dict[str, list] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            vname, rshape, op = dm.group(1), dm.group(2), dm.group(3)
            rlist = _SHAPE_RE.findall(rshape)
            shapes[vname] = rlist
            rbytes = sum(_shape_bytes(dt, d) for dt, d in rlist)

            # ---- collectives ----
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                g = _group_size(line, num_partitions)
                coll[base] += rbytes * _wire_factor(base, g) * f_comp
                coll_counts[base] += f_comp
                bytes_accessed += 2 * rbytes * f_comp
                continue

            # ---- flops: dot ----
            if op == "dot":
                cm = _CONTRACT_RE.search(line)
                contract = 1
                # first operand = lhs
                call = line[dm.end():]
                ops_names = _OPERAND_RE.findall(call.split(")")[0])
                if cm and ops_names:
                    lhs = shapes.get(ops_names[0])
                    if lhs:
                        dims = [int(x) for x in lhs[0][1].split(",") if x]
                        for ci in cm.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                contract *= dims[int(ci)]
                n_res = 1
                for dt, d in rlist:
                    for x in d.split(","):
                        if x:
                            n_res *= int(x)
                flops += 2.0 * n_res * contract * f_comp

            # ---- bytes ----
            if op in exact_ops or op in capped_ops:
                obytes = 0
                call = line[dm.end():]
                for on in _OPERAND_RE.findall(call.split("),")[0]):
                    ol = shapes.get(on)
                    if ol:
                        ob = sum(_shape_bytes(dt, d) for dt, d in ol)
                        if op in capped_ops:
                            ob = min(ob, rbytes)
                        obytes += ob
                bytes_accessed += (rbytes + obytes) * f_comp
            elif op in double_ops:
                bytes_accessed += 2 * rbytes * f_comp
            elif op in single_ops:
                bytes_accessed += rbytes * f_comp

    coll["total"] = sum(coll[k] for k in _COLLECTIVES)
    coll["counts"] = coll_counts
    return {"flops": flops, "bytes": bytes_accessed, "collectives": coll}


def _wire_factor(kind: str, g: int) -> float:
    """Ring-algorithm per-device wire-byte factor on the RESULT size:
      all-gather       (g−1)/g     all-reduce   2(g−1)/g
      reduce-scatter   (g−1)       all-to-all   (g−1)/g
      collective-permute  1
    """
    if kind == "collective-permute":
        return 1.0
    if g <= 1:
        return 0.0
    return {"all-gather": (g - 1) / g,
            "all-reduce": 2 * (g - 1) / g,
            "reduce-scatter": float(g - 1),
            "all-to-all": (g - 1) / g}[kind]


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    flops_ratio: float           # MODEL_FLOPS / HLO_FLOPs
    coll_breakdown: dict

    def as_dict(self):
        d = dataclasses.asdict(self)
        return d


def derive_terms(arch: str, shape: str, mesh_name: str, chips: int,
                 census: dict, model_flops: float) -> RooflineTerms:
    """census: :func:`hlo_census` of the compiled per-partition module —
    all quantities are per-device, so term = quantity / per-chip peak.
    ``model_flops`` is global, so the useful-compute ratio divides by
    (per-device flops × chips)."""
    flops = float(census["flops"])
    mem_bytes = float(census["bytes"])
    coll = census["collectives"]
    coll_total = float(coll.get("total", 0))
    compute_s = flops / PEAK_FLOPS
    memory_s = mem_bytes / HBM_BW
    collective_s = coll_total / ICI_BW
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", collective_s)), key=lambda kv: kv[1])[0]
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=flops, hlo_bytes=mem_bytes, coll_bytes=coll_total,
        model_flops=model_flops,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant,
        flops_ratio=(model_flops / (flops * chips)) if flops else 0.0,
        coll_breakdown={k: coll[k] for k in _COLLECTIVES} | {
            "counts": coll["counts"]},
    )


def model_flops_for(cfg, shape) -> float:
    """6·N·D convention (N = active params, D = tokens processed).
    Decode steps process global_batch tokens; train includes the 3× of
    backward (6·N·D already counts fwd+bwd for training; for pure forward
    (prefill/decode) we use 2·N·D)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch          # one new token per sequence
    return 2.0 * n_active * tokens
