"""Production mesh builders.

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
extends data parallelism across the inter-pod DCI link.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets the forced device count before any init).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int | None = None, data: int = 1):
    """Small mesh over whatever local devices exist (tests, examples)."""
    devs = jax.devices()
    model = model or (len(devs) // data)
    arr = np.array(devs[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))


def data_axes_for(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
