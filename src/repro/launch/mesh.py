"""Production/host mesh builders — thin shims over the single mesh owner.

``repro.runtime.mesh`` owns all mesh construction (shape resolution,
strict no-truncation device accounting, replica-axis bookkeeping); this
module only keeps the launch-facing spellings alive:

Single pod: (16, 16) = 256 v5e chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
extends data parallelism across the inter-pod DCI link.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets the forced device count before any init).
"""
from __future__ import annotations

import jax

from ..runtime.mesh import data_axes_for  # noqa: F401  (canonical home)
from ..runtime.mesh import hybrid_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """The fleet meshes, via :func:`repro.runtime.hybrid_mesh`.

    The pod shape is fixed (256/512 chips), so an *explicit* device
    slice is passed — the dry-run forces 512 host devices and then
    builds both pod variants, which is the documented escape hatch from
    the no-silent-truncation contract (the caller spells the subset).
    ``topology=True`` keeps the physical-topology-aware device ordering
    the old ``jax.make_mesh`` builder provided (model axis on
    ICI-adjacent chips)."""
    n = 512 if multi_pod else 256
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(
            f"production mesh needs {n} devices, {len(devs)} visible")
    if multi_pod:
        return hybrid_mesh(model=16, data=16, pod=2, devices=devs[:n],
                           topology=True).mesh
    return hybrid_mesh(model=16, data=16, devices=devs[:n],
                       topology=True).mesh


def make_host_mesh(model: int | None = None, data: int = 1, pod: int = 1,
                   devices=None):
    """Small mesh over the local devices (tests, examples).

    Returns a raw ``jax.sharding.Mesh`` with axes (data, model) — or
    (pod, data, model) when ``pod > 1``, the host-scale analog of the
    multi-pod production mesh.  Unlike the old builder this never
    silently truncates the device list: the requested shape must consume
    exactly the visible (or given) devices (``model=None`` infers the
    model degree, which must divide exactly) — see
    :func:`repro.runtime.resolve_mesh_shape`.  To use a subset of the
    host, pass the slice explicitly, e.g.
    ``make_host_mesh(model=2, data=2, devices=jax.devices()[:4])``.
    """
    return hybrid_mesh(model=model, data=data, pod=pod,
                       devices=devices).mesh
