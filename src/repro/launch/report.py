"""Render dry-run JSON records into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report --in results/dryrun_baseline
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_records(path: str) -> list[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_bytes(b: float) -> str:
    return f"{b / 2**30:.2f}"


def fmt_s(s: float) -> str:
    if s == 0:
        return "0"
    return f"{s:.2e}"


def roofline_table(recs: list[dict], mesh: str = "16x16",
                   strategy: str | None = None) -> str:
    rows = [r for r in recs if r["mesh"] == mesh
            and (strategy is None or r["strategy"] == strategy)]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    out = [
        "| arch | shape | compute s | memory s | collective s | dominant |"
        " peak GiB/dev | MODEL/HLO flops | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        note = _note(r)
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(rf['compute_s'])} "
            f"| {fmt_s(rf['memory_s'])} | {fmt_s(rf['collective_s'])} "
            f"| {rf['dominant']} "
            f"| {fmt_bytes(r['memory']['peak_bytes'])} "
            f"| {rf['flops_ratio']:.2f} | {note} |")
    return "\n".join(out)


def _note(r: dict) -> str:
    rf = r["roofline"]
    dom = rf["dominant"]
    cb = rf["coll_breakdown"]
    if dom == "memory":
        if r["shape"].startswith(("prefill", "train")) \
                and rf["memory_s"] > 10 * rf["compute_s"]:
            return "S×S score buffers — needs blockwise attention"
        if r["shape"].startswith("decode"):
            return "KV-cache sweep per token (expected decode regime)"
        return "activation traffic"
    if dom == "collective":
        big = max((k for k in ("all-gather", "all-reduce", "all-to-all",
                               "reduce-scatter")), key=lambda k: cb[k])
        return f"{big} dominates — resharding/overlap candidate"
    return "compute-bound (near roofline)"


def dryrun_table(recs: list[dict]) -> str:
    rows = sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    out = [
        "| arch | shape | mesh | strategy | compile s | peak GiB/dev |"
        " flops/dev | coll GiB/dev | a2a GiB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        rf = r["roofline"]
        cb = rf["coll_breakdown"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['strategy']} "
            f"| {r['compile_seconds']:.1f} "
            f"| {fmt_bytes(r['memory']['peak_bytes'])} "
            f"| {rf['hlo_flops']:.2e} "
            f"| {fmt_bytes(rf['coll_bytes'])} "
            f"| {fmt_bytes(cb['all-to-all'])} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="path", default="results/dryrun_baseline")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args()
    recs = load_records(args.path)
    print(f"### Roofline (single-pod {args.mesh}, {len(recs)} records "
          f"total)\n")
    print(roofline_table(recs, mesh=args.mesh))
    print("\n### Dry-run census (all meshes)\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
