"""Pure-jnp oracle for the SSD kernel: the dense dual (quadratic) form.

y[t] = Σ_{s≤t} C[t]·exp(Σ_{s<k≤t} da[k])·dt[s]·(B[s]·x[s])  — one S×S
masked matrix, no chunking.  Independent of BOTH the chunked jnp
implementation (nn/ssm.ssd_chunked) and the Pallas kernel's scheduling,
so it can arbitrate between them.  Small shapes only (materializes S×S).
"""
from __future__ import annotations

import jax.numpy as jnp


def ssd_dense_ref(x, dt, a, b_mat, c_mat):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,N) → (B,S,H,P)."""
    s = x.shape[1]
    da = dt * a                                        # (B,S,H)
    cs = jnp.cumsum(da, axis=1)
    # L[t, s] = exp(cs[t] - cs[s]) for s <= t  (decay from s+1..t)
    seg = cs[:, :, None] - cs[:, None, :]              # (B,T,S,H)
    mask = jnp.tril(jnp.ones((s, s), bool))
    l_mat = jnp.where(mask[None, :, :, None], jnp.exp(seg), 0.0)
    scores = jnp.einsum("btn,bsn->bts", c_mat, b_mat)  # (B,T,S)
    m = scores[..., None] * l_mat * dt[:, None]        # (B,T,S,H)
    y = jnp.einsum("btsh,bshp->bthp", m, x)
    return y
