"""Jitted public wrapper: full chunked SSD using the Pallas intra-chunk
kernel + the tiny jnp inter-chunk recurrence.  Drop-in replacement for
``repro.nn.ssm.ssd_chunked`` (same signature and semantics)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ssd import ssd_intra_chunk, hbm_bytes_model
from .ref import ssd_dense_ref

__all__ = ["ssd_chunked_pallas", "ssd_dense_ref", "hbm_bytes_model"]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunked_pallas(x, dt, a, b_mat, c_mat, chunk: int, *,
                       interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,N).
    Returns (y (B,S,H,P) fp32, final_state (B,H,P,N))."""
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    s_orig = s
    if s % chunk:
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        s += pad
    nc = s // chunk

    y_intra, states = ssd_intra_chunk(
        x.astype(jnp.float32), dt.astype(jnp.float32), a,
        b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
        chunk=chunk, interpret=interpret)

    # inter-chunk recurrence (tiny: nc steps over (B,H,P,N))
    da_h = (dt * a[None, None]).reshape(bsz, nc, chunk, h) \
        .transpose(0, 1, 3, 2)                          # (B,nc,H,Q)
    chunk_decay = jnp.exp(jnp.sum(da_h, axis=-1))       # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)       # (B,nc,H,P,N)

    # inter-chunk contribution (head-major batched matmul, as in R3.1)
    cc = c_mat.reshape(bsz, nc, chunk, n).astype(jnp.float32)
    decay_from_start = jnp.exp(jnp.cumsum(da_h, axis=-1))
    ch = cc[:, :, None] * decay_from_start[..., None]   # (B,nc,H,Q,N)
    y_inter_h = ch @ jnp.swapaxes(prev_states, -1, -2)  # (B,nc,H,Q,P)
    y_inter = y_inter_h.transpose(0, 1, 3, 2, 4).reshape(bsz, s, h, p)

    y = y_intra + y_inter
    return y[:, :s_orig], final
