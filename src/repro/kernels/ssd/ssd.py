"""Pallas TPU kernel: Mamba2 SSD intra-chunk dual form.

§Perf round 3 follow-up: R3.1's layout restructure halved mamba2's memory
term at the HLO level, but the (Q,Q) chunk matrices (scores, decay L, M)
still round-trip HBM between the XLA dots.  On TPU they belong in VMEM:
this kernel fuses the whole intra-chunk computation — decay segsum,
C·Bᵀ scores, masked M = scores⊙L⊙dt, y_intra = M·X, and the per-chunk
boundary state — into one grid step per (batch·head, chunk).

  grid = (B·H, nc)
  VMEM per step (Q=64, P=64, N=128, fp32):
    x (Q,P) 16K + b,c (Q,N) 2×32K + L/scores/M (Q,Q) 3×16K
    + y (Q,P) 16K + state (P,N) 32K ≈ 180 KiB — far inside ~16 MiB,
  leaving room to raise Q to 128 on real hardware (MXU-preferred).

The tiny inter-chunk recurrence (nc steps over (P,N) states) and the
y_inter correction stay in jnp — they are O(S/Q) and bandwidth-trivial.

HBM traffic model (ops.hbm_bytes_model): each chunk reads x, dt, b, c
once and writes y_intra + state once — no (Q,Q) buffer ever leaves VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref):
    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)        # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)       # scalar decay rate
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)
    q = x.shape[0]

    da = dt * a                               # (Q,)
    cs = jnp.cumsum(da)
    seg = cs[:, None] - cs[None, :]           # (Q, Q)
    qi = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    kj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(kj <= qi, jnp.exp(seg), 0.0)

    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    m = scores * l_mat * dt[None, :]          # (Q, Q)
    y_ref[0] = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # chunk boundary state: st[p, n] = Σ_k exp(cs_Q − cs_k)·dt_k·x[k,p]·b[k,n]
    w = jnp.exp(cs[q - 1] - cs) * dt          # (Q,)
    st_ref[0, 0] = jax.lax.dot_general(
        x, b * w[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_intra_chunk(x, dt, a, b_mat, c_mat, *, chunk: int,
                    interpret: bool = True):
    """Intra-chunk SSD via the Pallas kernel.

    x: (B,S,H,P); dt: (B,S,H) (already softplus'd); a: (H,);
    b/c: (B,S,N); S % chunk == 0.
    Returns (y_intra (B,S,H,P), states (B,nc,H,P,N)) in fp32.
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # head-major flattening: rows are (B·H), kernel indexes chunks
    xh = x.transpose(0, 2, 1, 3).reshape(bsz * h, s, p)
    dth = dt.transpose(0, 2, 1).reshape(bsz * h, s)
    ah = jnp.broadcast_to(a[None], (bsz, h)).reshape(bsz * h, 1)
    # b/c shared across heads: index map divides the row id by H
    y, st = pl.pallas_call(
        _kernel,
        grid=(bsz * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, chunk), lambda r, c: (r, c)),
            pl.BlockSpec((1, 1), lambda r, c: (r, 0)),
            pl.BlockSpec((1, chunk, n), lambda r, c, h=h: (r // h, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda r, c, h=h: (r // h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda r, c: (r, c, 0)),
            pl.BlockSpec((1, 1, p, n), lambda r, c: (r, c, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz * h, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz * h, nc, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(xh, dth, ah, b_mat, c_mat)

    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    st = st.reshape(bsz, h, nc, p, n).transpose(0, 2, 1, 3, 4)
    return y, st


def hbm_bytes_model(bsz: int, s: int, h: int, p: int, n: int, *,
                    chunk: int = 64, itemsize: int = 4) -> int:
    """Kernel HBM traffic: x,dt read + y written per (b,h); b,c read per
    (b,h) chunk pass; boundary states written once.  No (Q,Q) traffic."""
    nc = -(-s // chunk)
    xy = 2 * bsz * h * s * p
    dtb = bsz * h * s
    bc = 2 * bsz * h * s * n
    states = bsz * h * nc * p * n
    return (xy + dtb + bc + states) * itemsize
