from .ops import ssd_chunked_pallas, ssd_dense_ref, hbm_bytes_model  # noqa: F401
