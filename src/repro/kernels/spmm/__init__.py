from .ops import BlockSparseDev, block_sparse_dev, aggregate_pallas  # noqa: F401
from .ref import spmm_ref, spmm_dense_ref  # noqa: F401
from .spmm import spmm_block_sparse  # noqa: F401
