from .ops import (BlockSparseDev, BlockSparsePlanDev, block_sparse_dev,
                  block_sparse_plan_dev, square_plan_dev, aggregate_pallas,
                  aggregate_plan)  # noqa: F401
from .ref import spmm_ref, spmm_dense_ref  # noqa: F401
from .spmm import spmm_block_sparse, resolve_interpret  # noqa: F401
