"""Pallas TPU kernel: block-sparse SpMM for full-graph GNN aggregation.

The paper's compute hot-spot is the graph aggregation ``Â @ H`` executed by
every worker on its feature slice.  A GPU implementation would gather rows
with scatter/atomics; that ports badly to TPU, so we adapt the insight to
the MXU: the normalized adjacency is stored as dense ``(bs × bs)`` tiles for
the non-empty (dst-block, src-block) pairs (``repro.graph.format
.block_sparse``) and aggregation becomes a sequence of small dense matmuls

    out[r(k)] (+)= blocks[k] @ h[c(k)]        k = 0..nnzb-1, sorted by r(k)

Scheduling:
  * grid = (d_tiles, nnzb) — the tile index k iterates fastest, so all tiles
    of one destination row-block are consecutive and the output block stays
    resident in VMEM while it accumulates (revisiting pattern).
  * the (r(k), c(k), first(k)) tables are scalar-prefetched so the
    BlockSpec index maps can look them up before each step's DMA.
  * VMEM working set per step: bs·bs (tile) + bs·dt (src rows) + bs·dt
    (out) floats — bs=dt=128 ⇒ ~192 KiB in fp32, well inside the ~16 MiB
    VMEM budget, MXU-aligned on both matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, cols_ref, first_ref, blocks_ref, h_ref, out_ref):
    k = pl.program_id(1)
    a = blocks_ref[0]                      # (bs, bs) adjacency tile
    x = h_ref[...]                         # (bs, dt) source feature rows
    contrib = jnp.dot(a, x, preferred_element_type=jnp.float32)

    @pl.when(first_ref[k] == 1)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(first_ref[k] == 0)
    def _acc():
        out_ref[...] = (out_ref[...].astype(jnp.float32)
                        + contrib).astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("d_tile", "interpret"))
def spmm_block_sparse(blocks: jax.Array, block_rows: jax.Array,
                      block_cols: jax.Array, row_first: jax.Array,
                      h: jax.Array, *, d_tile: int = 128,
                      interpret: bool = True) -> jax.Array:
    """out = A @ h with A given as sorted block tiles.

    blocks     : (nnzb, bs, bs) float
    block_rows : (nnzb,) int32 non-decreasing destination block ids
    block_cols : (nnzb,) int32 source block ids
    row_first  : (nnzb,) int32 — 1 iff first tile of its destination row
    h          : (n_padded, d) with n_padded % bs == 0 and d % d_tile == 0
    """
    nnzb, bs, _ = blocks.shape
    n_padded, d = h.shape
    assert n_padded % bs == 0, (n_padded, bs)
    assert d % d_tile == 0, (d, d_tile)
    d_tiles = d // d_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(d_tiles, nnzb),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda j, k, rows, cols, first:
                         (k, 0, 0)),
            pl.BlockSpec((bs, d_tile), lambda j, k, rows, cols, first:
                         (cols[k], j)),
        ],
        out_specs=pl.BlockSpec((bs, d_tile), lambda j, k, rows, cols, first:
                               (rows[k], j)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_padded, d), h.dtype),
        interpret=interpret,
    )
    return fn(block_rows, block_cols, row_first, blocks, h)
