"""Pallas TPU kernel: block-sparse SpMM for full-graph GNN aggregation.

The paper's compute hot-spot is the graph aggregation ``Â @ H`` executed by
every worker on its feature slice.  A GPU implementation would gather rows
with scatter/atomics; that ports badly to TPU, so we adapt the insight to
the MXU: the normalized adjacency is stored as dense ``(bs × bs)`` tiles for
the non-empty (dst-block, src-block) pairs (``repro.graph.format
.block_sparse``) and aggregation becomes a sequence of small dense matmuls

    out[r(k)] (+)= blocks[k] @ h[c(k)]        k = 0..nnzb-1, sorted by r(k)

Scheduling:
  * grid = (d_tiles, nnzb) — the tile index k iterates fastest, so all tiles
    of one destination row-block are consecutive and the output block stays
    resident in VMEM while it accumulates (revisiting pattern).
  * the (r(k), c(k), first(k)) tables are scalar-prefetched so the
    BlockSpec index maps can look them up before each step's DMA.
  * VMEM working set per step: bs·bs (tile) + bs·dt (src rows) + bs·dt
    (out) floats — bs=dt=128 ⇒ ~192 KiB in fp32, well inside the ~16 MiB
    VMEM budget, MXU-aligned on both matmul dims.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, cols_ref, first_ref, blocks_ref, h_ref, out_ref):
    k = pl.program_id(1)
    a = blocks_ref[0]                      # (bs, bs) adjacency tile
    x = h_ref[...]                         # (bs, dt) source feature rows
    contrib = jnp.dot(a, x, preferred_element_type=jnp.float32)

    @pl.when(first_ref[k] == 1)
    def _init():
        out_ref[...] = contrib.astype(out_ref.dtype)

    @pl.when(first_ref[k] == 0)
    def _acc():
        out_ref[...] = (out_ref[...].astype(jnp.float32)
                        + contrib).astype(out_ref.dtype)


def resolve_interpret(interpret: bool | None) -> bool:
    """The kernel's ``interpret`` auto-contract.

    ``None`` (the default) resolves at trace time to *interpret unless the
    program is actually lowering for TPU* — so CPU test rigs and the forced
    host-device harness run the Pallas interpreter transparently, while a
    real-TPU caller gets the compiled kernel without having to remember to
    flip a flag.  An explicit ``True``/``False`` always wins (tests pin the
    interpreter; a TPU debug session can force it on)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


@functools.partial(jax.jit,
                   static_argnames=("d_tile", "interpret", "n_out"))
def spmm_block_sparse(blocks: jax.Array, block_rows: jax.Array,
                      block_cols: jax.Array, row_first: jax.Array,
                      h: jax.Array, *, d_tile: int = 128,
                      interpret: bool | None = None,
                      n_out: int | None = None) -> jax.Array:
    """out = A @ h with A given as sorted block tiles.

    blocks     : (nnzb, bs, bs) float
    block_rows : (nnzb,) int32 non-decreasing destination block ids
    block_cols : (nnzb,) int32 source block ids
    row_first  : (nnzb,) int32 — 1 iff first tile of its destination row
    h          : (n_padded, d) with n_padded % bs == 0 and d % d_tile == 0
    n_out      : output rows (multiple of bs); defaults to n_padded.  A
                 rectangular A slice (per-chunk forward, transposed
                 backward) has out rows ≠ in rows.
    interpret  : None → auto (:func:`resolve_interpret`): interpret
                 everywhere except a real TPU backend.
    """
    nnzb, bs, _ = blocks.shape
    n_padded, d = h.shape
    n_out = n_padded if n_out is None else n_out
    if n_padded % bs:
        raise ValueError(
            f"spmm_block_sparse: h has {n_padded} rows, not a multiple of "
            f"the block size bs={bs} — pad the source rows first")
    if n_out % bs:
        raise ValueError(
            f"spmm_block_sparse: n_out={n_out} is not a multiple of the "
            f"block size bs={bs}")
    if d % d_tile:
        raise ValueError(
            f"spmm_block_sparse: feature dim d={d} is not a multiple of "
            f"d_tile={d_tile} — pad the feature dim first")
    interpret = resolve_interpret(interpret)
    d_tiles = d // d_tile

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(d_tiles, nnzb),
        in_specs=[
            pl.BlockSpec((1, bs, bs), lambda j, k, rows, cols, first:
                         (k, 0, 0)),
            pl.BlockSpec((bs, d_tile), lambda j, k, rows, cols, first:
                         (cols[k], j)),
        ],
        out_specs=pl.BlockSpec((bs, d_tile), lambda j, k, rows, cols, first:
                               (rows[k], j)),
    )
    fn = pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_out, d), h.dtype),
        interpret=interpret,
    )
    return fn(block_rows, block_cols, row_first, blocks, h)
