"""Pure-jnp oracle for the block-sparse SpMM kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def spmm_ref(blocks: jax.Array, block_rows: jax.Array,
             block_cols: jax.Array, h: jax.Array,
             n_out: int | None = None) -> jax.Array:
    """out[r] = Σ_k [rows[k]==r] blocks[k] @ h_block[cols[k]]   (dense math).

    Independent of the kernel's scheduling: gathers source blocks, does one
    batched matmul, and segment-sums per destination block.  ``n_out``
    (multiple of bs) sets the output rows for rectangular A slices.
    """
    nnzb, bs, _ = blocks.shape
    n_padded, d = h.shape
    n_out = n_padded if n_out is None else n_out
    n_in_blocks = n_padded // bs
    n_out_blocks = n_out // bs
    h_blocked = h.reshape(n_in_blocks, bs, d)
    contribs = jnp.einsum("kab,kbd->kad", blocks,
                          h_blocked[block_cols],
                          preferred_element_type=jnp.float32)
    out = jax.ops.segment_sum(contribs, block_rows,
                              num_segments=n_out_blocks)
    return out.reshape(n_out, d).astype(h.dtype)


def spmm_dense_ref(dense_a: jax.Array, h: jax.Array) -> jax.Array:
    """Fully dense oracle (small graphs only)."""
    return (dense_a @ h.astype(jnp.float32)).astype(h.dtype)
