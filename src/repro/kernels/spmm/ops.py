"""Jitted public wrappers around the block-sparse SpMM Pallas kernel.

Two device-side containers:

* :class:`BlockSparseDev`     — forward tiles only (kernel benchmarking,
  one-shot aggregation; autodiff differentiates *through* the pallas_call).
* :class:`BlockSparsePlanDev` — forward + transposed tiles.  Aggregations
  through a plan carry a ``jax.custom_vjp`` whose backward multiplies the
  cotangent by the precomputed Âᵀ tiles through the same kernel, so the
  gradient is exact (Â is constant data) and never depends on Pallas
  autodiff support.

GAT exclusion: the engines route only *static-weight* aggregation (GCN /
SAGE / GIN, where Â is fixed per graph) through these kernels.  GAT's edge
weights α are computed at runtime from the layer's features — they cannot
be baked into precomputed tiles — so GAT always aggregates via the
segment-sum backend (see ``repro.core.agg``).

``interpret=None`` everywhere means auto: run the Pallas interpreter unless
the program is lowering for a real TPU (``spmm.resolve_interpret``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ...graph.format import (BlockSparseGraph, BlockSparsePlan,
                             block_sparse_transpose)
from .spmm import spmm_block_sparse
from .ref import spmm_ref


@partial(jax.tree_util.register_dataclass,
         data_fields=("blocks", "block_rows", "block_cols", "row_first"),
         meta_fields=("n", "n_padded", "bs"))
@dataclasses.dataclass(frozen=True)
class BlockSparseDev:
    blocks: jax.Array
    block_rows: jax.Array
    block_cols: jax.Array
    row_first: jax.Array
    n: int
    n_padded: int
    bs: int


def block_sparse_dev(bsg: BlockSparseGraph,
                     dtype=jnp.float32) -> BlockSparseDev:
    return BlockSparseDev(
        blocks=jnp.asarray(bsg.blocks, dtype),
        block_rows=jnp.asarray(bsg.block_rows),
        block_cols=jnp.asarray(bsg.block_cols),
        row_first=jnp.asarray(bsg.row_first),
        n=bsg.n, n_padded=bsg.n_padded, bs=bsg.bs)


@partial(jax.tree_util.register_dataclass,
         data_fields=("blocks", "block_rows", "block_cols", "row_first",
                      "blocks_t", "block_rows_t", "block_cols_t",
                      "row_first_t"),
         meta_fields=("n_rows", "n_cols", "rows_padded", "cols_padded",
                      "bs"))
@dataclasses.dataclass(frozen=True)
class BlockSparsePlanDev:
    """Device mirror of :class:`repro.graph.format.BlockSparsePlan`.

    Data arrays may carry one leading stack axis (chunks / DP workers)
    which ``lax.scan`` unstacks; the static meta is shared across the
    stack, so a scanned-out slice is again a valid plan instance."""

    blocks: jax.Array
    block_rows: jax.Array
    block_cols: jax.Array
    row_first: jax.Array
    blocks_t: jax.Array
    block_rows_t: jax.Array
    block_cols_t: jax.Array
    row_first_t: jax.Array
    n_rows: int
    n_cols: int
    rows_padded: int
    cols_padded: int
    bs: int


def block_sparse_plan_dev(plan: BlockSparsePlan,
                          dtype=jnp.float32) -> BlockSparsePlanDev:
    return BlockSparsePlanDev(
        blocks=jnp.asarray(plan.blocks, dtype),
        block_rows=jnp.asarray(plan.block_rows),
        block_cols=jnp.asarray(plan.block_cols),
        row_first=jnp.asarray(plan.row_first),
        blocks_t=jnp.asarray(plan.blocks_t, dtype),
        block_rows_t=jnp.asarray(plan.block_rows_t),
        block_cols_t=jnp.asarray(plan.block_cols_t),
        row_first_t=jnp.asarray(plan.row_first_t),
        n_rows=plan.n_rows, n_cols=plan.n_cols,
        rows_padded=plan.rows_padded, cols_padded=plan.cols_padded,
        bs=plan.bs)


def square_plan_dev(bsg: BlockSparseGraph,
                    dtype=jnp.float32) -> BlockSparsePlanDev:
    """Full-graph (square Â) plan: forward tiles + Âᵀ tiles for the VJP."""
    t = block_sparse_transpose(bsg)
    return BlockSparsePlanDev(
        blocks=jnp.asarray(bsg.blocks, dtype),
        block_rows=jnp.asarray(bsg.block_rows),
        block_cols=jnp.asarray(bsg.block_cols),
        row_first=jnp.asarray(bsg.row_first),
        blocks_t=jnp.asarray(t.blocks, dtype),
        block_rows_t=jnp.asarray(t.block_rows),
        block_cols_t=jnp.asarray(t.block_cols),
        row_first_t=jnp.asarray(t.row_first),
        n_rows=bsg.n, n_cols=bsg.n,
        rows_padded=bsg.n_padded, cols_padded=bsg.n_padded, bs=bsg.bs)


def _run_tiles(blocks, rows, cols, first, h, n_in_padded: int, n_out: int,
               d_tile: int, interpret, use_ref: bool):
    """Pad h (rows → n_in_padded, d → d_tile multiple), run, unpad d."""
    n, d = h.shape
    dt = min(d_tile, _round_up(d, 8))
    d_pad = _round_up(d, dt) - d
    hp = jnp.pad(h, ((0, n_in_padded - n), (0, d_pad)))
    if use_ref:
        out = spmm_ref(blocks, rows, cols, hp, n_out=n_out)
    else:
        out = spmm_block_sparse(blocks, rows, cols, first, hp,
                                d_tile=dt, interpret=interpret,
                                n_out=n_out)
    return out[:, :d]


def _zero_cotangent(tree):
    """Cotangent of a non-differentiable operand pytree: zeros for float
    leaves, ``float0`` for integer leaves (jax's tangent dtype for them)."""
    def zero(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return jnp.zeros(x.shape, x.dtype)
        return np.zeros(x.shape, jax.dtypes.float0)
    return jax.tree.map(zero, tree)


@partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _plan_spmm(n_in, d_tile, interpret, use_ref, plan, h):
    return _run_tiles(plan.blocks, plan.block_rows, plan.block_cols,
                      plan.row_first, h, plan.cols_padded,
                      plan.rows_padded, d_tile, interpret, use_ref)


def _plan_spmm_fwd(n_in, d_tile, interpret, use_ref, plan, h):
    return _plan_spmm(n_in, d_tile, interpret, use_ref, plan, h), plan


def _plan_spmm_bwd(n_in, d_tile, interpret, use_ref, plan, gy):
    # grad_h = Âᵀ @ gy through the same kernel on the transposed tiles.
    # The padded-row tail of the primal output was sliced away by the
    # caller, so its cotangent rows arrive as exact zeros and contribute
    # nothing — no masking needed.
    gh = _run_tiles(plan.blocks_t, plan.block_rows_t, plan.block_cols_t,
                    plan.row_first_t, gy, plan.rows_padded,
                    plan.cols_padded, d_tile, interpret, use_ref)
    return _zero_cotangent(plan), gh[:n_in]


_plan_spmm.defvjp(_plan_spmm_fwd, _plan_spmm_bwd)


def aggregate_plan(plan: BlockSparsePlanDev, h: jax.Array, *,
                   d_tile: int = 128, interpret: bool | None = None,
                   use_ref: bool = False) -> jax.Array:
    """One plan instance: ``(rows_padded, d) = Â_plan @ h`` with the exact
    custom VJP through the transposed tiles.  ``h`` is (n_in, d) with
    n_in ≤ cols_padded (rows are zero-padded internally); the caller
    slices the real output rows (``[:plan.n_rows]``)."""
    return _plan_spmm(h.shape[0], d_tile, interpret, use_ref, plan, h)


def aggregate_pallas(bsg: BlockSparseDev | BlockSparsePlanDev,
                     h: jax.Array, *, d_tile: int = 128,
                     interpret: bool | None = None,
                     use_ref: bool = False) -> jax.Array:
    """Â @ h via the Pallas kernel; pads rows/dims, unpads the result.

    Given a :class:`BlockSparsePlanDev` (square), the multiply carries the
    custom VJP: the backward multiplies the cotangent by the precomputed
    Âᵀ tiles through the same kernel instead of differentiating through
    the pallas_call.  A plain :class:`BlockSparseDev` runs forward-only
    tiles (autodiff, if requested, goes through the kernel itself).

    ``interpret=None`` → auto: interpret everywhere except a real TPU
    backend (``spmm.resolve_interpret``); tests pass ``True`` to pin the
    interpreter, a TPU caller may pass ``False`` explicitly.  ``use_ref``
    short-circuits to the jnp oracle (useful to A/B inside larger models).

    Note: only static-weight aggregation can use these tiles — GAT's
    runtime attention weights keep it on the segment-sum path (module
    docstring)."""
    n, d = h.shape
    if isinstance(bsg, BlockSparsePlanDev):
        return aggregate_plan(bsg, h, d_tile=d_tile, interpret=interpret,
                              use_ref=use_ref)[:n]
    pad_rows = bsg.n_padded - n
    d_tile = min(d_tile, _round_up(d, 8))
    d_pad = _round_up(d, d_tile) - d
    hp = jnp.pad(h, ((0, pad_rows), (0, d_pad)))
    if use_ref:
        out = spmm_ref(bsg.blocks, bsg.block_rows, bsg.block_cols, hp)
    else:
        out = spmm_block_sparse(bsg.blocks, bsg.block_rows, bsg.block_cols,
                                bsg.row_first, hp, d_tile=d_tile,
                                interpret=interpret)
    return out[:n, :d]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
