"""Jitted public wrapper around the block-sparse SpMM Pallas kernel."""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from ...graph.format import BlockSparseGraph
from .spmm import spmm_block_sparse
from .ref import spmm_ref


@partial(jax.tree_util.register_dataclass,
         data_fields=("blocks", "block_rows", "block_cols", "row_first"),
         meta_fields=("n", "n_padded", "bs"))
@dataclasses.dataclass(frozen=True)
class BlockSparseDev:
    blocks: jax.Array
    block_rows: jax.Array
    block_cols: jax.Array
    row_first: jax.Array
    n: int
    n_padded: int
    bs: int


def block_sparse_dev(bsg: BlockSparseGraph,
                     dtype=jnp.float32) -> BlockSparseDev:
    return BlockSparseDev(
        blocks=jnp.asarray(bsg.blocks, dtype),
        block_rows=jnp.asarray(bsg.block_rows),
        block_cols=jnp.asarray(bsg.block_cols),
        row_first=jnp.asarray(bsg.row_first),
        n=bsg.n, n_padded=bsg.n_padded, bs=bsg.bs)


def aggregate_pallas(bsg: BlockSparseDev, h: jax.Array, *,
                     d_tile: int = 128, interpret: bool = True,
                     use_ref: bool = False) -> jax.Array:
    """Â @ h via the Pallas kernel; pads rows/dims, unpads the result.

    ``interpret=True`` executes the kernel body on CPU (validation mode);
    on real TPU pass ``interpret=False``.  ``use_ref`` short-circuits to the
    jnp oracle (useful to A/B inside larger models).
    """
    n, d = h.shape
    pad_rows = bsg.n_padded - n
    d_tile = min(d_tile, _round_up(d, 8))
    d_pad = _round_up(d, d_tile) - d
    hp = jnp.pad(h, ((0, pad_rows), (0, d_pad)))
    if use_ref:
        out = spmm_ref(bsg.blocks, bsg.block_rows, bsg.block_cols, hp)
    else:
        out = spmm_block_sparse(bsg.blocks, bsg.block_rows, bsg.block_cols,
                                bsg.row_first, hp, d_tile=d_tile,
                                interpret=interpret)
    return out[:n, :d]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m
