"""Pallas TPU kernel: flash attention (GQA-aware, causal/windowed/softcap).

The §Roofline baseline shows the memory term of every *_32k prefill and
train_4k combo is dominated by attention score traffic: even the jnp
blockwise schedule keeps its (block_q × block_kv) score/prob temporaries in
HBM at the HLO level.  On TPU the fix is structural — the score block must
live and die in VMEM.  This kernel is the flash-attention schedule with
explicit BlockSpec tiling:

  grid = (B·Hq, nq, nkv)    kv innermost (revisiting accumulation)
  VMEM per step: q (bq × hd) + k,v (bkv × hd) + scores (bq × bkv)
                 + acc (bq × hd) + m,l (bq)
  bq = bkv = 512, hd = 128 ⇒ ~2.6 MiB fp32 — inside the ~16 MiB VMEM
  budget with headroom for double-buffered DMAs; both matmul dims are
  multiples of 128 (MXU-aligned).

GQA is handled in the k/v BlockSpec index maps: query head h reads kv head
h // (Hq/Hkv) — no head replication in HBM.

HBM traffic per (b, h): Q once, O once, K/V once per q-block
  ⇒ bytes ≈ B·Hq·(2·Sq·hd + 2·nq·Skv·hd_kv)·itemsize
which is the "kernel-corrected" memory term quoted in §Perf (the dry-run
HLO census cannot see VMEM residency — CPU backend Pallas is
interpret-only — so §Perf reports both the census number and this model).

Causal block skipping: steps with block_kv_start > block_q_end contribute
nothing; ``pl.when`` guards the compute so the MXU work is skipped on
TPU (the DMA for the skipped block is still scheduled — acceptable, since
fetching K/V is ≤ ¼ of the compute-side win at these block shapes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_kv: int, nkv: int,
            sq: int, skv: int, causal: bool, window, softcap):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = kj * block_kv
    # causal: skip blocks entirely above the diagonal
    needed = True
    if causal:
        needed = k_start <= q_start + block_q - 1
    if window is not None:
        needed = needed & (k_start + block_kv - 1 > q_start - window)

    @pl.when(needed)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0].astype(jnp.float32)                  # (bkv, hd)
        v = v_ref[0].astype(jnp.float32)                  # (bkv, hdv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_kv), 1)
        mask = k_pos < skv                                 # kv padding
        mask &= q_pos < sq                                 # q padding
        if causal:
            mask &= k_pos <= q_pos
        if window is not None:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                # (bq,)
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_prev * alpha + p.sum(axis=1)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kj == nkv - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                              "block_q", "block_kv", "interpret"))
def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True, window: int | None = None,
                         softcap: float | None = None,
                         scale: float | None = None,
                         block_q: int = 512, block_kv: int = 512,
                         interpret: bool = True) -> jax.Array:
    """Flash attention on head-major layouts.

    q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd[_v]) with Hq % Hkv == 0.
    Sq/Skv may be arbitrary (padded internally to block multiples).
    Returns (B, Hq, Sq, hd_v) in q.dtype.
    """
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5

    block_q = min(block_q, _round_up(sq, 8))
    block_kv = min(block_kv, _round_up(skv, 8))
    q_pad = (-sq) % block_q
    kv_pad = (-skv) % block_kv
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, q_pad), (0, 0)))
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, kv_pad), (0, 0)))
    nq = (sq + q_pad) // block_q
    nkv = (skv + kv_pad) // block_kv

    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_kv=block_kv,
        nkv=nkv, sq=sq, skv=skv, causal=causal, window=window,
        softcap=softcap)

    out = pl.pallas_call(
        kernel,
        grid=(b * hq, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, block_q, hd),
                         lambda bh, i, j: (bh, i, 0)),
            pl.BlockSpec((1, block_kv, hd),
                         lambda bh, i, j, g=g, hq=hq:
                         ((bh // hq) * hkv + (bh % hq) // g, j, 0)),
            pl.BlockSpec((1, block_kv, hdv),
                         lambda bh, i, j, g=g, hq=hq:
                         ((bh // hq) * hkv + (bh % hq) // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hdv),
                               lambda bh, i, j: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq + q_pad, hdv), q.dtype),
        scratch_shapes=[
            _vmem_scratch((block_q,), jnp.float32),    # running max m
            _vmem_scratch((block_q,), jnp.float32),    # running sum l
            _vmem_scratch((block_q, hdv), jnp.float32),  # accumulator
        ],
        interpret=interpret,
    )(q.reshape(b * hq, sq + q_pad, hd),
      k.reshape(b * hkv, skv + kv_pad, hd),
      v.reshape(b * hkv, skv + kv_pad, hdv))
    return out.reshape(b, hq, sq + q_pad, hdv)[:, :, :sq]


def _vmem_scratch(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def hbm_bytes_model(b: int, hq: int, hkv: int, sq: int, skv: int,
                    hd: int, hdv: int, *, block_q: int = 512,
                    itemsize: int = 4) -> int:
    """Analytic HBM traffic of this kernel's schedule (the VMEM-resident
    score block never touches HBM): Q+O once, K/V once per q-block."""
    nq = -(-sq // block_q)
    q_o = b * hq * sq * (hd + hdv)
    kv = b * hkv * nq * skv * (hd + hdv)
    return (q_o + kv) * itemsize
