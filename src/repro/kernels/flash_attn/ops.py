"""Jitted public wrapper around the flash-attention Pallas kernel.

Accepts the model's (B, S, H, hd) token-major layout, transposes to the
kernel's head-major layout, and dispatches to the kernel (interpret=True on
CPU — validation mode) or the jnp oracle.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .flash import flash_attention_bhsd, hbm_bytes_model
from .ref import flash_ref

__all__ = ["flash_attention", "flash_ref", "hbm_bytes_model"]


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "scale",
                                   "block_q", "block_kv", "interpret",
                                   "use_ref"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: Optional[int] = None,
                    softcap: Optional[float] = None,
                    scale: Optional[float] = None,
                    block_q: int = 512, block_kv: int = 512,
                    interpret: bool = True,
                    use_ref: bool = False) -> jax.Array:
    """q: (B, Sq, Hq, hd); k/v: (B, Skv, Hkv, hd[_v]) → (B, Sq, Hq, hd_v).

    ``interpret=True`` executes the kernel body on CPU; pass False on TPU.
    ``use_ref`` short-circuits to the dense oracle (A/B inside models)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if use_ref:
        out = flash_ref(qt, kt, vt, causal=causal, window=window,
                        softcap=softcap, scale=scale)
    else:
        out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                                   softcap=softcap, scale=scale,
                                   block_q=block_q, block_kv=block_kv,
                                   interpret=interpret)
    return out.transpose(0, 2, 1, 3)
