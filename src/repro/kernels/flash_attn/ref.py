"""Pure-jnp oracle for the flash-attention kernel (head-major layout)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def flash_ref(q, k, v, *, causal: bool = True,
              window: Optional[int] = None,
              softcap: Optional[float] = None,
              scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, Hq, Sq, hd); k/v: (B, Hkv, Skv, hd[_v]).  Dense math —
    materializes the full score matrix (small shapes only)."""
    b, hq, sq, hd = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    kq = jnp.repeat(k, g, axis=1)          # (B, Hq, Skv, hd)
    vq = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kq.astype(jnp.float32)) * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= kj > qi - window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vq.astype(jnp.float32))
    return out.astype(q.dtype)
