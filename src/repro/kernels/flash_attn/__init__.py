from .ops import flash_attention, flash_ref, hbm_bytes_model  # noqa: F401
