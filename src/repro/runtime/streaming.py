"""Host→device staging primitives for out-of-core chunk streaming.

The §4.2 out-of-core path (:mod:`repro.core.stream`) keeps features and
chunk plans in host numpy and walks them through a **double-buffered
prefetch**: while the device consumes staged item ``c``, item ``c+1``'s
``device_put`` is already in flight (jax transfers are async — the
enqueue returns immediately and XLA overlaps the copy with compute).
This module owns the three primitives that make that honest:

* :func:`stage`      — place one host pytree on the mesh
  (:func:`repro.runtime.distributed.put_global` per leaf, so the same
  call works on a multi-process mesh) and record its bytes in the
  telemetry H2D column (:func:`repro.runtime.telemetry.record_h2d`) —
  staged bytes are measured, never inferred.
* :func:`prefetched` — generator that keeps at most ``depth`` staged
  items alive (the two-item footprint contract: the item being consumed
  plus the one in flight).
* :func:`global_zeros` — allocate a zero-initialized global array with a
  given sharding *without* a host round trip (jitted zeros with
  ``out_shardings``; each process materializes only its shards) — the
  accumulator/double buffers the streaming driver donates back into its
  programs.

Donation is how the footprint stays at two staged items regardless of V:
consumed buffers are handed back to XLA (``donate_argnums``) instead of
accumulating.  The CPU backend does not implement buffer donation (XLA
warns and copies), so :func:`donation_supported` gates it — the
*structure* of the streaming path is identical either way, which is what
the forced-host-device tests exercise.
"""
from __future__ import annotations

import collections
import functools
from typing import Any, Callable, Iterable, Iterator

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from . import telemetry as T
from .distributed import process_count, put_global
from .mesh import as_mesh

__all__ = [
    "donation_supported", "global_zeros", "prefetched", "stage",
    "sync_for_collectives",
]


def stage(tree: Any, mesh, spec=P(), *, label: str = "host") -> Any:
    """Stage one host pytree onto ``mesh`` with layout ``spec`` (every
    leaf the same spec), recording its bytes in the H2D telemetry
    column.  Returns the device pytree; the transfer is async — reading
    the result blocks until it lands, enqueuing it does not."""
    leaves, treedef = jax.tree.flatten(tree)
    T.record_h2d(leaves, label=label)
    return jax.tree.unflatten(
        treedef, [put_global(l, mesh, spec) for l in leaves])


def prefetched(items: Iterable[Any], stage_fn: Callable[[Any], Any], *,
               depth: int = 2) -> Iterator[Any]:
    """Yield ``stage_fn(item)`` for each item, keeping up to ``depth``
    staged items in flight ahead of the consumer.

    ``depth=2`` is the double buffer: when the caller receives item
    ``c``, item ``c+1`` has already been enqueued, so its host→device
    copy overlaps the caller's compute on ``c``.  The generator holds
    references to at most ``depth`` staged items — together with the
    caller's donation of consumed buffers this bounds device residency
    at two staged items regardless of how many the sequence yields."""
    if depth < 1:
        raise ValueError(f"prefetched depth must be >= 1, got {depth}")
    buf: collections.deque = collections.deque()
    for item in items:
        buf.append(stage_fn(item))
        if len(buf) > depth - 1:
            yield buf.popleft()
    while buf:
        yield buf.popleft()


@functools.lru_cache(maxsize=None)
def _zeros_program(sharding: NamedSharding, shape: tuple, dtype):
    return jax.jit(lambda: jnp.zeros(shape, dtype),
                   out_shardings=sharding)


def global_zeros(mesh, spec, shape, dtype=jnp.float32) -> jax.Array:
    """Zero-initialized global array on ``mesh``/``spec``, allocated
    device-side (no host buffer of size ``shape`` ever exists).  The
    jitted zeros program is cached per (sharding, shape, dtype), so
    per-round buffer allocation in the streaming driver costs one trace
    total."""
    return _zeros_program(NamedSharding(as_mesh(mesh), spec),
                          tuple(shape), jnp.dtype(dtype))()


def donation_supported() -> bool:
    """Whether the default backend honors ``donate_argnums`` (CPU does
    not — XLA falls back to a copy with a warning per call)."""
    return jax.default_backend() != "cpu"


def sync_for_collectives(x: Any) -> Any:
    """Barrier between collective-bearing executables on a multi-process
    mesh: gloo cannot have two executables' collectives concurrently in
    flight (the single-executable discipline of
    :func:`repro.core.decouple.bundled_value_and_grad`).  The streaming
    driver dispatches *several* executables per epoch, so it blocks on
    the previous program's results before launching the next
    collective-bearing one.  Single-process this is a no-op — the whole
    point of async staging is not to synchronize."""
    if process_count() > 1:
        jax.block_until_ready(x)
    return x
