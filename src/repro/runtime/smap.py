"""Version-portable ``shard_map``: one entry point for sharded execution.

JAX has moved (and re-keyworded) shard_map across releases:

* 0.4.x       — ``jax.experimental.shard_map.shard_map(...)`` with the
                replication-check flag spelled ``check_rep``;
* newer lines — ``jax.shard_map(...)`` at top level, with the flag renamed
                ``check_vma`` (varying-manual-axes checking).

The split/gather collectives in ``core.tp`` produce outputs whose
replication the checker cannot always infer, so every call site in this
repo disables the check.  Rather than copy the version probe into each
subsystem, :func:`resolve_shard_map` runs once at import time and
:func:`smap` / :func:`engine` present a single stable signature.

``engine(fn, in_specs, out_specs, mesh=...)`` is the only way repo code
should enter sharded execution; specs are validated eagerly against the
mesh so a bad axis name fails at build time with a readable error instead
of deep inside jax tracing.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax
from jax.sharding import PartitionSpec as P

from .mesh import as_mesh, tp_mesh

JAX_VERSION = jax.__version__

#: JAX release lines the shim is known to resolve on (see CHANGES.md).
SUPPORTED_JAX = ">=0.4.30 (check_rep spelling) and >=0.5 (check_vma spelling)"


def resolve_shard_map() -> tuple[Callable, str | None]:
    """Locate shard_map on the installed JAX and its check-flag keyword.

    Returns ``(impl, check_kw)`` where ``check_kw`` is ``"check_vma"``,
    ``"check_rep"``, or ``None`` when the installed signature has neither
    (the flag is simply dropped).
    """
    impl = getattr(jax, "shard_map", None)
    if impl is None:
        from jax.experimental.shard_map import shard_map as impl
    try:
        params = inspect.signature(impl).parameters
    except (TypeError, ValueError):  # C-level or wrapped callables
        params = {}
    if "check_vma" in params:
        return impl, "check_vma"
    if "check_rep" in params:
        return impl, "check_rep"
    return impl, None


_SHARD_MAP, CHECK_KW = resolve_shard_map()


def _iter_spec_leaves(specs):
    """Yield PartitionSpec/None leaves of a specs pytree.

    PartitionSpec is a tuple subclass, so generic flattening would walk
    *into* it; stop at P (and None) explicitly.
    """
    leaves, _ = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: x is None or isinstance(x, P))
    return leaves


def validate_specs(mesh, specs, name: str = "specs") -> None:
    """Eagerly reject malformed specs with an error naming the culprit."""
    mesh = as_mesh(mesh)
    axes = set(mesh.axis_names)
    for leaf in _iter_spec_leaves(specs):
        if leaf is None:
            continue
        if not isinstance(leaf, P):
            raise TypeError(
                f"{name}: expected PartitionSpec (or None) leaves, got "
                f"{type(leaf).__name__}: {leaf!r}")
        used: list[str] = []
        for entry in leaf:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for ax in names:
                if ax not in axes:
                    raise ValueError(
                        f"{name}: {leaf} names mesh axis {ax!r} but the "
                        f"mesh only has axes {sorted(axes)}")
                if ax in used:
                    raise ValueError(
                        f"{name}: {leaf} uses mesh axis {ax!r} on more "
                        f"than one dimension")
                used.append(ax)


def smap(fn: Callable, mesh, in_specs, out_specs, *,
         check: bool = False, validate: bool = True) -> Callable:
    """Portable shard_map with the check flag translated per JAX version."""
    mesh = as_mesh(mesh)
    if validate:
        validate_specs(mesh, in_specs, "in_specs")
        validate_specs(mesh, out_specs, "out_specs")
    kwargs: dict[str, Any] = {CHECK_KW: check} if CHECK_KW else {}
    return _SHARD_MAP(fn, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kwargs)


def engine(fn: Callable, in_specs, out_specs, *, mesh=None,
           check: bool = False, backend: str = "explicit") -> Callable:
    """The repo-wide sharded-execution entry point.

    ``mesh`` may be a TPMesh, a raw jax Mesh, or None (a fresh 1-D "model"
    mesh over every visible device — under a ``jax.distributed`` job that
    is the *global* ``jax.devices()``, so the default is multihost-correct:
    every process maps the same program over the same global mesh while
    holding only its local devices; operands must then be global arrays,
    see :func:`repro.runtime.distributed.put_global` and the bundle
    ``mesh=`` placement).  Multi-axis meshes (``hybrid_mesh``'s
    (data, model) / (pod, data, model)) are first-class on both backends:
    a spec dimension may name a tuple of mesh axes — the hybrid vertex
    layout ``P(("model",) + data_axes)`` shards the batch/replica
    dimension over the data axes while the feature gather/split
    transitions stay on "model".  Returns the mapped callable; wrap in
    ``jax.jit`` at the call site as usual.

    ``backend`` selects how sharded execution is realized:

    * ``"explicit"`` (default) — shard_map; ``fn`` is a per-shard body
      using :mod:`repro.runtime.collectives` for cross-worker traffic.
    * ``"constraint"`` — ``jax.jit`` + ``with_sharding_constraint``
      (:mod:`repro.runtime.constraint`); ``fn`` has global-view semantics
      and expresses layout transitions via
      :func:`repro.runtime.constraint.constrain`, letting XLA schedule
      and overlap the lowered collectives.

    The two backends expect *differently written* ``fn`` bodies (per-shard
    vs global) but share the spec vocabulary and produce matching numerics
    — see ``tests/test_constraint_backend.py``.
    """
    if backend == "constraint":
        if check:
            raise ValueError(
                "check=True is a shard_map replication check; the "
                "constraint backend has no per-shard bodies to check — "
                "drop the flag or use backend='explicit'")
        from .constraint import constraint_engine
        return constraint_engine(fn, in_specs, out_specs, mesh=mesh)
    if backend != "explicit":
        raise ValueError(
            f"engine backend must be 'explicit' or 'constraint', "
            f"got {backend!r}")
    if mesh is None:
        mesh = tp_mesh()
    return smap(fn, mesh, in_specs, out_specs, check=check)
