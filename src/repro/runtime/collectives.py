"""Axis collectives used inside :func:`repro.runtime.engine` bodies.

Thin, named wrappers over ``jax.lax`` so the rest of the repo has exactly
one import for "talk across a mesh axis" — the dedicated communication
layer that distributed-GNN systems factor out (NeutronTP's gather/split,
DepComm halo exchanges, EP MoE dispatch all reduce to these ops).
Keeping them in one module is what makes backends, multi-axis meshes, and
per-axis byte counters local changes instead of repo-wide ones — it is a
tested choke point (tests/test_collectives_chokepoint.py): no other
module may call the ``jax.lax`` collectives directly.

Two families:

* model-axis ops (:func:`psum`, :func:`all_gather`, :func:`all_to_all`,
  :func:`ppermute`) — the paper's TP traffic inside a replica group;
* replica ops (:func:`replica_gather`, :func:`replica_slice`,
  :func:`psum_replicas`, :func:`replica_index`, :func:`replica_size`) —
  hybrid DP×TP traffic across the data/pod axes.  ``data_axes`` is a
  (possibly empty) tuple, outermost first, exactly as carried by
  :class:`repro.runtime.TPMesh`; every replica op is the identity for
  ``data_axes=()`` so pure-TP call sites pay nothing.

The cross-replica *gradient* psum of hybrid training is the autodiff
transpose of these ops: replicated (``P()``) engine inputs have their
cotangents psummed over every mesh axis by shard_map's transpose, and
:func:`replica_gather`'s transpose is the mirrored psum-scatter over the
data axes — so wiring the forward through this module is what puts the
data-axis all-reduce bytes on the wire.

All functions must be called *inside* a mapped body with the axes bound.

Version portability lives here too: ``jax.lax.axis_size`` only exists on
newer JAX lines, so :func:`axis_size` falls back to the classic
``psum(1, axis)`` idiom (which constant-folds to the static axis size) on
0.4.x.
"""
from __future__ import annotations

import jax

from .mesh import DEFAULT_AXIS

_HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")


def axis_index(axis: str = DEFAULT_AXIS) -> jax.Array:
    """This worker's coordinate on ``axis``."""
    return jax.lax.axis_index(axis)


def axis_size(axis: str = DEFAULT_AXIS) -> int:
    """Number of workers on ``axis`` (a static int under tracing)."""
    if _HAS_AXIS_SIZE:
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def psum(x, axis=DEFAULT_AXIS):
    """Sum-reduce ``x`` across one axis or a tuple of axes (loss/metric
    reductions; pass ``("model",) + data_axes`` for hybrid DP×TP)."""
    return jax.lax.psum(x, axis)


def all_gather(x: jax.Array, axis: str = DEFAULT_AXIS, *,
               gather_axis: int = 0, tiled: bool = True) -> jax.Array:
    """Concatenate every worker's ``x`` along ``gather_axis``."""
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


def ppermute(x: jax.Array, axis: str = DEFAULT_AXIS, *,
             perm: list[tuple[int, int]]) -> jax.Array:
    """Point-to-point rotation (ring pipelines: (src, dst) pairs)."""
    return jax.lax.ppermute(x, axis, perm)


def all_to_all(x: jax.Array, axis: str = DEFAULT_AXIS, *,
               split_axis: int, concat_axis: int,
               tiled: bool = False) -> jax.Array:
    """The gather/split workhorse: exchange equal blocks of ``split_axis``
    for equal blocks of ``concat_axis`` (V·D/N bytes per device, graph- and
    skew-independent — the paper's load-balance argument)."""
    return jax.lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=tiled)


# ---------------------------------------------------------------------------
# Replica (data/pod) axis ops — hybrid DP×TP
# ---------------------------------------------------------------------------

def replica_index(data_axes: tuple[str, ...]) -> jax.Array:
    """Flattened replica coordinate over ``data_axes`` (major-to-minor,
    outermost first — matches the ``P((model,) + data_axes)`` block order
    of the hybrid vertex layout).  0 for ``data_axes=()``."""
    idx = 0
    for a in data_axes:
        idx = idx * axis_size(a) + axis_index(a)
    return idx


def replica_size(data_axes: tuple[str, ...]) -> int:
    """Total replica count (product of the data-axis sizes; 1 for ())."""
    n = 1
    for a in data_axes:
        n = n * axis_size(a)
    return n


def replica_gather(x: jax.Array, data_axes: tuple[str, ...], *,
                   gather_axis: int = 0) -> jax.Array:
    """Concatenate the replica shards of ``x`` along ``gather_axis``.

    Gathers innermost axis first so that, for an array sharded
    ``P((model,) + data_axes)`` on ``gather_axis``, the result is the
    contiguous model-worker shard in global row order.  Its autodiff
    transpose is the mirrored psum-scatter over the data axes — the
    cross-replica gradient reduction of hybrid DP×TP.  Identity for
    ``data_axes=()``.
    """
    for a in reversed(data_axes):
        x = all_gather(x, a, gather_axis=gather_axis, tiled=True)
    return x


def replica_slice(x: jax.Array, data_axes: tuple[str, ...], *,
                  axis: int = 0) -> jax.Array:
    """This replica's block of ``x`` along ``axis`` (inverse of
    :func:`replica_gather` on replica-identical values).  Identity for
    ``data_axes=()``."""
    if not data_axes:
        return x
    n = replica_size(data_axes)
    block = x.shape[axis] // n
    start = replica_index(data_axes) * block
    return jax.lax.dynamic_slice_in_dim(x, start, block, axis=axis)


def psum_replicas(x, data_axes: tuple[str, ...]):
    """Sum-reduce ``x`` across the replica axes (the explicit cross-replica
    psum of hybrid DP×TP).  Identity for ``data_axes=()``."""
    if not data_axes:
        return x
    return psum(x, tuple(data_axes))
