"""Axis collectives used inside :func:`repro.runtime.engine` bodies.

Thin, named wrappers over ``jax.lax`` so the rest of the repo has exactly
one import for "talk across a mesh axis" — the dedicated communication
layer that distributed-GNN systems factor out (NeutronTP's gather/split,
DepComm halo exchanges, EP MoE dispatch all reduce to these ops).
Keeping them in one module is what makes backends, multi-axis meshes, and
per-axis byte counters local changes instead of repo-wide ones — it is a
tested choke point (tests/test_collectives_chokepoint.py): no other
module may call the ``jax.lax`` collectives directly.

Two families:

* model-axis ops (:func:`psum`, :func:`all_gather`, :func:`all_to_all`,
  :func:`ppermute`) — the paper's TP traffic inside a replica group;
* replica ops (:func:`replica_gather`, :func:`replica_slice`,
  :func:`psum_replicas`, :func:`replica_index`, :func:`replica_size`) —
  hybrid DP×TP traffic across the data/pod axes.  ``data_axes`` is a
  (possibly empty) tuple, outermost first, exactly as carried by
  :class:`repro.runtime.TPMesh`; every replica op is the identity for
  ``data_axes=()`` so pure-TP call sites pay nothing.

Telemetry contract (ROADMAP "Collective telemetry")
---------------------------------------------------

Because every wire byte flows through these wrappers, they double as the
measurement point: while a :func:`repro.runtime.telemetry.collect_comm`
ledger is active, each call reports its (op kind, axis, dtype) together
with per-device payload bytes and ring-model wire bytes — computed at
**trace time** from the abstract shapes and the *static* mesh axis sizes
(:func:`static_axis_size`).  Three conventions make the ledger exact:

* **trace-time semantics** — a ledger fills during the first trace of a
  program (wrap the initial ``.lower()``/call); cached re-executions
  record nothing;
* **loop multipliers** — scans whose bodies communicate are wrapped in
  :func:`repro.runtime.telemetry.loop_scope` at the call site (see
  ``core/decouple.py``), so in-scan collectives count trip× instead of
  1× — the same undercount the HLO census re-derives from while-loop
  trip constants;
* **autodiff mirrors** — each data-moving call declares ``mirror=``:
  True (default for a2a/all_gather/ppermute) when the backward pass
  transposes it into the mirrored collective at identical wire bytes,
  False when the moved data is not differentiated (layer-0 input
  features of the coupled forwards).  ``psum`` defaults to
  ``mirror=False`` — the repo only psums loss/metric scalars, and the
  backward parameter-gradient all-reduce has no forward counterpart
  (see the telemetry module docstring for why it is out of scope).

The constraint backend has no per-shard bodies and never calls these
wrappers; its ``constrain``/``layout_cast`` transition points in
:mod:`repro.runtime.constraint` record the *implied* resharding
collective instead (``P(axis,·) ↔ P(·,axis)`` is the paper's a2a;
dropping a data axis is the replica all-gather), so both backends emit
comparable ledgers — pinned byte-for-byte against each other, the
analytic §3.2 formulas, and the HLO census by
tests/dist_progs/check_telemetry.py.

All functions must be called *inside* a mapped body with the axes bound.
Axis sizes and indices are *global* — under a multi-process
``jax.distributed`` mesh the same wrappers move bytes across process
boundaries (gloo on forced-host CPU, ICI/NCCL on real accelerators)
with no code change here, which is what keeps the telemetry ledger's
per-device accounting topology-independent.

Version portability lives here too: :func:`axis_size` resolves the
static size from ``jax.lax.axis_size`` (newer lines) or the bound axis
env (0.4.x) — see its docstring for the exact contract.
"""
from __future__ import annotations

import jax

from . import telemetry as T
from .mesh import DEFAULT_AXIS

_HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")


def axis_index(axis: str = DEFAULT_AXIS) -> jax.Array:
    """This worker's coordinate on ``axis``."""
    return jax.lax.axis_index(axis)


def static_axis_size(axis: str) -> int | None:
    """Static participant count of a bound mesh axis, or None.

    Resolution order: ``jax.lax.axis_size`` (newer JAX lines), then the
    tracing axis env (``jax.core.axis_frame`` — on 0.4.x this returns
    the static size of a shard_map-bound axis).  Returns None when the
    axis is unbound or the installed JAX exposes neither — callers that
    *need* a static int (telemetry, shape arithmetic) can then fail
    loudly instead of computing with a traced value.
    """
    if _HAS_AXIS_SIZE:
        try:
            return int(jax.lax.axis_size(axis))
        except Exception:  # unbound axis / exotic tracer  # noqa: BLE001
            return None
    try:
        size = jax.core.axis_frame(axis)  # 0.4.x: the size itself
    except Exception:  # noqa: BLE001
        return None
    if isinstance(size, int):
        return size
    size = getattr(size, "size", None)   # future-proof: a frame object
    return size if isinstance(size, int) else None


def axis_size(axis: str = DEFAULT_AXIS) -> int:
    """Number of workers on ``axis``.

    Returns a static Python int whenever the size is resolvable from the
    installed JAX (:func:`static_axis_size`) — which holds on every
    supported line (0.4.30+ via the axis env, newer via
    ``jax.lax.axis_size``), so shape arithmetic like ``dim // n`` is
    safe.  Only if *both* probes fail does it fall back to the classic
    ``psum(1, axis)`` idiom; note that fallback is static only because
    ``jax.lax.psum`` constant-folds non-tracer operands — on a line
    without that fast path it would return a traced Array, so the
    fallback is a last resort, not the contract
    (tests/test_telemetry.py covers the branch).
    """
    n = static_axis_size(axis)
    if n is not None:
        return n
    return jax.lax.psum(1, axis)


def _record(op: str, axis, x, mirror: bool) -> None:
    """Report into the active telemetry ledgers (no-op when none).

    Group sizes must be static while collecting — a ledger that silently
    skipped unresolvable calls would be the exact silent-zero bug class
    the telemetry replaces, so this raises instead.
    """
    if not T.active_ledgers():
        return
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    g = 1
    for a in axes:
        s = static_axis_size(a)
        if s is None:
            raise T.TelemetryError(
                f"collective telemetry needs the static size of axis "
                f"{a!r} but it is not resolvable on this JAX "
                f"({jax.__version__}) — is the axis bound by the engine?")
        g *= s
    T.record(op, axes, x, group_size=g, mirror=mirror)


def psum(x, axis=DEFAULT_AXIS, *, mirror: bool = False):
    """Sum-reduce ``x`` across one axis or a tuple of axes (loss/metric
    reductions; pass ``("model",) + data_axes`` for hybrid DP×TP)."""
    _record("psum", axis, x, mirror)
    return jax.lax.psum(x, axis)


def all_gather(x: jax.Array, axis: str = DEFAULT_AXIS, *,
               gather_axis: int = 0, tiled: bool = True,
               mirror: bool = True) -> jax.Array:
    """Concatenate every worker's ``x`` along ``gather_axis``.

    ``mirror=False`` when ``x`` is not differentiated (no backward
    psum-scatter will be emitted) — see the module docstring."""
    _record("all_gather", axis, x, mirror)
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


def ppermute(x: jax.Array, axis: str = DEFAULT_AXIS, *,
             perm: list[tuple[int, int]],
             mirror: bool = True) -> jax.Array:
    """Point-to-point rotation (ring pipelines: (src, dst) pairs)."""
    _record("ppermute", axis, x, mirror)
    return jax.lax.ppermute(x, axis, perm)


def all_to_all(x: jax.Array, axis: str = DEFAULT_AXIS, *,
               split_axis: int, concat_axis: int, tiled: bool = False,
               mirror: bool = True) -> jax.Array:
    """The gather/split workhorse: exchange equal blocks of ``split_axis``
    for equal blocks of ``concat_axis`` (V·D/N bytes per device, graph- and
    skew-independent — the paper's load-balance argument).

    ``mirror=False`` when ``x`` carries no gradient (the coupled
    forwards' layer-0 feature move): autodiff then emits no mirrored
    all-to-all, and the ledger must not count one."""
    _record("all_to_all", axis, x, mirror)
    return jax.lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=tiled)


# ---------------------------------------------------------------------------
# Replica (data/pod) axis ops — hybrid DP×TP
# ---------------------------------------------------------------------------

def replica_index(data_axes: tuple[str, ...]) -> jax.Array:
    """Flattened replica coordinate over ``data_axes`` (major-to-minor,
    outermost first — matches the ``P((model,) + data_axes)`` block order
    of the hybrid vertex layout).  0 for ``data_axes=()``."""
    idx = 0
    for a in data_axes:
        idx = idx * axis_size(a) + axis_index(a)
    return idx


def replica_size(data_axes: tuple[str, ...]) -> int:
    """Total replica count (product of the data-axis sizes; 1 for ())."""
    n = 1
    for a in data_axes:
        n = n * axis_size(a)
    return n


def replica_gather(x: jax.Array, data_axes: tuple[str, ...], *,
                   gather_axis: int = 0,
                   mirror: bool = True) -> jax.Array:
    """Concatenate the replica shards of ``x`` along ``gather_axis``.

    Gathers innermost axis first so that, for an array sharded
    ``P((model,) + data_axes)`` on ``gather_axis``, the result is the
    contiguous model-worker shard in global row order.  Its autodiff
    transpose is the mirrored psum-scatter over the data axes — the
    cross-replica gradient reduction of hybrid DP×TP (``mirror=False``
    when ``x`` is not differentiated).  Identity for ``data_axes=()``.
    """
    for a in reversed(data_axes):
        x = all_gather(x, a, gather_axis=gather_axis, tiled=True,
                       mirror=mirror)
    return x


def _replica_block(length: int, n: int, axis: int,
                   data_axes: tuple[str, ...]) -> int:
    """Per-replica block length, refusing to silently truncate.

    The old ``length // n`` floored, so a non-divisible axis dropped the
    trailing ``length % n`` rows of every replica but the bug surfaced
    only as slightly-wrong numerics.  Raise with the full context
    instead (PR 3's no-silent-defaults convention)."""
    block, rem = divmod(length, n)
    if rem:
        raise ValueError(
            f"replica_slice: axis {axis} of length {length} does not "
            f"divide the replica count {n} (= product of data axes "
            f"{data_axes!r}) — flooring would silently drop {rem} "
            f"trailing rows per replica; pad the axis to a multiple of "
            f"{n} first (runtime.padded_size / core.tp.pad_to_multiple)")
    return block


def replica_slice(x: jax.Array, data_axes: tuple[str, ...], *,
                  axis: int = 0) -> jax.Array:
    """This replica's block of ``x`` along ``axis`` (inverse of
    :func:`replica_gather` on replica-identical values).  Identity for
    ``data_axes=()``; raises when the axis does not divide the replica
    count instead of silently truncating."""
    if not data_axes:
        return x
    n = replica_size(data_axes)
    if isinstance(n, int):   # static on every supported JAX line
        block = _replica_block(x.shape[axis], n, axis, data_axes)
    else:                    # last-resort traced size: keep old behaviour
        block = x.shape[axis] // n
    start = replica_index(data_axes) * block
    return jax.lax.dynamic_slice_in_dim(x, start, block, axis=axis)


def psum_replicas(x, data_axes: tuple[str, ...], *, mirror: bool = False):
    """Sum-reduce ``x`` across the replica axes (the explicit cross-replica
    psum of hybrid DP×TP).  Identity for ``data_axes=()``."""
    if not data_axes:
        return x
    return psum(x, tuple(data_axes), mirror=mirror)
