"""Axis collectives used inside :func:`repro.runtime.engine` bodies.

Thin, named wrappers over ``jax.lax`` so the rest of the repo has exactly
one import for "talk across the TP axis" — the dedicated communication
layer that distributed-GNN systems factor out (NeutronTP's gather/split,
DepComm halo exchanges, EP MoE dispatch all reduce to these five ops).
Keeping them in one module is what makes a future second backend
(pjit constraints, explicit device buffers, a real multi-host launcher)
a local change instead of a repo-wide one.

All functions must be called *inside* a mapped body with ``axis`` bound.

Version portability lives here too: ``jax.lax.axis_size`` only exists on
newer JAX lines, so :func:`axis_size` falls back to the classic
``psum(1, axis)`` idiom (which constant-folds to the static axis size) on
0.4.x.
"""
from __future__ import annotations

import jax

from .mesh import DEFAULT_AXIS

_HAS_AXIS_SIZE = hasattr(jax.lax, "axis_size")


def axis_index(axis: str = DEFAULT_AXIS) -> jax.Array:
    """This worker's coordinate on ``axis``."""
    return jax.lax.axis_index(axis)


def axis_size(axis: str = DEFAULT_AXIS) -> int:
    """Number of workers on ``axis`` (a static int under tracing)."""
    if _HAS_AXIS_SIZE:
        return jax.lax.axis_size(axis)
    return jax.lax.psum(1, axis)


def psum(x, axis: str = DEFAULT_AXIS):
    """Sum-reduce ``x`` across the axis (loss/metric reductions)."""
    return jax.lax.psum(x, axis)


def all_gather(x: jax.Array, axis: str = DEFAULT_AXIS, *,
               gather_axis: int = 0, tiled: bool = True) -> jax.Array:
    """Concatenate every worker's ``x`` along ``gather_axis``."""
    return jax.lax.all_gather(x, axis, axis=gather_axis, tiled=tiled)


def ppermute(x: jax.Array, axis: str = DEFAULT_AXIS, *,
             perm: list[tuple[int, int]]) -> jax.Array:
    """Point-to-point rotation (ring pipelines: (src, dst) pairs)."""
    return jax.lax.ppermute(x, axis, perm)


def all_to_all(x: jax.Array, axis: str = DEFAULT_AXIS, *,
               split_axis: int, concat_axis: int,
               tiled: bool = False) -> jax.Array:
    """The gather/split workhorse: exchange equal blocks of ``split_axis``
    for equal blocks of ``concat_axis`` (V·D/N bytes per device, graph- and
    skew-independent — the paper's load-balance argument)."""
    return jax.lax.all_to_all(x, axis, split_axis=split_axis,
                              concat_axis=concat_axis, tiled=tiled)
