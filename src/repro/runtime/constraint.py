"""Constraint-backend engine: ``jax.jit`` + ``with_sharding_constraint``.

The explicit backend (:mod:`repro.runtime.smap`) enters shard_map and the
body spells every collective by hand — the all-to-alls are real ops the
scheduler must run where they stand, serialized against compute.  This
module implements the same ``engine(fn, in_specs, out_specs, mesh=...)``
contract a second way: the function keeps *global* (automatic-sharding)
semantics, inputs/outputs are laid out via jit shardings, and the paper's
gather/split layout transitions become :func:`constrain` re-shardings
(``P(axis, None) → P(None, axis)``).  XLA's SPMD partitioner lowers each
transition to the identical all-to-all HLO (same wire bytes — verified by
``benchmarks.bench_comm_volume``'s census) but owns its *schedule*, so it
may hoist, fuse, and overlap the collectives with compute instead of
running them inline.

Semantics contract (the one real difference between backends):

* ``backend="explicit"`` — ``fn`` is a per-shard body; arrays arrive as
  local shards and cross-worker traffic uses
  :mod:`repro.runtime.collectives`.
* ``backend="constraint"`` — ``fn`` is a global-view function; arrays
  arrive whole, reductions are plain ``jnp`` ops, and layout transitions
  are requested with :func:`constrain` (no manual collectives).

While the engine traces ``fn`` the mesh is exposed through a context
variable so :func:`constrain` (and the ``core.tp`` constraint variants
built on it) can name mesh axes without threading a mesh argument through
every call.  Outside an active context :func:`constrain` is a no-op, so
global-semantics code also runs unmodified on a single device.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Callable

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from . import telemetry as T
from .mesh import as_mesh, tp_mesh
from .smap import validate_specs

#: Mesh visible to :func:`constrain` while a constraint engine traces.
_ACTIVE_MESH: contextvars.ContextVar = contextvars.ContextVar(
    "repro_constraint_mesh", default=None)


@contextlib.contextmanager
def mesh_context(mesh):
    """Expose ``mesh`` to :func:`constrain` for the duration of a trace."""
    token = _ACTIVE_MESH.set(as_mesh(mesh))
    try:
        yield
    finally:
        _ACTIVE_MESH.reset(token)


def current_mesh():
    """The mesh of the innermost active constraint engine (or None)."""
    return _ACTIVE_MESH.get()


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """Request layout ``spec`` for ``x`` on the active constraint mesh.

    This is the constraint backend's "collective": constraining an array
    whose producer laid it out differently makes the SPMD partitioner
    materialize the transition (``P(axis, None) → P(None, axis)`` lowers
    to the paper's all-to-all).  No-op when no constraint engine is
    tracing, so shared code also runs under the explicit backend's
    reference path or on a single device.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def layout_cast(x: jax.Array, spec: P,
                src_spec: P | None = None, *,
                mirror: bool = True) -> jax.Array:
    """A layout *transition*: anchor ``x`` at ``src_spec``, then at ``spec``.

    A single ``with_sharding_constraint`` only pins the target side, and —
    being its own transpose — pins the *cotangent* to the target layout
    too, which is the wrong direction for a transition (autodiff of the
    explicit backend's all-to-all emits the mirrored collective, laid out
    like the transition's input).  Anchoring both sides is self-mirroring:
    the transposed pair constrains the cotangent back to ``src_spec`` at
    exactly this point, so the backward program reshards where the
    explicit path's transposed all-to-all sits.  No-op outside an active
    constraint engine.

    This is also the constraint backend's telemetry point: knowing both
    sides, the *implied* resharding collective (``P(axis,·) ↔ P(·,axis)``
    is the paper's all-to-all; dropping a data axis is the replica
    all-gather) is reported into any active
    :func:`repro.runtime.telemetry.collect_comm` ledger, with ``mirror``
    declaring whether autodiff transposes the pair (False when ``x``
    carries no gradient — the coupled forwards' layer-0 feature move).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    if src_spec is not None:
        # anchored: both endpoints get with_sharding_constraint eqns
        # below — the jaxpr audit checks this record against them
        note_transition(x, src_spec, spec, mirror=mirror, anchored=True)
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, src_spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def note_transition(x, src_spec: P, dst_spec: P, *,
                    mirror: bool = True, anchored: bool = False) -> None:
    """Record the implied collective of a ``src_spec → dst_spec``
    transition of global array ``x`` without emitting any constraint —
    for transition points spelled as raw ``constrain`` pairs (e.g. the
    DP halo exchange's transpose-and-reconstrain, whose all-to-all the
    partitioner materializes from an axis *moving dims* across an
    existing pair of anchors).  ``anchored=True`` is set by
    ``layout_cast``, which emits both-side constraint anchors the jaxpr
    audit then verifies; raw ``constrain``-pair sites leave the default
    False.  No-op outside an active constraint engine or when no ledger
    is collecting.
    """
    mesh = current_mesh()
    if mesh is None or not T.active_ledgers():
        return
    T.record_transition(jax.numpy.shape(x), jax.numpy.result_type(x),
                        src_spec, dst_spec, dict(mesh.shape),
                        mirror=mirror, anchored=anchored)


def _is_spec_leaf(x) -> bool:
    return x is None or isinstance(x, P)


def _shardings(mesh, specs):
    """specs pytree (PartitionSpec/None leaves) → NamedSharding pytree."""
    return jax.tree_util.tree_map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        specs, is_leaf=_is_spec_leaf)


def constraint_engine(fn: Callable, in_specs, out_specs, *,
                      mesh=None) -> Callable:
    """``engine(..., backend="constraint")`` implementation.

    ``fn`` must have global-view semantics (see module docstring).  The
    specs carry the same meaning as the explicit backend's: the global
    layout of each argument/output on ``mesh`` — here they become jit
    ``in_shardings``/``out_shardings`` instead of shard_map specs.
    Returns a jitted callable (composable under further ``jax.jit`` and
    autodiff, where the inner shardings act as constraints).
    """
    if mesh is None:
        mesh = tp_mesh()
    m = as_mesh(mesh)
    validate_specs(m, in_specs, "in_specs")
    validate_specs(m, out_specs, "out_specs")

    def traced(*args):
        with mesh_context(m):
            return fn(*args)

    return jax.jit(traced, in_shardings=_shardings(m, in_specs),
                   out_shardings=_shardings(m, out_specs))
