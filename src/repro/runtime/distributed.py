"""Multi-host process runtime: ``jax.distributed`` init + global placement.

The engine stack (``runtime.engine`` and everything built on it) is
written against *global* meshes: a mesh enumerates every device in the
job, specs describe global layouts, and the collectives move global
arrays.  On one process that is trivially true — ``jax.devices()`` is
the whole world.  This module is what makes the same programs run when
the world is **N processes each owning a slice of the devices** (the
paper's 16-node cluster; §5): it owns

* :func:`initialize` — the one entry into ``jax.distributed.initialize``
  (coordinator_address / num_processes / process_id, CLI- or env-driven
  via :data:`ENV_COORDINATOR` / :data:`ENV_NUM_PROCESSES` /
  :data:`ENV_PROCESS_ID`), with eager validation and *actionable*
  errors: an unreachable coordinator or a process-count mismatch raises
  naming the address, ids, and timeout instead of hanging silently.  On
  the CPU backend it enables the gloo cross-process collectives (the
  forced-host CI topology below runs real multi-process all-to-alls).
* :func:`put_global` / :func:`replicate` — host data → global arrays.
  Each process materializes the (replicated) host-side value and
  contributes only the shards its local devices hold, via
  ``jax.make_array_from_callback`` — the per-process placement
  ``jax.make_array_from_process_local_data`` is sugar for.  This is how
  ``prepare_bundle`` / ``prepare_dp_bundle`` shard the training bundle
  per host (``mesh=`` argument) so the per-shard engine bodies and the
  constraint backend's jit shardings run unchanged.
* :func:`context` — the process topology (process_id, num_processes,
  local/global device counts) for accounting: ``runtime.mesh`` appends
  it to device-accounting errors, benches gate output on
  :func:`is_coordinator`, and per-process telemetry ledgers are merged
  at the coordinator (``CommLedger.merge_from`` /
  ``CommLedger.from_dict``).

Supported CI topology (no cluster needed)
-----------------------------------------

N processes × M forced host devices each, coordinator on localhost::

    COORDINATOR_ADDRESS=127.0.0.1:<port> NUM_PROCESSES=N PROCESS_ID=i \\
    XLA_FLAGS=--xla_force_host_platform_device_count=M  python <prog>

Every process then sees ``len(jax.local_devices()) == M`` and
``len(jax.devices()) == N*M``, and the gather/split all-to-alls execute
across real process boundaries (gloo over TCP).  ``scripts/
launch_multihost.sh`` spawns exactly this; ``tests/dist_progs/
harness.py`` is the test-suite spelling of it.  On a real cluster the
same three env vars point at the rank-0 host and the devices are
whatever accelerators each host owns.

One discipline multihost imposes on callers: **collective-bearing
computations must run as a single jitted executable**.  Two executables
in flight at once race their collectives on the shared cross-process
transport (observed as gloo ``op.preamble.length <= op.nbytes`` aborts
on the CPU topology) — which is exactly what *eager* autodiff of a
sharded loss produces (separate forward and transposed-backward
executables).  The repo's factories comply: ``make_tp_train_fns`` /
``make_dp_train_fns`` jit the whole step with the bundle fed as
arguments (a traced function may not close over arrays spanning
non-addressable devices), and ``make_tp_value_and_grad`` /
``make_dp_value_and_grad`` are the jitted equivalence-test handles.
"""
from __future__ import annotations

import dataclasses
import os
import socket
import sys
import time

import numpy as np

#: Environment contract of the launcher (scripts/launch_multihost.sh and
#: any real-cluster scheduler export these for every process).
ENV_COORDINATOR = "COORDINATOR_ADDRESS"
ENV_NUM_PROCESSES = "NUM_PROCESSES"
ENV_PROCESS_ID = "PROCESS_ID"
#: Optional: seconds before a connect attempt gives up (default 60; the
#: failure-mode tests shrink it so "unreachable" fails fast).
ENV_INIT_TIMEOUT = "DIST_INIT_TIMEOUT"

_DEFAULT_TIMEOUT = 60.0


@dataclasses.dataclass(frozen=True)
class DistContext:
    """Resolved process topology after :func:`initialize`."""

    coordinator_address: str | None
    num_processes: int
    process_id: int
    local_device_count: int
    global_device_count: int

    @property
    def is_coordinator(self) -> bool:
        return self.process_id == 0

    @property
    def is_distributed(self) -> bool:
        return self.num_processes > 1


_CONTEXT: DistContext | None = None


def env_topology(env=None) -> dict:
    """The launcher env contract as ``initialize`` kwargs (missing keys
    omitted).  ``{}`` means "no multihost env": single-process mode."""
    env = os.environ if env is None else env
    out: dict = {}
    if env.get(ENV_COORDINATOR):
        out["coordinator_address"] = env[ENV_COORDINATOR]
    for key, name in ((ENV_NUM_PROCESSES, "num_processes"),
                      (ENV_PROCESS_ID, "process_id")):
        if env.get(key):
            try:
                out[name] = int(env[key])
            except ValueError:
                raise ValueError(
                    f"environment variable {key}={env[key]!r} must be an "
                    f"integer") from None
    if env.get(ENV_INIT_TIMEOUT):
        try:
            out["timeout"] = float(env[ENV_INIT_TIMEOUT])
        except ValueError:
            raise ValueError(
                f"environment variable {ENV_INIT_TIMEOUT}="
                f"{env[ENV_INIT_TIMEOUT]!r} must be a number of "
                f"seconds") from None
    return out


def _validate(coordinator_address, num_processes, process_id) -> None:
    """Eager topology validation — catches the classic launcher mistakes
    before anything can block on the network."""
    problems = []
    if num_processes < 1:
        problems.append(f"num_processes={num_processes} must be >= 1")
    if not 0 <= process_id < max(num_processes, 1):
        problems.append(
            f"process_id={process_id} out of range for "
            f"num_processes={num_processes} (valid ids: 0.."
            f"{num_processes - 1}) — every process must be launched with "
            f"the same {ENV_NUM_PROCESSES} and a distinct {ENV_PROCESS_ID}")
    if num_processes > 1:
        if not coordinator_address:
            problems.append(
                f"multihost ({num_processes} processes) needs a "
                f"coordinator address — set {ENV_COORDINATOR}=host:port "
                f"(the rank-0 host) on every process")
        else:
            _, _, port = str(coordinator_address).rpartition(":")
            if not port.isdigit():
                problems.append(
                    f"coordinator address {coordinator_address!r} is not "
                    f"host:port")
    if problems:
        raise ValueError("invalid multihost topology: "
                         + "; ".join(problems))


def _await_coordinator(address: str, timeout: float,
                       num_processes: int, process_id: int) -> None:
    """TCP-probe the coordinator before handing control to the XLA
    distributed client.

    An unreachable coordinator inside the C++ client is a ``LOG(FATAL)``
    — the process aborts and no Python ``except`` ever sees it.  Probing
    first (with retries up to ``timeout``: the coordinator may simply
    not have bound yet) turns the common launcher mistake into a
    catchable, actionable ``RuntimeError``.
    """
    host, _, port = address.rpartition(":")
    deadline = time.monotonic() + timeout
    last: Exception | None = None
    while True:                      # always probe at least once
        try:
            with socket.create_connection((host, int(port)),
                                          timeout=max(0.5, min(2.0,
                                                               timeout))):
                return
        except OSError as e:
            last = e
            if time.monotonic() >= deadline:
                break
            time.sleep(0.25)
    raise RuntimeError(
        f"coordinator at {address!r} unreachable after {timeout:.0f}s "
        f"(worker {process_id} of {num_processes}): {last}. Check that "
        f"process 0 is running and reachable at that host:port, that "
        f"{ENV_COORDINATOR} is identical on every process, and that "
        f"{ENV_NUM_PROCESSES}/{ENV_PROCESS_ID} describe the actual "
        f"launch ({ENV_INIT_TIMEOUT} raises this timeout).")


def initialize(coordinator_address: str | None = None,
               num_processes: int | None = None,
               process_id: int | None = None, *,
               timeout: float | None = None) -> DistContext:
    """Join (or start, as process 0) the distributed job and create the
    global device topology.  Arguments default to the env contract
    (:func:`env_topology`); with neither args nor env this is the
    single-process no-op and existing single-host entry points are
    unchanged.

    Must run before anything creates the JAX backend (any
    ``jax.devices()`` call): the CPU gloo collectives and the process's
    local-device slice are fixed at backend creation.  Idempotent once
    initialized (returns the existing context; re-initializing with a
    *different* topology raises).
    """
    global _CONTEXT
    envkw = env_topology()
    if coordinator_address is None:
        coordinator_address = envkw.get("coordinator_address")
    if num_processes is None:
        num_processes = envkw.get("num_processes", 1)
    if process_id is None:
        process_id = envkw.get("process_id", 0)
    if timeout is None:
        timeout = envkw.get("timeout", _DEFAULT_TIMEOUT)
    _validate(coordinator_address, num_processes, process_id)

    if _CONTEXT is not None:
        same = (_CONTEXT.coordinator_address, _CONTEXT.num_processes,
                _CONTEXT.process_id) == \
               (coordinator_address, num_processes, process_id)
        if not same:
            raise RuntimeError(
                f"distributed runtime already initialized as process "
                f"{_CONTEXT.process_id}/{_CONTEXT.num_processes} "
                f"(coordinator {_CONTEXT.coordinator_address!r}); cannot "
                f"re-initialize as {process_id}/{num_processes} "
                f"(coordinator {coordinator_address!r})")
        return _CONTEXT

    import jax

    if num_processes > 1:
        from jax._src import xla_bridge as _xb
        if getattr(_xb, "backends_are_initialized", lambda: False)():
            raise RuntimeError(
                "JAX backends are already initialized — "
                "runtime.distributed.initialize() must run before the "
                "first jax.devices()/device_put in the process (the "
                "local-device slice and cross-process collectives are "
                "fixed at backend creation)")
        try:
            # CPU cross-process collectives (the forced-host CI
            # topology) need gloo; a no-op where the option is absent
            # or the platform is not CPU.
            jax.config.update("jax_cpu_collectives_implementation",
                              "gloo")
        except (AttributeError, ValueError):
            pass
        # preflight, to stderr: failures past this point may be C++
        # LOG(FATAL)s inside the XLA client (no Python traceback), so
        # put the topology context next to them in the log
        print(f"[repro.runtime.distributed] process {process_id}/"
              f"{num_processes} connecting to coordinator "
              f"{coordinator_address} (timeout {timeout:.0f}s)",
              file=sys.stderr, flush=True)
        if process_id != 0:
            _await_coordinator(coordinator_address, timeout,
                               num_processes, process_id)
        try:
            jax.distributed.initialize(
                coordinator_address=coordinator_address,
                num_processes=num_processes, process_id=process_id,
                initialization_timeout=int(max(1, timeout)))
        except Exception as e:  # noqa: BLE001 — re-raise actionable
            role = ("coordinator" if process_id == 0
                    else f"worker {process_id}")
            raise RuntimeError(
                f"jax.distributed.initialize failed for {role} "
                f"(coordinator_address={coordinator_address!r}, "
                f"num_processes={num_processes}, process_id="
                f"{process_id}, timeout={timeout:.0f}s): "
                f"{type(e).__name__}: {e}. Check that the coordinator "
                f"host:port is reachable from every process, that "
                f"exactly {num_processes} processes were launched with "
                f"distinct {ENV_PROCESS_ID} values 0.."
                f"{num_processes - 1}, and that all share the same "
                f"{ENV_NUM_PROCESSES} and {ENV_COORDINATOR}.") from e

    n_local = len(jax.local_devices())
    n_global = len(jax.devices())
    if num_processes > 1 and jax.process_count() != num_processes:
        raise RuntimeError(
            f"backend reports {jax.process_count()} processes but "
            f"initialize was called with num_processes={num_processes}")
    _CONTEXT = DistContext(
        coordinator_address=coordinator_address,
        num_processes=num_processes, process_id=process_id,
        local_device_count=n_local, global_device_count=n_global)
    return _CONTEXT


def is_initialized() -> bool:
    return _CONTEXT is not None


def _require_initialized_under_multihost_env() -> None:
    """Topology queried before :func:`initialize` in a job whose env
    contract says this IS a multihost process: raise instead of letting
    ``jax.process_count()`` create a local-only backend that reports a
    wrong single-process topology (every rank would then think it is
    the coordinator — exactly the duplicate-output/write hazard the
    process-0 gating exists to prevent) and poisons the later
    ``initialize`` call."""
    if env_topology().get("num_processes", 1) > 1:
        raise RuntimeError(
            f"multihost environment ({ENV_NUM_PROCESSES}/"
            f"{ENV_COORDINATOR} are set) but "
            f"runtime.distributed.initialize() has not run in this "
            f"process — call it before any topology or device query "
            f"(or unset {ENV_NUM_PROCESSES}/{ENV_COORDINATOR} if this "
            f"is not a multihost process)")


def context() -> DistContext:
    """The current topology; synthesizes the single-process context when
    :func:`initialize` was never called (every entry point works
    unmodified on one process — this may create the JAX backend, which
    is harmless there).  Raises if the multihost env contract is set
    but :func:`initialize` has not run."""
    if _CONTEXT is not None:
        return _CONTEXT
    _require_initialized_under_multihost_env()
    import jax

    return DistContext(coordinator_address=None,
                       num_processes=jax.process_count(),
                       process_id=jax.process_index(),
                       local_device_count=len(jax.local_devices()),
                       global_device_count=len(jax.devices()))


def process_count() -> int:
    """Processes in the job.  Uninitialized single-process callers may
    trigger (harmless) backend creation via ``jax.process_count()``;
    with the multihost env contract set and :func:`initialize` not run,
    this raises like :func:`context` does."""
    if _CONTEXT is not None:
        return _CONTEXT.num_processes
    _require_initialized_under_multihost_env()
    try:
        import jax

        return jax.process_count()
    except Exception:  # noqa: BLE001 — accounting only
        return 1


def is_coordinator() -> bool:
    """True on process 0 (and always on a single process) — the gate for
    anything that must happen once per job: writing ``BENCH_*.json``,
    printing result rows, raising ledger asserts."""
    return context().process_id == 0


def topology_note() -> str:
    """Human-readable per-process device accounting, appended to mesh
    errors under multihost (``resolve_mesh_shape``'s ``note=``) — a
    global count alone reads like a single-host bug when each process
    only holds a slice.

    Decorative, so it must never raise or create a backend: before
    :func:`initialize` has run it is simply empty (the mesh factories
    call it on their success path too)."""
    ctx = _CONTEXT
    if ctx is None or not ctx.is_distributed:
        return ""
    return (f" [multihost: {ctx.num_processes} processes × "
            f"{ctx.local_device_count} local devices each = "
            f"{ctx.global_device_count} global devices; this process "
            f"({ctx.process_id}) holds only jax.local_devices()]")


# ---------------------------------------------------------------------------
# Global placement of host data
# ---------------------------------------------------------------------------

def put_global(x, mesh, spec):
    """Place host value ``x`` on ``mesh`` with layout ``spec`` as one
    global array.

    Single-process this is a plain sharded ``device_put``.  Multihost,
    every process holds the full host-side value (the repo's bundles are
    built deterministically from a shared seed on every process) and
    contributes the shards its local devices own — the
    ``make_array_from_process_local_data`` placement, spelled through
    ``make_array_from_callback`` so one call handles sharded *and*
    replicated (``P()``) leaves alike.
    """
    import jax
    from jax.sharding import NamedSharding
    from .mesh import as_mesh

    sharding = NamedSharding(as_mesh(mesh), spec)
    if isinstance(x, jax.Array) and getattr(x, "sharding", None) == \
            sharding:
        return x                     # already placed: no round trip
    xnp = np.asarray(x)
    if process_count() == 1:
        return jax.device_put(xnp, sharding)
    return jax.make_array_from_callback(
        xnp.shape, sharding, lambda idx: xnp[idx])


def replicate(tree, mesh):
    """Every leaf of ``tree`` → a fully-replicated global array on
    ``mesh`` (params / optimizer state under multihost: each process
    computes the identical host value, the callback placement commits it
    to every device)."""
    import jax
    from jax.sharding import PartitionSpec as P

    return jax.tree.map(lambda x: put_global(x, mesh, P()), tree)
