"""repro.runtime — the single owner of meshes and sharded execution.

Public surface:

* :func:`engine`       — the one way to enter sharded execution.  Two
                         selectable backends behind one contract:
                         ``engine(fn, in_specs, out_specs, mesh=...,
                         backend="explicit"|"constraint")``.
                         ``"explicit"`` (default) is version-portable
                         shard_map: ``fn`` is a per-shard body and every
                         collective is spelled by hand via
                         :mod:`collectives`.  ``"constraint"`` is
                         ``jax.jit`` + ``with_sharding_constraint``
                         (:mod:`constraint`): ``fn`` has global-view
                         semantics, layout transitions are requested with
                         :func:`constrain`, and XLA schedules/overlaps the
                         lowered collectives (same wire bytes, different
                         freedom — see benchmarks/bench_comm_volume.py).
* :func:`smap`         — explicit backend with a required mesh argument
* :func:`constrain`    — the constraint backend's layout-transition op
* :class:`TPMesh` / :func:`tp_mesh` — the paper's 1-D "model" mesh with
                         the divisibility/padding contract attached
* :mod:`collectives`   — axis_index / axis_size / psum / all_gather /
                         all_to_all used inside explicit engine bodies

No other module may call ``shard_map`` (any spelling) directly.
"""
from . import collectives  # noqa: F401
from .constraint import (  # noqa: F401
    constrain,
    constraint_engine,
    current_mesh,
    layout_cast,
    mesh_context,
)
from .mesh import (  # noqa: F401
    DEFAULT_AXIS,
    TPMesh,
    as_mesh,
    padded_size,
    tp_mesh,
)
from .smap import (  # noqa: F401
    CHECK_KW,
    JAX_VERSION,
    SUPPORTED_JAX,
    engine,
    resolve_shard_map,
    smap,
    validate_specs,
)

__all__ = [
    "DEFAULT_AXIS", "TPMesh", "as_mesh", "padded_size", "tp_mesh",
    "CHECK_KW", "JAX_VERSION", "SUPPORTED_JAX", "engine",
    "resolve_shard_map", "smap", "validate_specs", "collectives",
    "constrain", "constraint_engine", "current_mesh", "layout_cast",
    "mesh_context",
]
