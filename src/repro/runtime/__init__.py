"""repro.runtime — the single owner of meshes and sharded execution.

Public surface:

* :func:`engine`       — the one way to enter sharded execution.  Two
                         selectable backends behind one contract:
                         ``engine(fn, in_specs, out_specs, mesh=...,
                         backend="explicit"|"constraint")``.
                         ``"explicit"`` (default) is version-portable
                         shard_map: ``fn`` is a per-shard body and every
                         collective is spelled by hand via
                         :mod:`collectives`.  ``"constraint"`` is
                         ``jax.jit`` + ``with_sharding_constraint``
                         (:mod:`constraint`): ``fn`` has global-view
                         semantics, layout transitions are requested with
                         :func:`constrain`, and XLA schedules/overlaps the
                         lowered collectives (same wire bytes, different
                         freedom — see benchmarks/bench_comm_volume.py).
* :func:`smap`         — explicit backend with a required mesh argument
* :func:`constrain`    — the constraint backend's layout-transition op
* :class:`TPMesh`      — the single mesh owner: a model axis plus optional
                         replica (data/pod) axes, with the
                         divisibility/padding contract attached
* :func:`tp_mesh`      — the paper's 1-D "model" mesh (pure TP)
* :func:`hybrid_mesh`  — (data, model) / (pod, data, model) meshes for
                         hybrid DP×TP; strict no-truncation device
                         accounting via :func:`resolve_mesh_shape`
* :func:`data_axes_for`— the replica axes of a mesh (raises on unknown
                         axis names instead of silently dropping them)
* :mod:`collectives`   — axis_index / axis_size / psum / all_gather /
                         all_to_all on the model axis plus the replica
                         ops (replica_gather / replica_slice /
                         psum_replicas) used inside explicit engine
                         bodies; the tested choke point every wire byte
                         flows through
* :mod:`distributed`   — multi-host process runtime: the one entry into
                         ``jax.distributed.initialize`` (env/CLI-driven
                         coordinator_address / num_processes /
                         process_id, actionable failure errors) plus
                         :func:`distributed.put_global` /
                         :func:`distributed.replicate` host-data
                         placement, so the same global meshes and engine
                         programs run when N processes each own a slice
                         of the devices
* :mod:`telemetry`     — trace-time collective telemetry at that choke
                         point: :func:`collect_comm` ledgers of per
                         (op, axis, dtype) call counts / payload / ring
                         wire bytes, :func:`loop_scope` trip
                         multipliers, and the constraint backend's
                         implied-collective transition records — the
                         primary measured columns of
                         bench_comm_volume (HLO census demoted to a
                         cross-check)

No other module may call ``shard_map`` (any spelling) or the ``jax.lax``
collectives directly (tests/test_collectives_chokepoint.py enforces it).
"""
from . import collectives  # noqa: F401
from . import distributed  # noqa: F401
from . import streaming  # noqa: F401
from . import telemetry  # noqa: F401
from .constraint import (  # noqa: F401
    constrain,
    constraint_engine,
    current_mesh,
    layout_cast,
    mesh_context,
    note_transition,
)
from .telemetry import CommLedger, collect_comm, loop_scope  # noqa: F401
from .mesh import (  # noqa: F401
    DATA_AXES_ORDER,
    DEFAULT_AXIS,
    TPMesh,
    as_mesh,
    data_axes_for,
    hybrid_mesh,
    mesh_axes,
    padded_size,
    resolve_bundle_degrees,
    resolve_mesh_shape,
    resolve_replicas,
    tp_mesh,
)
from .smap import (  # noqa: F401
    CHECK_KW,
    JAX_VERSION,
    SUPPORTED_JAX,
    engine,
    resolve_shard_map,
    smap,
    validate_specs,
)

__all__ = [
    "DATA_AXES_ORDER", "DEFAULT_AXIS", "TPMesh", "as_mesh",
    "data_axes_for", "hybrid_mesh", "mesh_axes", "padded_size",
    "resolve_bundle_degrees", "resolve_mesh_shape",
    "resolve_replicas", "tp_mesh", "CHECK_KW", "JAX_VERSION", "SUPPORTED_JAX", "engine",
    "resolve_shard_map", "smap", "validate_specs", "collectives",
    "constrain", "constraint_engine", "current_mesh", "layout_cast",
    "mesh_context", "note_transition", "telemetry", "CommLedger",
    "collect_comm", "loop_scope", "distributed", "streaming",
]
