"""repro.runtime — the single owner of meshes and sharded execution.

Public surface:

* :func:`engine`       — the one way to enter sharded execution
                         (version-portable shard_map + spec validation)
* :func:`smap`         — same, with an explicit mesh argument required
* :class:`TPMesh` / :func:`tp_mesh` — the paper's 1-D "model" mesh with
                         the divisibility/padding contract attached
* :mod:`collectives`   — axis_index / axis_size / psum / all_gather /
                         all_to_all used inside engine bodies

No other module may call ``shard_map`` (any spelling) directly.
"""
from . import collectives  # noqa: F401
from .mesh import (  # noqa: F401
    DEFAULT_AXIS,
    TPMesh,
    as_mesh,
    padded_size,
    tp_mesh,
)
from .smap import (  # noqa: F401
    CHECK_KW,
    JAX_VERSION,
    SUPPORTED_JAX,
    engine,
    resolve_shard_map,
    smap,
    validate_specs,
)

__all__ = [
    "DEFAULT_AXIS", "TPMesh", "as_mesh", "padded_size", "tp_mesh",
    "CHECK_KW", "JAX_VERSION", "SUPPORTED_JAX", "engine",
    "resolve_shard_map", "smap", "validate_specs", "collectives",
]
