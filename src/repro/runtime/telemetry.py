"""Trace-time collective telemetry — the measured side of Fig. 8.

NeutronTP's central quantitative claim is about *wire bytes*: TP's
gather/split moves exactly V·D/N bytes per device regardless of graph
skew.  Every wire byte in this repo flows through one tested choke point
(:mod:`repro.runtime.collectives` for the explicit backend, the
``constrain``/``layout_cast`` transition points of
:mod:`repro.runtime.constraint` for the constraint backend), so that is
where bytes are counted — at **trace time**, from abstract shapes and
static mesh axis sizes, instead of regex-parsing compiled HLO text
(:func:`repro.launch.roofline.hlo_census`, which has shipped two
silent-zero parser bugs and is now demoted to a cross-check).

Usage::

    with telemetry.collect_comm() as ledger:
        step.lower(params, opt_state)        # first trace of the program
    ledger.wire_bytes(op="all_to_all", axis="model", train=True)

Contract (what a ledger entry means):

* **Trace-time semantics** — the choke-point wrappers report into every
  active ledger while the traced Python body runs.  A ledger therefore
  only fills during the *first* trace of a program: wrap the initial
  ``jit(...).lower(...)`` (or the first call); cached re-executions
  re-run no Python and record nothing.  An empty ledger where bytes were
  expected is a collection bug, never "zero traffic" — benches assert
  non-emptiness.
* **Keys** — entries accumulate per ``(op kind, axis label, dtype)``.
  Multi-axis reductions (e.g. ``psum`` over ``("model", "data")``) use
  the joined label ``"model+data"``; axis queries match a label when they
  equal it or name one of its ``+`` components.
* **Bytes** — ``payload_bytes`` is the per-device input payload;
  ``wire_bytes`` is the per-device ring-algorithm wire traffic of the
  collective, the same cost model as the HLO census
  (:func:`ring_wire_factor` mirrors ``roofline._wire_factor`` —
  byte-for-byte comparable, pinned by tests/test_telemetry.py).
* **Loop multipliers** — ``jax.lax.scan``/``while`` bodies trace once
  but execute trip× (the undercount the census re-derives from
  while-loop constants).  Call sites wrap scans whose bodies communicate
  in :func:`loop_scope`, so in-scan collectives count trip×.
* **Autodiff mirrors** — backward passes are derived by transposing the
  jaxpr; no Python re-runs, so the wrappers cannot see the mirrored
  collectives.  Instead each call site declares ``mirror=`` — True when
  the cotangent flows back through this collective (its transpose emits
  the mirrored op: a2a ↔ a2a, all_gather ↔ psum_scatter, ppermute ↔
  reversed ppermute, all at identical ring wire bytes), False when the
  moved data is not differentiated (e.g. the layer-0 input features of
  the coupled forwards — the backward stops at the first parameter
  matmul, which the HLO census confirms).  ``train=True`` queries add
  the mirrored bytes; ``train=False`` is forward-only.  ``psum``
  defaults to ``mirror=False``: the repo only psums scalars
  (loss/metrics), whose mirrored bytes are negligible, and the
  replicated-parameter gradient all-reduce of the backward pass has no
  forward counterpart at all — it is shard_map's transpose of the
  replicated-input broadcast and is out of ledger scope (its data-axis
  portion is covered analytically by ``grad_allreduce_data`` in
  benchmarks/bench_comm_volume.py).

The constraint backend records the *implied* collective of each layout
transition (:func:`record_transition`): ``P(axis,·) ↔ P(·,axis)`` is the
paper's all-to-all, dropping a data axis is the replica all-gather, and
adding sharding axes is a local slice (free).  Both backends therefore
emit comparable ledgers — equality on the bench workload is pinned by
tests/dist_progs/check_telemetry.py.

This module is pure bookkeeping: it calls no ``jax.lax`` collectives and
never touches the traced values — only their avals.
"""
from __future__ import annotations

import contextlib
import dataclasses
import math
from contextvars import ContextVar
from typing import Iterator, Mapping

__all__ = [
    "CommEntry", "CommLedger", "H2D_OP", "TelemetryError",
    "TransitionRecord", "active_ledgers", "collect_comm",
    "loop_multiplier", "loop_scope", "mirror_scope", "normalize_spec",
    "record", "record_h2d", "record_transition", "ring_wire_factor",
]


class TelemetryError(RuntimeError):
    """A collective could not be accounted (e.g. no static axis size) while
    a ledger was collecting — raised instead of silently skipping the
    bytes (the silent-zero failure mode this module exists to kill)."""


#: Ledger "op" kind → HLO instruction kind of the census, so the two
#: cost models can be cross-pinned (tests/test_telemetry.py asserts the
#: ring factors agree).
OP_TO_HLO = {
    "psum": "all-reduce",
    "all_gather": "all-gather",
    "all_to_all": "all-to-all",
    "ppermute": "collective-permute",
    "psum_scatter": "reduce-scatter",
}

#: Ledger op kind for host→device staging traffic (out-of-core chunk
#: streaming, repro.core.stream).  NOT a collective: it never appears in
#: a jaxpr, has no ring factor and no autodiff mirror, so it is keyed
#: outside OP_TO_HLO and the jaxpr audit skips it.  Unlike collective
#: entries (trace-time), H2D entries are **execution-time**: the staging
#: helpers record every ``device_put`` they issue, so one epoch inside
#: ``collect_comm`` measures that epoch's actual staged bytes —
#: re-executions record again (cached traces do not re-record
#: collectives, so a post-warmup per-epoch ledger isolates H2D cleanly).
H2D_OP = "h2d"


def ring_wire_factor(op: str, g: int) -> float:
    """Ring-algorithm per-device wire-byte factor on the RESULT size —
    the same model as ``repro.launch.roofline._wire_factor``:

      all_gather      (g−1)/g      psum (all-reduce)   2(g−1)/g
      psum_scatter    (g−1)        all_to_all          (g−1)/g
      ppermute        1
    """
    if op == "ppermute":
        return 1.0
    if g <= 1:
        return 0.0
    return {"all_gather": (g - 1) / g,
            "psum": 2 * (g - 1) / g,
            "psum_scatter": float(g - 1),
            "all_to_all": (g - 1) / g}[op]


@dataclasses.dataclass
class CommEntry:
    """Accumulated counters for one (op, axis label, dtype) key."""

    calls: float = 0.0            # forward collective executions (trip-scaled)
    payload_bytes: float = 0.0    # per-device input payload, forward
    wire_bytes: float = 0.0       # per-device ring wire bytes, forward
    mirrored_calls: float = 0.0   # autodiff-mirrored executions (backward)
    mirrored_wire_bytes: float = 0.0

    def merge(self, other: "CommEntry") -> None:
        self.calls += other.calls
        self.payload_bytes += other.payload_bytes
        self.wire_bytes += other.wire_bytes
        self.mirrored_calls += other.mirrored_calls
        self.mirrored_wire_bytes += other.mirrored_wire_bytes


def normalize_spec(spec) -> tuple:
    """Canonical hashable form of a PartitionSpec-like: tuple entries
    stay tuples of ``str`` names, scalars become ``str``, and trailing
    ``None`` dims (replicated) are dropped — so ``P("model", None)``,
    ``P("model")`` and ``("model",)`` all compare equal.  Used to match
    ledger :class:`TransitionRecord` endpoints against the
    ``sharding_constraint`` equations of a traced constraint-backend
    program (repro.analysis.jaxpr_audit)."""
    entries = []
    for e in tuple(spec):
        if e is None:
            entries.append(None)
        elif isinstance(e, (tuple, list)):
            entries.append(tuple(str(a) for a in e))
        else:
            entries.append(str(e))
    while entries and entries[-1] is None:
        entries.pop()
    return tuple(entries)


@dataclasses.dataclass(frozen=True)
class TransitionRecord:
    """One constraint-backend layout transition as declared at trace
    time (``layout_cast``/``note_transition``) — the endpoints the jaxpr
    audit checks for anchoring ``sharding_constraint`` equations.
    Trace-local evidence: not serialized by ``as_dict`` and not merged
    by ``merge_from`` (per-process ledgers compare *counters*; the
    transitions of an SPMD program are identical per process anyway)."""

    shape: tuple        # global array shape
    dtype: str
    src_spec: tuple     # normalize_spec() form
    dst_spec: tuple
    calls: float        # loop_scope-multiplied executions
    mirror: bool
    anchored: bool      # True iff layout_cast emitted both-side
    #                     with_sharding_constraint anchors for it


def _axis_label(axes) -> str:
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    return "+".join(axes)


def _label_matches(label: str, axis: str | None) -> bool:
    return axis is None or axis == label or axis in label.split("+")


class CommLedger:
    """Per-(op, axis, dtype) collective counters for one traced program."""

    def __init__(self) -> None:
        self._entries: dict[tuple[str, str, str], CommEntry] = {}
        self._transitions: list[TransitionRecord] = []

    # ---- accumulation --------------------------------------------------

    def add(self, op: str, axes, dtype: str, *, payload: float, wire: float,
            calls: float = 1.0, mirror: bool = False) -> None:
        key = (op, _axis_label(axes), str(dtype))
        entry = self._entries.setdefault(key, CommEntry())
        entry.calls += calls
        entry.payload_bytes += payload * calls
        entry.wire_bytes += wire * calls
        if mirror:
            entry.mirrored_calls += calls
            entry.mirrored_wire_bytes += wire * calls

    def add_transition(self, rec: TransitionRecord) -> None:
        self._transitions.append(rec)

    def transitions(self) -> tuple[TransitionRecord, ...]:
        """Layout transitions recorded this trace (constraint backend
        only; empty for explicit-backend programs)."""
        return tuple(self._transitions)

    # ---- queries -------------------------------------------------------

    def _select(self, op: str | None, axis: str | None):
        for (kop, klabel, _), entry in self._entries.items():
            if op is not None and kop != op:
                continue
            if not _label_matches(klabel, axis):
                continue
            yield entry

    def wire_bytes(self, op: str | None = None, axis: str | None = None, *,
                   train: bool = False) -> float:
        """Per-device ring wire bytes.  ``train=True`` adds the declared
        autodiff mirrors (fwd+bwd of one step); default is forward-only."""
        total = 0.0
        for e in self._select(op, axis):
            total += e.wire_bytes + (e.mirrored_wire_bytes if train else 0.0)
        return total

    def payload_bytes(self, op: str | None = None,
                      axis: str | None = None) -> float:
        return sum(e.payload_bytes for e in self._select(op, axis))

    def call_count(self, op: str | None = None, axis: str | None = None, *,
                   train: bool = False) -> float:
        total = 0.0
        for e in self._select(op, axis):
            total += e.calls + (e.mirrored_calls if train else 0.0)
        return total

    def entries(self) -> dict[tuple[str, str, str], CommEntry]:
        return dict(self._entries)

    def as_dict(self) -> dict:
        """JSON-friendly view: ``{"op|axis|dtype": {counters...}}``."""
        return {"|".join(k): dataclasses.asdict(v)
                for k, v in sorted(self._entries.items())}

    @classmethod
    def from_dict(cls, d: Mapping[str, Mapping[str, float]]) -> "CommLedger":
        """Inverse of :func:`as_dict` — how per-process ledgers travel
        under multihost (each process JSON-serializes its trace-time
        ledger; the coordinator rebuilds and merges them).  Axis labels
        may contain ``+`` (joined multi-axis keys) but never ``|``."""
        ledger = cls()
        for key, counters in d.items():
            parts = key.split("|")
            if len(parts) != 3:
                raise TelemetryError(
                    f"malformed ledger key {key!r} (want 'op|axis|dtype')")
            ledger._entries[tuple(parts)] = CommEntry(**dict(counters))
        return ledger

    def merge_from(self, other: "CommLedger") -> "CommLedger":
        """Accumulate ``other``'s counters into this ledger (coordinator-
        side merge of per-process ledgers: each process traces the same
        SPMD program, so per-device counters are summed to job totals —
        or compared for equality first, as test_multihost does)."""
        for key, entry in other._entries.items():
            self._entries.setdefault(key, CommEntry()).merge(entry)
        return self

    def __len__(self) -> int:
        return len(self._entries)

    def __bool__(self) -> bool:
        return bool(self._entries)

    def __repr__(self) -> str:  # debugging aid
        return f"CommLedger({self.as_dict()!r})"


# ---------------------------------------------------------------------------
# Collection context
# ---------------------------------------------------------------------------

_LEDGERS: ContextVar[tuple[CommLedger, ...]] = ContextVar(
    "repro_comm_ledgers", default=())
_LOOP_MULT: ContextVar[float] = ContextVar("repro_comm_loop_mult",
                                           default=1.0)
_SUPPRESS: ContextVar[bool] = ContextVar("repro_comm_suppress",
                                         default=False)


@contextlib.contextmanager
def collect_comm(ledger: CommLedger | None = None) -> Iterator[CommLedger]:
    """Collect collective telemetry from every trace inside the block.

    Nested contexts stack: an inner ``collect_comm`` does not hide the
    outer one — every active ledger receives every record (so a bench can
    hold a per-row ledger inside a whole-run aggregate).
    """
    ledger = CommLedger() if ledger is None else ledger
    token = _LEDGERS.set(_LEDGERS.get() + (ledger,))
    try:
        yield ledger
    finally:
        _LEDGERS.reset(token)


def active_ledgers() -> tuple[CommLedger, ...]:
    return _LEDGERS.get()


@contextlib.contextmanager
def loop_scope(trips: int) -> Iterator[None]:
    """Multiply records inside the block by ``trips`` — wrap the
    ``jax.lax.scan``/``while`` call whose body communicates (the body
    traces once but executes trip×).  Scopes nest multiplicatively."""
    if not isinstance(trips, (int,)) or isinstance(trips, bool) or trips < 1:
        raise ValueError(
            f"loop_scope trips must be a positive int (the static trip "
            f"count of the wrapped scan), got {trips!r}")
    token = _LOOP_MULT.set(_LOOP_MULT.get() * trips)
    try:
        yield
    finally:
        _LOOP_MULT.reset(token)


def loop_multiplier() -> float:
    return _LOOP_MULT.get()


@contextlib.contextmanager
def mirror_scope() -> Iterator[None]:
    """Suppress collective recording inside the block.

    For programs that *manually materialize* an autodiff mirror already
    declared elsewhere with ``mirror=True`` — e.g. the out-of-core
    streaming driver's split-transpose program, which applies the
    ``gather`` all-to-all to a hand-propagated cotangent.  The forward
    split's ``mirror=True`` declaration already accounts those wire
    bytes (that is the declaration's whole meaning), so letting the
    materialized transpose record again would double-count and break
    ledger parity with the in-memory path.  Wrap every call of such a
    program: recording happens at trace time (the first call), and
    cached re-executions record nothing anyway, so the blanket wrap is
    both sufficient and free."""
    token = _SUPPRESS.set(True)
    try:
        yield
    finally:
        _SUPPRESS.reset(token)


def mirror_suppressed() -> bool:
    return _SUPPRESS.get()


# ---------------------------------------------------------------------------
# Recording
# ---------------------------------------------------------------------------

def _aval_bytes(x) -> tuple[float, str]:
    """(total bytes, dtype label) of a pytree of arrays/tracers/scalars,
    from abstract values only.  The dtype label is the first leaf's (the
    repo's collectives are dtype-homogeneous per call)."""
    import jax
    import numpy as np

    total = 0.0
    dtype = "?"
    for i, leaf in enumerate(jax.tree_util.tree_leaves(x)):
        aval = jax.core.get_aval(leaf)
        dt = np.dtype(aval.dtype)
        total += float(math.prod(aval.shape)) * dt.itemsize
        if i == 0:
            dtype = dt.name
    return total, dtype


def record(op: str, axes, x, *, group_size: int,
           mirror: bool = False) -> None:
    """Report one collective execution into every active ledger.

    ``x`` is the (pytree of) per-device input operand(s) — only abstract
    shapes/dtypes are read.  ``group_size`` is the static participant
    count on ``axes`` (product over a tuple).  ``mirror`` declares that
    autodiff will emit the mirrored collective in the backward pass (see
    module docstring).  No-op when no ledger is collecting.
    """
    ledgers = active_ledgers()
    if not ledgers or mirror_suppressed():
        return
    if op not in OP_TO_HLO:
        raise TelemetryError(f"unknown collective op kind {op!r} "
                             f"(known: {sorted(OP_TO_HLO)})")
    payload, dtype = _aval_bytes(x)
    # ring_wire_factor is defined on the RESULT size (census convention);
    # derive the result from the input payload per op: all_gather grows
    # it g×, psum_scatter shrinks it g×, the rest preserve it
    if op == "all_gather":
        wire = (group_size - 1) * payload
    elif op == "psum_scatter":
        wire = ring_wire_factor(op, group_size) * payload / group_size
    else:
        wire = ring_wire_factor(op, group_size) * payload
    mult = loop_multiplier()
    for ledger in ledgers:
        ledger.add(op, axes, dtype, payload=payload, wire=wire,
                   calls=mult, mirror=mirror)


# ---------------------------------------------------------------------------
# Constraint-backend layout transitions
# ---------------------------------------------------------------------------

def _spec_placement(spec, ndim: int) -> dict[str, int]:
    """axis name → array dim it shards, for one PartitionSpec."""
    entries = list(spec) + [None] * (ndim - len(spec))
    out: dict[str, int] = {}
    for dim, entry in enumerate(entries):
        if entry is None:
            continue
        for a in (entry if isinstance(entry, tuple) else (entry,)):
            out[a] = dim
    return out


def implied_collectives(shape, itemsize: int, src_spec, dst_spec,
                        axis_sizes: Mapping[str, int]) -> list[tuple]:
    """Collectives the SPMD partitioner must materialize for the layout
    transition ``src_spec → dst_spec`` of a *global* array, staged the way
    the repo's transitions lower:

    * an axis sharding a different dim on each side → its all-to-all
      (the paper's gather/split, ``P(a,·) ↔ P(·,a)``);
    * an axis present only in ``src`` → the replica all-gather that drops
      it (processed innermost-first, matching ``replica_gather``);
    * an axis present only in ``dst`` → a local slice, free (recorded as
      nothing).

    Returns ``[(op, axis, payload_bytes, wire_bytes), ...]`` with bytes
    per device, using the same ring model as :func:`record`.
    """
    ndim = len(shape)
    src = _spec_placement(src_spec, ndim)
    dst = _spec_placement(dst_spec, ndim)
    for a in set(src) | set(dst):
        if a not in axis_sizes:
            raise TelemetryError(
                f"layout transition names mesh axis {a!r} but the active "
                f"mesh only has axes {sorted(axis_sizes)}")
    total = float(math.prod(shape)) * itemsize
    current = dict(src)
    out: list[tuple] = []

    def sharded_by(axes) -> float:
        return float(math.prod(axis_sizes[a] for a in axes))

    # gathers first, innermost (last-listed) axis first — replica_gather's
    # order; each gather grows the per-device block
    removed = [a for a in src if a not in dst]
    for a in reversed(removed):
        del current[a]
        g = axis_sizes[a]
        result = total / sharded_by(current)
        out.append(("all_gather", a, result / g,
                    ring_wire_factor("all_gather", g) * result))
    # then the dim-moving all-to-alls
    for a in src:
        if a in dst and src[a] != dst[a]:
            g = axis_sizes[a]
            result = total / sharded_by(current)
            out.append(("all_to_all", a, result,
                        ring_wire_factor("all_to_all", g) * result))
    return out


def record_transition(shape, dtype, src_spec, dst_spec,
                      axis_sizes: Mapping[str, int], *,
                      mirror: bool = True, anchored: bool = False) -> None:
    """Report the implied collectives of a constraint-backend layout
    transition (see :func:`implied_collectives`), plus the transition
    itself as a :class:`TransitionRecord` for the jaxpr audit.
    ``anchored=True`` (set by ``layout_cast``) declares that the caller
    also emitted ``with_sharding_constraint`` anchors for both
    endpoints, which the audit verifies structurally.  No-op when no
    ledger is collecting."""
    ledgers = active_ledgers()
    if not ledgers or mirror_suppressed():
        return
    import numpy as np

    itemsize = np.dtype(dtype).itemsize
    mult = loop_multiplier()
    for op, axis, payload, wire in implied_collectives(
            shape, itemsize, src_spec, dst_spec, axis_sizes):
        for ledger in ledgers:
            ledger.add(op, axis, np.dtype(dtype).name, payload=payload,
                       wire=wire, calls=mult, mirror=mirror)
    rec = TransitionRecord(
        shape=tuple(shape), dtype=np.dtype(dtype).name,
        src_spec=normalize_spec(src_spec), dst_spec=normalize_spec(dst_spec),
        calls=mult, mirror=mirror, anchored=anchored)
    for ledger in ledgers:
        ledger.add_transition(rec)


# ---------------------------------------------------------------------------
# Host→device staging traffic (out-of-core streaming)
# ---------------------------------------------------------------------------

def record_h2d(x, *, label: str = "host") -> None:
    """Report one host→device staging transfer into every active ledger.

    ``x`` is the (pytree of) host array(s) being staged; its total bytes
    are recorded under ``(H2D_OP, label, dtype)`` with
    ``payload == wire`` (a PCIe/host-link copy has no ring factor) and
    no mirror.  Execution-time semantics — see :data:`H2D_OP`: call this
    once per issued ``device_put``, every time it is issued.  The
    ``loop_scope`` multiplier is deliberately NOT applied (it corrects
    trace-once/execute-many scans; staging is recorded per execution).
    No-op when no ledger is collecting."""
    ledgers = active_ledgers()
    if not ledgers:
        return
    payload, dtype = _aval_bytes(x)
    for ledger in ledgers:
        ledger.add(H2D_OP, label, dtype, payload=payload, wire=payload,
                   calls=1.0)
