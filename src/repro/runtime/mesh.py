"""Device meshes for the TP engine — the repo's single mesh owner.

``TPMesh`` owns the paper's "model" axis plus optional replica axes: a
1-D ``("model",)`` mesh is the paper's pure tensor parallelism, while
``("data", "model")`` and ``("pod", "data", "model")`` meshes compose TP
within a replica group with data parallelism across groups (the cluster
scaling of §5: TP inside a group, gradient all-reduce across groups).
It builds the mesh, knows the TP degree, and validates the
divisibility/padding contract that the rectangular gather/split
all-to-alls rely on — an (V, D) activation matrix can only move
vertex-sharded ↔ dim-sharded when both V and D divide the TP degree
(pad first with :func:`padded_size` / ``core.tp.pad_to_multiple``).

Factories:

* :func:`tp_mesh`     — the paper's 1-D "model" mesh (pure TP).
* :func:`hybrid_mesh` — (data, model) or (pod, data, model) meshes for
  hybrid DP×TP.  Strict device accounting: the requested shape must
  consume *exactly* the visible (or given) devices — no silent
  truncation of the device list.
* :func:`resolve_mesh_shape` — the pure (n_devices, pod, data, model)
  → shape contract behind :func:`hybrid_mesh`, property-tested without
  real devices.

``launch.mesh``'s host/production builders are thin shims over these —
there is one mesh owner, and it is this module.  Everything that runs
sharded code goes through :func:`repro.runtime.engine`, which accepts
either a raw :class:`jax.sharding.Mesh` or a ``TPMesh`` (via
:func:`as_mesh`), so callers can hold whichever is convenient.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh

DEFAULT_AXIS = "model"

#: Replica axes the engine knows about, outermost first.  The "pod" axis
#: extends data parallelism across the inter-pod link; both behave as
#: gradient-all-reduce (data) axes to the GNN engine.
DATA_AXES_ORDER = ("pod", "data")


def padded_size(size: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``size``."""
    return -(-size // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class TPMesh:
    """A device mesh plus its model axis name, TP degree, and replica axes.

    The single owner of "how many workers" questions: divisibility
    validation and padded sizes (both are *model-axis* contracts — the
    gather/split all-to-alls run inside a replica group) plus the replica
    (data/pod) axes that gradient psums span.
    """

    mesh: Mesh
    axis: str = DEFAULT_AXIS
    data_axes: tuple[str, ...] = ()

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(
                f"TPMesh axis {self.axis!r} not in mesh axes "
                f"{self.mesh.axis_names}")
        object.__setattr__(self, "data_axes", tuple(self.data_axes))
        for a in self.data_axes:
            if a not in self.mesh.axis_names:
                raise ValueError(
                    f"TPMesh data axis {a!r} not in mesh axes "
                    f"{self.mesh.axis_names}")
            if a == self.axis:
                raise ValueError(
                    f"TPMesh axis {a!r} cannot be both the model axis and "
                    f"a data axis")

    @property
    def size(self) -> int:
        """TP degree N (number of workers on the model axis)."""
        return self.mesh.shape[self.axis]

    @property
    def data_size(self) -> int:
        """Number of replica groups (product of the data/pod axis sizes)."""
        return math.prod(self.mesh.shape[a] for a in self.data_axes)

    @property
    def n_devices(self) -> int:
        """Total devices = data_size × size (× unnamed spectator axes)."""
        return self.mesh.devices.size

    @property
    def devices(self):
        return tuple(self.mesh.devices.flat)

    # ---- padding / divisibility contract -------------------------------

    def padded(self, size: int, chunks: int = 1) -> int:
        """``size`` padded so it divides N (and optionally N·chunks)."""
        return padded_size(size, self.size * chunks)

    def validate_divisible(self, n_vertices: int | None = None,
                           dim: int | None = None,
                           replicas: int | None = None) -> None:
        """Raise with a padding hint when (V, D) violate the TP contract.

        ``n_vertices`` is checked against *all* workers (model × data:
        the vertex dim shards over every device in the hybrid layout);
        ``dim`` only against the model degree (features never shard over
        replica axes).  ``replicas`` overrides the mesh's own
        ``data_size`` — callers that resolved an explicit ``data_axes``
        (e.g. the pure-TP escape hatch ``()`` on a hybrid mesh) validate
        against the replica count the execution will actually use.
        """
        n = self.size
        k = n * (self.data_size if replicas is None else replicas)
        problems = []
        if n_vertices is not None and n_vertices % k:
            problems.append(
                f"vertex count {n_vertices} % {k} != 0 "
                f"(pad to {padded_size(n_vertices, k)})")
        if dim is not None and dim % n:
            problems.append(
                f"feature dim {dim} % {n} != 0 "
                f"(pad to {padded_size(dim, n)})")
        if problems:
            raise ValueError(
                "TPMesh divisibility violated — rectangular gather/split "
                "all-to-alls need both dims to divide the TP degree "
                "(and the vertex dim to divide the full device count): "
                + "; ".join(problems)
                + ". Use core.tp.pad_to_multiple / runtime.padded_size.")


def tp_mesh(n_workers: int | None = None, axis: str = DEFAULT_AXIS,
            devices=None) -> TPMesh:
    """Build the paper's 1-D model mesh over the visible devices.

    ``n_workers`` defaults to every visible device — under a
    ``jax.distributed`` job that is the *global* ``jax.devices()`` list
    (every process builds the same mesh while holding only its
    ``jax.local_devices()`` slice; see :mod:`repro.runtime.distributed`).
    Passing more than exist is an error (forcing host devices is the
    launcher's job — see ``XLA_FLAGS=--xla_force_host_platform_device_count``).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    n_workers = len(devices) if n_workers is None else int(n_workers)
    if n_workers < 1 or n_workers > len(devices):
        from . import distributed as dist
        raise ValueError(
            f"n_workers={n_workers} but only {len(devices)} devices "
            f"visible{dist.topology_note()}")
    return TPMesh(Mesh(np.array(devices[:n_workers]), (axis,)), axis=axis)


def resolve_mesh_shape(n_devices: int, model: int | None = None,
                       data: int = 1, pod: int = 1,
                       note: str = "") -> tuple[int, int, int]:
    """Resolve an (pod, data, model) request against a device count.

    The hybrid-mesh contract, as a pure function (property-tested):

    * every degree must be a positive integer;
    * ``model=None`` infers the model degree as
      ``n_devices // (pod·data)``, which must divide exactly;
    * the resolved shape must consume **all** ``n_devices`` — requesting
      fewer is an error, never a silent truncation of the device list
      (pass an explicit ``devices`` slice to use a subset).

    ``note`` is appended verbatim to the device-accounting errors; the
    mesh factories pass the per-process topology under multihost
    (:func:`repro.runtime.distributed.topology_note`) so "8 devices are
    visible" reads as "2 processes × 4 local devices" instead of looking
    like a single-host miscount.
    """
    if n_devices < 1:
        raise ValueError(f"need at least one device, got {n_devices}{note}")
    for name, deg in (("pod", pod), ("data", data), ("model", model)):
        if deg is not None and (not isinstance(deg, int) or deg < 1):
            raise ValueError(
                f"mesh degree {name}={deg!r} must be a positive int")
    groups = pod * data
    if model is None:
        if n_devices % groups:
            raise ValueError(
                f"cannot infer model degree: {n_devices} devices do not "
                f"divide into pod×data = {pod}×{data} = {groups} replica "
                f"groups{note}")
        model = n_devices // groups
    if groups * model != n_devices:
        raise ValueError(
            f"mesh shape (pod={pod}, data={data}, model={model}) needs "
            f"{groups * model} devices but {n_devices} are visible — "
            f"refusing to silently truncate the device list; pass an "
            f"explicit devices= slice to use a subset{note}")
    return pod, data, model


def hybrid_mesh(model: int | None = None, data: int = 1, pod: int = 1,
                axis: str = DEFAULT_AXIS, devices=None,
                topology: bool = False) -> TPMesh:
    """Build a hybrid DP×TP mesh: (data, model), or (pod, data, model).

    The model axis carries the paper's gather/split all-to-alls; the data
    (and pod) axes carry replica groups whose gradients are psummed.  The
    "data" axis is always present (degree 1 meshes keep the axis so specs
    stay uniform); the "pod" axis appears only when ``pod > 1``.

    ``topology=True`` asks ``jax.experimental.mesh_utils`` for a
    physical-topology-aware device arrangement (on TPU slices the
    trailing model axis then maps to ICI-adjacent chips, keeping the
    gather/split all-to-alls off slow links); the default is the plain
    device-list order, which is deterministic and what the forced-host
    equivalence tests expect.

    Strict device accounting — see :func:`resolve_mesh_shape`.  Under a
    ``jax.distributed`` job the default device list is the *global*
    ``jax.devices()`` (identical on every process), so the same call on
    every host builds the same global mesh; accounting errors then name
    the per-process topology (processes × local devices).
    """
    from . import distributed as dist
    devices = list(jax.devices()) if devices is None else list(devices)
    pod, data, model = resolve_mesh_shape(
        len(devices), model=model, data=data, pod=pod,
        note=dist.topology_note())
    if pod > 1:
        shape, axes = (pod, data, model), ("pod", "data", axis)
        data_axes = ("pod", "data")
    else:
        shape, axes = (data, model), ("data", axis)
        data_axes = ("data",)
    if topology:
        from jax.experimental import mesh_utils
        arr = mesh_utils.create_device_mesh(shape, devices)
    else:
        arr = np.array(devices).reshape(shape)
    return TPMesh(Mesh(arr, axes), axis=axis, data_axes=data_axes)


def data_axes_for(mesh, axis: str = DEFAULT_AXIS) -> tuple[str, ...]:
    """The replica (gradient-psum) axes of ``mesh``, outermost first.

    For a :class:`TPMesh` this is its ``data_axes`` field.  For a raw
    mesh the known replica names (:data:`DATA_AXES_ORDER`) are picked out
    — but a mesh whose extra axes are *not* known replica axes raises
    instead of silently returning ``()`` (the old behaviour dropped
    unrecognized axes, so a cross-replica grad psum silently became a
    no-op).  A pure 1-D ``(model,)`` mesh genuinely has no replica axes
    and returns ``()``.
    """
    if isinstance(mesh, TPMesh):
        return mesh.data_axes
    names = tuple(mesh.axis_names)
    if axis not in names:
        raise ValueError(
            f"mesh axes {names} have no model axis {axis!r} — cannot "
            f"derive replica axes")
    unknown = [a for a in names if a != axis and a not in DATA_AXES_ORDER]
    if unknown:
        raise ValueError(
            f"mesh axes {names} contain {unknown} which are neither the "
            f"model axis {axis!r} nor known replica axes "
            f"{DATA_AXES_ORDER} — name them explicitly via "
            f"TPMesh(mesh, axis=..., data_axes=...)")
    return tuple(a for a in DATA_AXES_ORDER if a in names)


def resolve_replicas(mesh, axis: str = DEFAULT_AXIS,
                     data_axes=None) -> tuple[int, int]:
    """(model degree, replica count) of ``mesh`` for the given replica
    axes — the one place the ``prod(mesh.shape[a])`` resolution lives
    (the TP/DP factories and their bundle-fit validators all route here).
    ``data_axes=None`` derives the axes via :func:`data_axes_for`; an
    explicit tuple (e.g. ``()``, the pure-TP escape hatch) wins over the
    mesh's own bookkeeping.
    """
    if data_axes is None:
        data_axes = data_axes_for(mesh, axis)
    if isinstance(mesh, TPMesh):
        n, m = mesh.size, mesh.mesh
    else:
        m = as_mesh(mesh)
        n = m.shape[axis]
    replicas = 1
    for a in data_axes:
        replicas *= m.shape[a]
    return n, replicas


def mesh_axes(mesh, axis: str = DEFAULT_AXIS) -> tuple[str, tuple]:
    """(model axis, data_axes) of a TPMesh or raw mesh — the spec
    vocabulary of the bundle preparers and placement helpers."""
    if isinstance(mesh, TPMesh):
        return mesh.axis, mesh.data_axes
    return axis, data_axes_for(as_mesh(mesh), axis)


def resolve_bundle_degrees(mesh, n_workers: int | None = None,
                           n_replicas: int | None = None, *,
                           caller: str = "prepare_bundle",
                           worker_name: str = "n_workers"
                           ) -> tuple[int, int]:
    """Resolve a bundle preparer's (workers, replicas) request against
    ``mesh``: ``None`` degrees are derived from the mesh, explicit ones
    must match it exactly — a bundle padded for different degrees than
    the execution mesh would only fail later and further from the
    mistake.  The one shared contract behind ``prepare_bundle`` /
    ``prepare_dp_bundle``'s ``mesh=`` arguments."""
    axis, data_axes = mesh_axes(mesh)
    mesh_workers, mesh_replicas = resolve_replicas(mesh, axis, data_axes)
    n_workers = mesh_workers if n_workers is None else n_workers
    n_replicas = mesh_replicas if n_replicas is None else n_replicas
    if (n_workers, n_replicas) != (mesh_workers, mesh_replicas):
        raise ValueError(
            f"{caller}({worker_name}={n_workers}, n_replicas="
            f"{n_replicas}) contradicts mesh degrees (model="
            f"{mesh_workers}, replicas={mesh_replicas}) — drop the "
            f"explicit counts or pass the matching mesh")
    return n_workers, n_replicas


def as_mesh(mesh) -> Mesh:
    """Coerce TPMesh | Mesh → the underlying jax Mesh."""
    if isinstance(mesh, TPMesh):
        return mesh.mesh
    if isinstance(mesh, Mesh):
        return mesh
    raise TypeError(f"expected TPMesh or jax.sharding.Mesh, got "
                    f"{type(mesh).__name__}")
