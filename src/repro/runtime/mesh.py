"""Device meshes for the TP engine.

``TPMesh`` owns the paper's 1-D "model" axis: it builds the mesh, knows the
TP degree, and validates the divisibility/padding contract that the
rectangular gather/split all-to-alls rely on — an (V, D) activation matrix
can only move vertex-sharded ↔ dim-sharded when both V and D divide the TP
degree (pad first with :func:`padded_size` / ``core.tp.pad_to_multiple``).

Everything that runs sharded code goes through :func:`repro.runtime.engine`,
which accepts either a raw :class:`jax.sharding.Mesh` or a ``TPMesh``
(via :func:`as_mesh`), so callers can hold whichever is convenient.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh

DEFAULT_AXIS = "model"


def padded_size(size: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``size``."""
    return -(-size // multiple) * multiple


@dataclasses.dataclass(frozen=True)
class TPMesh:
    """A 1-D tensor-parallel mesh plus its axis name and degree.

    The single owner of "how many workers" questions: divisibility
    validation and padded sizes.
    """

    mesh: Mesh
    axis: str = DEFAULT_AXIS

    def __post_init__(self):
        if self.axis not in self.mesh.axis_names:
            raise ValueError(
                f"TPMesh axis {self.axis!r} not in mesh axes "
                f"{self.mesh.axis_names}")

    @property
    def size(self) -> int:
        """TP degree N (number of workers on the model axis)."""
        return self.mesh.shape[self.axis]

    @property
    def devices(self):
        return tuple(self.mesh.devices.flat)

    # ---- padding / divisibility contract -------------------------------

    def padded(self, size: int, chunks: int = 1) -> int:
        """``size`` padded so it divides N (and optionally N·chunks)."""
        return padded_size(size, self.size * chunks)

    def validate_divisible(self, n_vertices: int | None = None,
                           dim: int | None = None) -> None:
        """Raise with a padding hint when (V, D) violate the TP contract."""
        n = self.size
        problems = []
        if n_vertices is not None and n_vertices % n:
            problems.append(
                f"vertex count {n_vertices} % {n} != 0 "
                f"(pad to {padded_size(n_vertices, n)})")
        if dim is not None and dim % n:
            problems.append(
                f"feature dim {dim} % {n} != 0 "
                f"(pad to {padded_size(dim, n)})")
        if problems:
            raise ValueError(
                "TPMesh divisibility violated — rectangular gather/split "
                "all-to-alls need both dims to divide the TP degree: "
                + "; ".join(problems)
                + ". Use core.tp.pad_to_multiple / runtime.padded_size.")


def tp_mesh(n_workers: int | None = None, axis: str = DEFAULT_AXIS,
            devices=None) -> TPMesh:
    """Build the paper's 1-D model mesh over local devices.

    ``n_workers`` defaults to every visible device; passing more than exist
    is an error (forcing host devices is the launcher's job — see
    ``XLA_FLAGS=--xla_force_host_platform_device_count``).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    n_workers = len(devices) if n_workers is None else int(n_workers)
    if n_workers < 1 or n_workers > len(devices):
        raise ValueError(
            f"n_workers={n_workers} but only {len(devices)} devices visible")
    return TPMesh(Mesh(np.array(devices[:n_workers]), (axis,)), axis=axis)


def as_mesh(mesh) -> Mesh:
    """Coerce TPMesh | Mesh → the underlying jax Mesh."""
    if isinstance(mesh, TPMesh):
        return mesh.mesh
    if isinstance(mesh, Mesh):
        return mesh
    raise TypeError(f"expected TPMesh or jax.sharding.Mesh, got "
                    f"{type(mesh).__name__}")
