"""Minimal pytree optimizers (AdamW / SGD) — no external dependencies.

API mirrors optax: ``opt = adamw(lr); state = opt.init(params);
updates, state = opt.update(grads, state, params); params =
apply_updates(params, updates)``.  Learning rates may be floats or
step-indexed schedules (callables); ``state.count`` carries the step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("count", "mu", "nu"), meta_fields=())
@dataclasses.dataclass
class OptState:
    count: jax.Array
    mu: Any
    nu: Any


class Optimizer(NamedTuple):
    init: Callable[[Any], OptState]
    update: Callable[..., tuple[Any, OptState]]


def _lr_at(lr, count):
    return lr(count) if callable(lr) else lr


def adamw(lr: float | Callable = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.0,
          grad_clip_norm: float | None = None) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(jnp.zeros_like, params)
        return OptState(count=jnp.zeros((), jnp.int32), mu=zeros,
                        nu=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state: OptState, params=None):
        count = state.count + 1
        if grad_clip_norm is not None:
            gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state.mu, grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * (g * g),
                          state.nu, grads)
        c = count.astype(jnp.float32)
        mu_hat_scale = 1.0 / (1 - b1 ** c)
        nu_hat_scale = 1.0 / (1 - b2 ** c)
        lr_t = _lr_at(lr, count)

        def upd(m, v, p):
            step = lr_t * (m * mu_hat_scale) / (
                jnp.sqrt(v * nu_hat_scale) + eps)
            if weight_decay and p is not None and p.ndim >= 2:
                step = step + lr_t * weight_decay * p
            return -step

        updates = jax.tree.map(upd, mu, nu,
                               params if params is not None else mu)
        return updates, OptState(count=count, mu=mu, nu=nu)

    return Optimizer(init=init, update=update)


def sgd(lr: float | Callable = 1e-2, momentum: float = 0.0) -> Optimizer:
    def init(params):
        return OptState(count=jnp.zeros((), jnp.int32),
                        mu=jax.tree.map(jnp.zeros_like, params), nu=None)

    def update(grads, state: OptState, params=None):
        count = state.count + 1
        lr_t = _lr_at(lr, count)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g,
                              state.mu, grads)
            updates = jax.tree.map(lambda m: -lr_t * m, mu)
        else:
            mu = state.mu
            updates = jax.tree.map(lambda g: -lr_t * g, grads)
        return updates, OptState(count=count, mu=mu, nu=None)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)
