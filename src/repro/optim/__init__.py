from .adamw import adamw, sgd, OptState, apply_updates  # noqa: F401
from .schedule import (constant, cosine_decay, linear_warmup_cosine)  # noqa: F401
