"""Step-indexed learning-rate schedules."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    return lambda step: jnp.asarray(value, jnp.float32)


def cosine_decay(peak: float, total_steps: int, floor: float = 0.0):
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        return floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
    return f


def linear_warmup_cosine(peak: float, warmup: int, total_steps: int,
                         floor: float = 0.0):
    def f(step):
        warm = peak * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, cos)
    return f
