"""DeepSeek-V2-Lite (15.7B total / 2.4B active) [arXiv:2405.04434].

MLA attention with kv_lora_rank=512 compressed KV cache (no q-lora in the
Lite variant), decoupled-RoPE head dim 64; MoE with 2 shared + 64 routed
experts, top-6 routing, expert FFN width 1408; the first layer uses a dense
MLP (width 10944).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-lite-16b", arch_type="moe",
    num_layers=27, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=10944, vocab_size=102400,
    use_mla=True, kv_lora_rank=512, q_lora_rank=0, rope_head_dim=64,
    moe=True, num_experts=64, num_experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1408, first_dense_layers=1,
    act="silu",
    source="arXiv:2405.04434 (DeepSeek-V2; Lite config: MLA kv_lora=512, "
           "2 shared + 64 routed experts, top-6)",
)
