"""MusicGen-large decoder [arXiv:2306.05284].

Decoder-only transformer over EnCodec tokens.  The EnCodec conv codec and
the T5 text conditioner are modality-frontend STUBS per the brief:
``input_specs()`` supplies precomputed conditioning embeddings; the model
here is the 48-layer LM backbone over the audio-token vocabulary (2048
codes/codebook; codebook interleave handled by the delay pattern outside
the backbone).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", arch_type="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    head_dim=64, d_ff=8192, vocab_size=2048,
    modality="audio", num_prefix_embeddings=64,   # conditioning frames
    act="gelu",
    source="arXiv:2306.05284 (MusicGen large: 48L/2048d decoder over "
           "EnCodec tokens)",
)
