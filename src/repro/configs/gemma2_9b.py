"""Gemma2-9B: alternating local(4096-window)/global attention, logit
softcapping, post-norms [arXiv:2408.00118]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b", arch_type="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8,
    head_dim=256, d_ff=14336, vocab_size=256000,
    local_global_pattern=True, sliding_window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
    post_norm=True, act="gelu", tie_embeddings=True,
    source="arXiv:2408.00118 (Gemma2-9B: 42L, local/global alternating, "
           "softcaps 50/30, head_dim=256)",
)
