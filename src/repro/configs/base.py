"""Architecture + input-shape config system.

Every assigned architecture is an :class:`ArchConfig`; the four benchmark
input shapes are :class:`InputShape`.  ``reduced()`` produces the CPU smoke
variant (≤2 layers, d_model ≤ 512, ≤4 experts) of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                     # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                  # 0 → d_model // num_heads
    # §Perf R2.5: pad the embedding/unembedding vocab dim up to this
    # multiple so it stays shardable over the model axis (vocabs like
    # 151655/49155 don't divide 16 ⇒ the partitioner silently replicates
    # the full fp32 logits per device).  0 = no padding (exact paper dims).
    pad_vocab_to: int = 0

    # attention flavor
    qkv_bias: bool = False             # qwen1.5
    logit_softcap: Optional[float] = None      # gemma2 final logits
    attn_softcap: Optional[float] = None       # gemma2 attention logits
    sliding_window: Optional[int] = None       # local-attention window
    local_global_pattern: bool = False         # gemma2 alternating layers
    rope_theta: float = 10000.0

    # MLA (deepseek-v2)
    use_mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 0             # decoupled-RoPE dims per head

    # MoE
    moe: bool = False
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                  # per-expert FFN width
    first_dense_layers: int = 0        # deepseek: layer 0 is dense-MLP
    moe_capacity_factor: float = 1.25  # Switch-style capacity (train)

    # SSM (mamba2 / SSD)
    ssm: bool = False
    ssm_state_dim: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    conv_kernel: int = 4

    # hybrid (zamba2): one weight-tied ("shared") attention block applied
    # every k layers, interleaved with mamba2 blocks
    hybrid_attn_every: int = 0

    # multimodal stubs: frontend provides precomputed embeddings
    modality: Optional[str] = None     # None | "vision" | "audio"
    num_prefix_embeddings: int = 0     # patch/frame embeddings per example

    norm_eps: float = 1e-6
    act: str = "silu"
    tie_embeddings: bool = False
    post_norm: bool = False            # gemma2 post-layer norms
    dtype: str = "bfloat16"

    # ---- performance knobs (§Perf; defaults = paper-faithful baseline) ---
    attn_impl: str = "naive"           # naive | blockwise | flash (Pallas)
    ssm_impl: str = "jnp"              # jnp | fused (Pallas SSD kernel)
    attn_block_q: int = 512
    attn_block_kv: int = 1024
    moe_impl: str = "gather"           # gather | expert_parallel (a2a)
    explicit_a2a: bool = False         # runtime.smap gather/split for mixing

    # citation for the exact numbers above
    source: str = ""

    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    # ---- derived -----------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        if not self.pad_vocab_to:
            return self.vocab_size
        m = self.pad_vocab_to
        return -(-self.vocab_size // m) * m

    @property
    def d_inner(self) -> int:          # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm else 0

    def layer_kinds(self) -> list[str]:
        """Per-layer block kind: 'dense' | 'moe' | 'mamba' | 'shared_attn'
        | 'local' | 'global'."""
        kinds = []
        for i in range(self.num_layers):
            if self.ssm and not self.hybrid_attn_every:
                kinds.append("mamba")
            elif self.hybrid_attn_every:
                # zamba2-style: shared attention block every k layers
                if (i + 1) % self.hybrid_attn_every == 0:
                    kinds.append("shared_attn")
                else:
                    kinds.append("mamba")
            elif self.local_global_pattern:
                kinds.append("local" if i % 2 == 0 else "global")
            elif self.moe:
                kinds.append("dense" if i < self.first_dense_layers
                             else "moe")
            else:
                kinds.append("dense")
        return kinds

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d = self.d_model
        total = self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for kind in self.layer_kinds():
            if kind == "mamba":
                di = self.d_inner
                nh = self.ssm_heads
                total += d * (2 * di + 2 * self.ssm_state_dim + nh)
                total += di * d      # out proj
                total += (di + 2 * self.ssm_state_dim) * self.conv_kernel
            else:
                hd = self.head_dim
                if self.use_mla:
                    r = self.kv_lora_rank
                    total += d * (self.num_heads * hd) * 2  # q, o (approx)
                    total += d * (r + self.rope_head_dim)
                    total += r * self.num_heads * 2 * hd
                else:
                    total += d * self.num_heads * hd        # wq
                    total += 2 * d * self.num_kv_heads * hd  # wk, wv
                    total += self.num_heads * hd * d        # wo
                if kind == "moe":
                    total += (self.num_experts + self.num_shared_experts) \
                        * 3 * d * self.moe_d_ff
                    total += d * self.num_experts            # router
                    if self.first_dense_layers:
                        pass
                else:
                    total += 3 * d * self.d_ff
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k + shared experts only)."""
        if not self.moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        # subtract inactive experts
        inactive = self.num_experts - self.num_experts_per_tok
        n_moe_layers = sum(k == "moe" for k in self.layer_kinds())
        total -= n_moe_layers * inactive * 3 * d * self.moe_d_ff
        return total

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: same family, tiny dims."""
        def shrink(v, cap):
            return min(v, cap) if v else v
        num_heads = min(self.num_heads, 4) if self.num_heads else 0
        d_model = min(self.d_model, 256)
        head_dim = d_model // num_heads if num_heads else 0
        attn_every = min(self.hybrid_attn_every, 3)
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2 * max(1, attn_every)),
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=min(self.num_kv_heads, max(1, num_heads // 2))
            if self.num_kv_heads else 0,
            head_dim=head_dim,
            d_ff=shrink(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 1024),
            kv_lora_rank=shrink(self.kv_lora_rank, 64),
            q_lora_rank=shrink(self.q_lora_rank, 64),
            rope_head_dim=shrink(self.rope_head_dim, 32),
            num_experts=shrink(self.num_experts, 4),
            num_experts_per_tok=shrink(self.num_experts_per_tok, 2),
            num_shared_experts=shrink(self.num_shared_experts, 1),
            moe_d_ff=shrink(self.moe_d_ff, 128),
            ssm_state_dim=shrink(self.ssm_state_dim, 32),
            ssm_head_dim=shrink(self.ssm_head_dim, 32),
            ssm_chunk=shrink(self.ssm_chunk, 16),
            sliding_window=shrink(self.sliding_window, 64),
            num_prefix_embeddings=shrink(self.num_prefix_embeddings, 8),
            first_dense_layers=min(self.first_dense_layers, 1),
            hybrid_attn_every=attn_every,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # train | prefill | decode

    def reduced(self, seq_len: int = 64, batch: int = 2) -> "InputShape":
        return dataclasses.replace(self, name=self.name + "-reduced",
                                   seq_len=seq_len, global_batch=batch)


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
