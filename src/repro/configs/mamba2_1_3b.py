"""Mamba2-1.3B: attention-free SSD state-space model [arXiv:2405.21060]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b", arch_type="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm=True, ssm_state_dim=128, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=256, conv_kernel=4,
    tie_embeddings=True,
    source="arXiv:2405.21060 (Mamba2 1.3B: 48L, d=2048, state=128, "
           "headdim=64, SSD)",
)
