"""Qwen1.5-4B [hf:Qwen/Qwen1.5-4B family card]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b", arch_type="dense",
    num_layers=40, d_model=2560, num_heads=20, num_kv_heads=20,
    head_dim=128, d_ff=6912, vocab_size=151936,
    qkv_bias=True, act="silu", rope_theta=5000000.0,
    source="hf:Qwen/Qwen1.5 model cards (4B: 40L, d=2560, 20H, QKV bias)",
)
