"""InternVL2-1B: InternViT-300M vision encoder + Qwen2-0.5B LM
[arXiv:2404.16821].

The ViT + MLP projector frontend is a STUB per the brief: ``input_specs()``
provides 256 precomputed patch embeddings per image; this config is the LM
backbone that consumes them interleaved with text tokens.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", arch_type="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    head_dim=64, d_ff=4864, vocab_size=151655,
    qkv_bias=True,                      # Qwen2 backbone uses QKV bias
    pad_vocab_to=256,                   # 151655 ∤ 16: keep logits shardable
    modality="vision", num_prefix_embeddings=256,
    tie_embeddings=True, act="silu",
    source="arXiv:2404.16821 (InternVL2-1B: InternViT + Qwen2-0.5B LM)",
)
