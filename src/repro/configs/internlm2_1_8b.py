"""InternLM2-1.8B [arXiv:2403.17297]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b", arch_type="dense",
    num_layers=24, d_model=2048, num_heads=16, num_kv_heads=8,
    head_dim=128, d_ff=8192, vocab_size=92544,
    act="silu", rope_theta=1000000.0,
    source="arXiv:2403.17297 (InternLM2 1.8B: 24L, d=2048, GQA kv=8)",
)
