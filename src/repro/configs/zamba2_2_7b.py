"""Zamba2-2.7B: Mamba2 backbone + periodically-applied weight-shared
attention block [arXiv:2411.15242].

54 mamba2 layers; one *shared* (weight-tied) transformer block is invoked
every 6 layers (9 invocations, single parameter copy) — modeled by
``hybrid_attn_every=6``.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", arch_type="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    head_dim=80, d_ff=10240, vocab_size=32000,
    ssm=True, ssm_state_dim=64, ssm_head_dim=64, ssm_expand=2,
    ssm_chunk=256, conv_kernel=4,
    hybrid_attn_every=6,
    source="arXiv:2411.15242 (Zamba2: Mamba2 + shared attention blocks)",
)
