"""Config registry: ``get_config(arch_id)`` / ``list_archs()``.

LM architectures come from the assigned public pool (each file cites its
source); the paper's own workloads (GNNs on graphs) are registered as
``gcn_reddit``-style entries handled by the GNN engine.
"""
from __future__ import annotations

from .base import ArchConfig, InputShape, INPUT_SHAPES  # noqa: F401

from . import (deepseek_v2_lite_16b, gemma2_9b, granite_moe_1b_a400m,
               internlm2_1_8b, internvl2_1b, mamba2_1_3b, minitron_8b,
               musicgen_large, qwen1_5_4b, zamba2_2_7b)

_REGISTRY: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG for m in (
        minitron_8b, deepseek_v2_lite_16b, musicgen_large, mamba2_1_3b,
        zamba2_2_7b, granite_moe_1b_a400m, internvl2_1b, qwen1_5_4b,
        gemma2_9b, internlm2_1_8b)
}


def get_config(name: str) -> ArchConfig:
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    return sorted(_REGISTRY)


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]
