"""Granite-3.0-1B-A400M: fine-grained MoE
[hf:ibm-granite/granite-3.0-1b-a400m-base]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", arch_type="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    head_dim=64, d_ff=512, vocab_size=49155,
    moe=True, num_experts=32, num_experts_per_tok=8, num_shared_experts=0,
    moe_d_ff=512, first_dense_layers=0,
    pad_vocab_to=256,                   # 49155 ∤ 16: keep logits shardable
    tie_embeddings=True, act="silu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (32 experts, top-8)",
)
