"""Mixture-of-Experts: top-k router + capacity-bounded sort-based dispatch.

The dispatch is the MoE analogue of the paper's gather/split: tokens
(vertex-sharded in NeutronTP terms) are exchanged into an expert-major
layout (E, C, D) — experts sharded over the model axis — processed by
batched expert FFNs, and combined back.  Under pjit the scatter/gather pair
lowers to all-to-all traffic between the token and expert shardings.

Sort-based dispatch (no (T, E, C) one-hot): flatten the (T·k) assignments,
sort by expert, rank within expert via a searchsorted baseline, drop
overflow beyond capacity.  O(T·k log(T·k)) and memory-light.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as nl
from .param import param

Sharder = Callable[[jax.Array, str], jax.Array]


def init_moe(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": param(ks[0], (d, e), ("embed", None), dtype=jnp.float32),
        "gate": param(ks[1], (e, d, f), ("experts", "embed", "mlp"),
                      dtype=dtype, scale=scale),
        "up": param(ks[2], (e, d, f), ("experts", "embed", "mlp"),
                    dtype=dtype, scale=scale),
        "down": param(ks[3], (e, f, d), ("experts", "mlp", "embed"),
                      dtype=dtype, scale=1.0 / jnp.sqrt(f)),
    }
    if cfg.num_shared_experts:
        p["shared"] = nl.init_mlp(ks[4], d,
                                  cfg.moe_d_ff * cfg.num_shared_experts,
                                  dtype=dtype)
    return p


def moe_apply(p: dict, cfg: ArchConfig, x: jax.Array, *,
              capacity_factor: float | None = None,
              dropless: bool = False,
              shard: Sharder = lambda a, k: a):
    """x: (B, S, D) → (y, aux_loss).

    Routing: softmax → top-k (renormalized).  Capacity per expert
    C = ceil(T·k/E · cf); overflow tokens are dropped (their combine weight
    is zero), matching Switch/GShard semantics.  ``dropless=True`` sets
    C = T (decode path: bitwise-consistent with any routing history).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # (T, E)
    top_p, top_e = jax.lax.top_k(probs, k)                     # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # ---- load-balance auxiliary loss (Switch eq. 4) ----
    me = jnp.mean(probs, axis=0)                               # router mass
    one_hot = jnp.zeros((t, e), jnp.float32).at[
        jnp.arange(t)[:, None], top_e].add(1.0)
    ce = jnp.mean(one_hot, axis=0) / k                         # token frac
    aux = e * jnp.sum(me * ce)

    # ---- expert-parallel dispatch (explicit all-to-all; §Perf HC2) ----
    if not dropless and getattr(shard, "ep_moe", None):
        y = shard.ep_moe(p, cfg, x, top_e.reshape(b, s, k),
                         top_p.reshape(b, s, k), capacity_factor)
        if y is not None:
            if "shared" in p:
                y = y + nl.mlp(p["shared"], xf, cfg.act).reshape(b, s, d)
            return y, aux

    # ---- sort-based dispatch ----
    cap = t if dropless else int(max(1, -(-t * k // e) * capacity_factor))
    fe = top_e.reshape(-1)                                     # (T·k,)
    ft = jnp.repeat(jnp.arange(t), k)
    fp = top_p.reshape(-1)
    order = jnp.argsort(fe, stable=True)
    se, st, sp = fe[order], ft[order], fp[order]
    first = jnp.searchsorted(se, jnp.arange(e))                # (E,)
    pos = jnp.arange(t * k) - first[se]                        # rank in expert
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap - 1)

    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[se, pos_c].add(
        jnp.where(keep[:, None], xf[st], 0).astype(x.dtype))
    buf = shard(buf, "expert_buf")                             # (E, C, D)

    # ---- batched expert FFN ----
    act = nl.activation(cfg.act)
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["gate"].astype(x.dtype))) \
        * jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(x.dtype))
    y_buf = jnp.einsum("ecf,efd->ecd", h, p["down"].astype(x.dtype))
    y_buf = shard(y_buf, "expert_buf")

    # ---- combine ----
    gathered = y_buf[se, pos_c] * (sp * keep)[:, None].astype(x.dtype)
    yf = jnp.zeros((t, d), x.dtype).at[st].add(gathered)

    if "shared" in p:
        yf = yf + nl.mlp(p["shared"], xf, cfg.act)
    return yf.reshape(b, s, d), aux
