"""Attention: GQA (with biases/softcap/sliding-window) and DeepSeek MLA.

All functions are layout-annotated through an optional ``shard`` callable
(``repro.sharding.specs.Sharder``) so the same math serves every
distribution strategy; with the default no-op sharder they run on a single
device (smoke tests).

Decode caches:
  * ``KVCache``        — dense (B, S_max, H_kv, hd) k/v
  * ``WindowKVCache``  — ring buffer of the sliding window (gemma2 local
                         layers at long context)
  * ``MLACache``       — compressed: (B, S_max, kv_lora) latent + shared
                         rope key (B, S_max, rope_hd); O(S·(r+rope_hd))
                         instead of O(S·2·H·hd) — the MLA memory win.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as nl
from .param import param

Sharder = Callable[[jax.Array, str], jax.Array]


def no_shard(x: jax.Array, kind: str) -> jax.Array:
    return x


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, \
        cfg.head_dim
    ks = jax.random.split(key, 8)
    if cfg.use_mla:
        r, rhd = cfg.kv_lora_rank, cfg.rope_head_dim
        p = {
            # queries (Lite: no q-lora): per-head nope + rope parts
            "wq": param(ks[0], (d, hq, hd + rhd), ("embed", "heads", None),
                        dtype=dtype),
            # compressed kv latent + shared rope key
            "wkv_a": param(ks[1], (d, r + rhd), ("embed", None),
                           dtype=dtype),
            "kv_norm": nl.init_rms_norm(r),
            # up-projections from the latent
            "wk_b": param(ks[2], (r, hq, hd), (None, "heads", None),
                          dtype=dtype),
            "wv_b": param(ks[3], (r, hq, hd), (None, "heads", None),
                          dtype=dtype),
            "wo": param(ks[4], (hq, hd, d), ("heads", None, "embed"),
                        dtype=dtype),
        }
        return p
    p = {
        "wq": param(ks[0], (d, hq, hd), ("embed", "heads", None),
                    dtype=dtype),
        "wk": param(ks[1], (d, hkv, hd), ("embed", "kv_heads", None),
                    dtype=dtype),
        "wv": param(ks[2], (d, hkv, hd), ("embed", "kv_heads", None),
                    dtype=dtype),
        "wo": param(ks[3], (hq, hd, d), ("heads", None, "embed"),
                    dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = param(None, (hq, hd), ("heads", None), init="zeros",
                        dtype=dtype)
        p["bk"] = param(None, (hkv, hd), ("kv_heads", None), init="zeros",
                        dtype=dtype)
        p["bv"] = param(None, (hkv, hd), ("kv_heads", None), init="zeros",
                        dtype=dtype)
    return p


# ---------------------------------------------------------------------------
# Masks and core attention
# ---------------------------------------------------------------------------

def _causal_mask(sq: int, skv: int, q_offset) -> jax.Array:
    qi = q_offset + jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    return kj <= qi


def _window_mask(sq: int, skv: int, q_offset, window: int) -> jax.Array:
    qi = q_offset + jnp.arange(sq)[:, None]
    kj = jnp.arange(skv)[None, :]
    return (kj <= qi) & (kj > qi - window)


def attention_blockwise(q, k, v, *, causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: int | jax.Array = 0,
                        softcap: Optional[float] = None,
                        scale: Optional[float] = None,
                        block_q: int = 512, block_kv: int = 1024
                        ) -> jax.Array:
    """Memory-efficient attention: scan over KV blocks with running
    (max, sum, acc) — never materializes the S×S score matrix.

    Beyond-paper optimization (§Perf iter 1): drops the attention working
    set from O(B·H·S²) to O(B·H·block_q·block_kv).  On TPU this is the
    flash-attention schedule; in pure jnp XLA fuses each block step.
    q: (B,Sq,Hq,hd); k/v: (B,Skv,Hkv,hd).  Returns (B,Sq,Hq,hd_v).
    """
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    hdv = v.shape[-1]
    scale = scale if scale is not None else hd ** -0.5

    q_pad = (-sq) % block_q
    kv_pad = (-skv) % block_kv
    qp = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
    nq, nkv = qp.shape[1] // block_q, kp.shape[1] // block_kv

    qb = qp.reshape(b, nq, block_q, hkv, g, hd).astype(jnp.float32) * scale
    kb = kp.reshape(b, nkv, block_kv, hkv, hd).astype(jnp.float32)
    vb = vp.reshape(b, nkv, block_kv, hkv, hdv).astype(jnp.float32)

    q_pos = q_offset + jnp.arange(nq * block_q).reshape(nq, block_q)
    k_pos = jnp.arange(nkv * block_kv).reshape(nkv, block_kv)
    k_valid = (jnp.arange(nkv * block_kv) < skv).reshape(nkv, block_kv)

    def q_block(qi):
        q_i = qb[:, qi]                              # (B,bq,hkv,g,hd)
        pos_i = q_pos[qi]

        def kv_step(carry, kj):
            m_run, l_run, acc = carry
            k_j = kb[:, kj]
            v_j = vb[:, kj]
            s = jnp.einsum("bqkgh,btkh->bkgqt", q_i, k_j)
            if softcap is not None:
                s = softcap_ * jnp.tanh(s / softcap_)
            msk = k_valid[kj][None, :]
            if causal:
                msk = msk & (k_pos[kj][None, :] <= pos_i[:, None])
            if window is not None:
                msk = msk & (k_pos[kj][None, :] > pos_i[:, None] - window)
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, s.max(axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p, v_j)
            return (m_new, l_new, acc), None

        softcap_ = softcap
        init = (jnp.full((b, hkv, g, block_q), -jnp.inf, jnp.float32),
                jnp.zeros((b, hkv, g, block_q), jnp.float32),
                jnp.zeros((b, hkv, g, block_q, hdv), jnp.float32))
        (m_run, l_run, acc), _ = jax.lax.scan(
            kv_step, init, jnp.arange(nkv))
        out = acc / jnp.maximum(l_run, 1e-30)[..., None]
        return out                                   # (B,hkv,g,bq,hdv)

    outs = jax.lax.map(q_block, jnp.arange(nq))      # (nq,B,hkv,g,bq,hdv)
    out = jnp.moveaxis(outs, 0, 1)                   # (B,nq,hkv,g,bq,hdv)
    out = out.transpose(0, 1, 4, 2, 3, 5).reshape(
        b, nq * block_q, hq, hdv)
    return out[:, :sq].astype(v.dtype)


def attention_core(q, k, v, mask, *, softcap: Optional[float] = None,
                   scale: Optional[float] = None) -> jax.Array:
    """q: (B,Sq,Hq,hd), k/v: (B,Skv,Hkv,hd) with Hq % Hkv == 0.

    Returns (B,Sq,Hq,hd).  ``mask`` broadcasts to (B,1,1,Sq,Skv)."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5
    qg = q.reshape(b, sq, hkv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    scores = nl.softcap(scores, softcap)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, v.shape[-1])   # v head dim may differ (MLA)


# ---------------------------------------------------------------------------
# GQA forward (train/prefill) + decode
# ---------------------------------------------------------------------------

def gqa_project_qkv(p, cfg: ArchConfig, x, positions):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = nl.apply_rope(q, positions, cfg.rope_theta)
    k = nl.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mixing_attention(cfg: ArchConfig, q, k, v, *,
                      window: Optional[int] = None,
                      scale: Optional[float] = None,
                      shard: Sharder = no_shard):
    """Core full-sequence attention with the NeutronTP mixing-phase layout
    (heads sharded, sequence gathered) and selectable implementation."""
    if getattr(shard, "explicit_a2a", None):
        out = shard.explicit_a2a(cfg, q, k, v, window=window, scale=scale)
        if out is not None:      # None → divisibility fallback below
            return out
    q = shard(q, "act_heads")
    k = shard(k, "act_kv_heads")
    v = shard(v, "act_kv_heads")
    if cfg.attn_impl == "flash":
        # Pallas kernel (kernels/flash_attn): interpret on CPU, native on
        # TPU.  The VMEM-resident score block is the §Perf HC1 fix.
        from ..kernels.flash_attn import flash_attention
        out = flash_attention(
            q, k, v, causal=True, window=window, softcap=cfg.attn_softcap,
            scale=scale, block_q=cfg.attn_block_q,
            block_kv=cfg.attn_block_kv,
            interpret=jax.default_backend() != "tpu")
    elif cfg.attn_impl == "blockwise":
        out = attention_blockwise(q, k, v, causal=True, window=window,
                                  softcap=cfg.attn_softcap, scale=scale,
                                  block_q=cfg.attn_block_q,
                                  block_kv=cfg.attn_block_kv)
    else:
        sq = q.shape[1]
        mask = (_window_mask(sq, sq, 0, window) if window
                else _causal_mask(sq, sq, 0))[None]
        out = attention_core(q, k, v, mask, softcap=cfg.attn_softcap,
                             scale=scale)
    return shard(out, "act_heads")


def gqa_attention(p, cfg: ArchConfig, x, positions, *,
                  window: Optional[int] = None, shard: Sharder = no_shard):
    """Full-sequence attention (training / prefill)."""
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    out = _mixing_attention(cfg, q, k, v, window=window, shard=shard)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard(y, "act_tokens")


def gqa_prefill(p, cfg: ArchConfig, x, positions, max_len: int, *,
                window: Optional[int] = None, shard: Sharder = no_shard,
                long_context: bool = False):
    """Full-sequence attention that also materializes the decode cache."""
    q, k, v = gqa_project_qkv(p, cfg, x, positions)
    out = _mixing_attention(cfg, q, k, v, window=window, shard=shard)
    sq = x.shape[1]
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    y = shard(y, "act_tokens")

    if window and long_context:
        cache = _fill_window_cache(cfg, k, v, window)
    else:
        b = x.shape[0]
        kc = jnp.zeros((b, max_len) + k.shape[2:], k.dtype)
        vc = jnp.zeros_like(kc)
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k, 0, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, v, 0, axis=1)
        cache = KVCache(k=shard(kc, "cache_seq"), v=shard(vc, "cache_seq"),
                        length=jnp.asarray(sq, jnp.int32))
    return y, cache


def _fill_window_cache(cfg: ArchConfig, k, v, window: int):
    """Scatter the last ``window`` positions into their ring slots."""
    b, s = k.shape[:2]
    start = max(0, s - window)
    ps = jnp.arange(start, s)
    slots = jnp.mod(ps, window)
    kr = jnp.zeros((b, window) + k.shape[2:], k.dtype)
    vr = jnp.zeros_like(kr)
    kr = kr.at[:, slots].set(k[:, start:s])
    vr = vr.at[:, slots].set(v[:, start:s])
    return WindowKVCache(k=kr, v=vr, length=jnp.asarray(s, jnp.int32),
                         window=window)


# ---- dense KV cache -------------------------------------------------------

@partial(jax.tree_util.register_dataclass, data_fields=("k", "v", "length"),
         meta_fields=())
@dataclasses.dataclass
class KVCache:
    k: jax.Array        # (B, S_max, H_kv, hd)
    v: jax.Array
    length: jax.Array   # () int32 — valid prefix length


def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int,
                  dtype=jnp.float32) -> KVCache:
    shape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
                   length=jnp.zeros((), jnp.int32))


def gqa_decode(p, cfg: ArchConfig, x, cache: KVCache, *,
               shard: Sharder = no_shard):
    """One-token decode against a dense cache.  x: (B, 1, D)."""
    pos = cache.length
    positions = pos[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
    q, k_new, v_new = gqa_project_qkv(p, cfg, x, positions)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new.astype(
        cache.k.dtype), pos, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new.astype(
        cache.v.dtype), pos, axis=1)
    k = shard(k, "cache_seq")
    v = shard(v, "cache_seq")
    skv = k.shape[1]
    mask = (jnp.arange(skv)[None, :] <= pos)[None]         # (1, 1, skv)
    out = attention_core(q, k, v, mask, softcap=cfg.attn_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, KVCache(k=k, v=v, length=pos + 1)


# ---- sliding-window ring cache --------------------------------------------

@partial(jax.tree_util.register_dataclass, data_fields=("k", "v", "length"),
         meta_fields=("window",))
@dataclasses.dataclass
class WindowKVCache:
    k: jax.Array        # (B, window, H_kv, hd) ring buffer
    v: jax.Array
    length: jax.Array   # () int32 — total tokens seen
    window: int


def init_window_cache(cfg: ArchConfig, batch: int,
                      dtype=jnp.float32) -> WindowKVCache:
    w = cfg.sliding_window
    shape = (batch, w, cfg.num_kv_heads, cfg.head_dim)
    return WindowKVCache(k=jnp.zeros(shape, dtype),
                         v=jnp.zeros(shape, dtype),
                         length=jnp.zeros((), jnp.int32), window=w)


def gqa_decode_windowed(p, cfg: ArchConfig, x, cache: WindowKVCache, *,
                        shard: Sharder = no_shard):
    """One-token decode with an O(window) ring cache — the sub-quadratic
    path that makes gemma2 local layers viable at 500k context."""
    pos = cache.length
    positions = pos[None, None] + jnp.zeros((x.shape[0], 1), jnp.int32)
    q, k_new, v_new = gqa_project_qkv(p, cfg, x, positions)
    slot = jnp.mod(pos, cache.window)
    k = jax.lax.dynamic_update_slice_in_dim(
        cache.k, k_new.astype(cache.k.dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache.v, v_new.astype(cache.v.dtype), slot, axis=1)
    # ring slot j holds absolute position: j + window*floor(...) — valid iff
    # within the last `window` tokens and <= pos
    ring = jnp.arange(cache.window)
    age = jnp.mod(slot - ring, cache.window)        # 0 = newest slot
    valid = age <= jnp.minimum(pos, cache.window - 1)
    mask = valid[None, None]                        # (1, 1, window)
    out = attention_core(q, k, v, mask, softcap=cfg.attn_softcap)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, WindowKVCache(k=k, v=v, length=pos + 1, window=cache.window)


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): compressed-latent attention
# ---------------------------------------------------------------------------

def _mla_q(p, cfg, x, positions):
    qfull = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q_nope = qfull[..., : cfg.head_dim]
    q_rope = nl.apply_rope(qfull[..., cfg.head_dim:], positions,
                           cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(p, cfg, x, positions):
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    c_kv = nl.rms_norm(kv_a[..., : cfg.kv_lora_rank],
                       p["kv_norm"].astype(jnp.float32))
    k_rope = nl.apply_rope(kv_a[..., None, cfg.kv_lora_rank:], positions,
                           cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_attention(p, cfg: ArchConfig, x, positions, *,
                  shard: Sharder = no_shard):
    """Full-sequence MLA (training / prefill)."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (b, s, cfg.num_heads,
                                   cfg.rope_head_dim))], axis=-1)
    scale = (cfg.head_dim + cfg.rope_head_dim) ** -0.5
    out = _mixing_attention(cfg, q, k, v, scale=scale, shard=shard)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return shard(y, "act_tokens")


def mla_prefill(p, cfg: ArchConfig, x, positions, max_len: int, *,
                shard: Sharder = no_shard):
    """MLA prefill: full-sequence attention + compressed cache fill."""
    b, s, _ = x.shape
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_latent(p, cfg, x, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"].astype(x.dtype))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None],
                                  (b, s, cfg.num_heads,
                                   cfg.rope_head_dim))], axis=-1)
    scale = (cfg.head_dim + cfg.rope_head_dim) ** -0.5
    out = _mixing_attention(cfg, q, k, v, scale=scale, shard=shard)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))

    ckv_buf = jnp.zeros((b, max_len, cfg.kv_lora_rank), c_kv.dtype)
    kr_buf = jnp.zeros((b, max_len, cfg.rope_head_dim), k_rope.dtype)
    ckv_buf = jax.lax.dynamic_update_slice_in_dim(ckv_buf, c_kv, 0, axis=1)
    kr_buf = jax.lax.dynamic_update_slice_in_dim(kr_buf, k_rope, 0, axis=1)
    cache = MLACache(c_kv=ckv_buf, k_rope=kr_buf,
                     length=jnp.asarray(s, jnp.int32))
    return y, cache


@partial(jax.tree_util.register_dataclass,
         data_fields=("c_kv", "k_rope", "length"), meta_fields=())
@dataclasses.dataclass
class MLACache:
    c_kv: jax.Array     # (B, S_max, kv_lora_rank) — compressed latents
    k_rope: jax.Array   # (B, S_max, rope_head_dim) — shared rope key
    length: jax.Array


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int,
                   dtype=jnp.float32) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, max_len, cfg.rope_head_dim), dtype),
        length=jnp.zeros((), jnp.int32))


def mla_decode(p, cfg: ArchConfig, x, cache: MLACache, *,
               shard: Sharder = no_shard):
    """One-token MLA decode on the compressed cache.

    Uses the absorbed-matmul trick: queries are pulled into latent space
    (q·W_kb) so attention runs against the (S, r) latents directly — per
    step FLOPs O(S·(r + rope_hd)·H) and cache stays compressed."""
    b = x.shape[0]
    pos = cache.length
    positions = pos[None, None] + jnp.zeros((b, 1), jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_new, kr_new = _mla_latent(p, cfg, x, positions)
    c_kv = jax.lax.dynamic_update_slice_in_dim(
        cache.c_kv, c_new.astype(cache.c_kv.dtype), pos, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache.k_rope, kr_new.astype(cache.k_rope.dtype), pos, axis=1)
    c_kv = shard(c_kv, "cache_seq_latent")
    k_rope = shard(k_rope, "cache_seq_latent")
    # absorb: q_lat (b,1,h,r) = q_nope · W_kb^T
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wk_b"].astype(x.dtype))
    scores = (jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32),
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32)))
    scores *= (cfg.head_dim + cfg.rope_head_dim) ** -0.5
    mask = (jnp.arange(c_kv.shape[1])[None, :] <= pos)  # (1, S)
    scores = jnp.where(mask[:, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out_lat = jnp.einsum("bhst,btr->bshr", probs, c_kv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", out_lat.astype(x.dtype),
                     p["wv_b"].astype(x.dtype))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return y, MLACache(c_kv=c_kv, k_rope=k_rope, length=pos + 1)
