"""Ring attention: sequence-parallel exact attention via collective-permute.

§Perf HC1 round 2 (beyond-paper).  NeutronTP's gather/split assumes the
mixing dimension (heads) divides the TP degree; qwen1.5-4b (20 heads) and
internvl2-1b (14 heads) break that on a 16-way model axis, so the baseline
partitioner replicates heads and all-gathers the sequence — full S² score
traffic per device AND g× wire bytes.

Ring attention keeps the sequence *sharded* through the mixing phase:
every device holds its S/n query chunk and rotates the K/V chunks around
the ring (n−1 collective-permutes), accumulating online softmax per step.
Per-device score working set drops from S² to (S/n)² per step (n steps),
and the wire traffic equals one all-gather of K/V — but chunked, so each
permute overlaps the previous chunk's compute.  This is exactly the
paper's inter-chunk pipelining (§4.2.2 / Fig. 9c) applied to attention:
chunk-level communication tasks overlapped with chunk compute, layer-wise
synchronization preserved.

Differentiable (lax.scan + ppermute transpose).  Must be called inside a
:func:`repro.runtime.engine`/``smap`` body with ``axis_name`` bound; all
heads local, seq sharded."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..runtime import collectives as C
from ..runtime import telemetry as T


def ring_attention_local(ql, kl, vl, axis_name: str, *,
                         causal: bool = True,
                         window: Optional[int] = None,
                         softcap: Optional[float] = None,
                         scale: Optional[float] = None) -> jax.Array:
    """ql: (B, S/n, Hq, hd) local query chunk; kl/vl: (B, S/n, Hkv, hd[_v])
    local K/V chunks.  Returns (B, S/n, Hq, hd_v) — same layout as ql."""
    b, sc, hq, hd = ql.shape
    hkv = kl.shape[2]
    hdv = vl.shape[-1]
    g = hq // hkv
    scale = scale if scale is not None else hd ** -0.5

    idx = C.axis_index(axis_name)
    n = C.axis_size(axis_name)
    q_pos = idx * sc + jnp.arange(sc)                   # global positions

    qg = ql.reshape(b, sc, hkv, g, hd).astype(jnp.float32) * scale
    perm = [(i, (i + 1) % n) for i in range(n)]

    def step(carry, r):
        k_c, v_c, m_run, l_run, acc = carry
        src = jnp.mod(idx - r, n)                       # chunk owner
        k_pos = src * sc + jnp.arange(sc)
        s = jnp.einsum("bqkgh,btkh->bkgqt", qg,
                       k_c.astype(jnp.float32))         # (B,hkv,g,sc,sc)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        mask = jnp.ones((sc, sc), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m_run, s.max(axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        l_new = l_run * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bkgqt,btkh->bkgqh", p, v_c.astype(jnp.float32))
        # rotate: device i sends its current chunk to i+1 (receives i−1's)
        k_nxt = C.ppermute(k_c, axis_name, perm=perm, mirror=True)
        v_nxt = C.ppermute(v_c, axis_name, perm=perm, mirror=True)
        return (k_nxt, v_nxt, m_new, l_new, acc_new), None

    init = (kl, vl,
            jnp.full((b, hkv, g, sc), -jnp.inf, jnp.float32),
            jnp.zeros((b, hkv, g, sc), jnp.float32),
            jnp.zeros((b, hkv, g, sc, hdv), jnp.float32))
    # remat each ring step: the backward pass recomputes the (sc, sc)
    # score/prob chunks instead of storing n of them across the scan —
    # without this, internvl2 train_4k peaked at 79 GiB/dev (§Perf R2.4)
    # loop_scope: the body's two ppermutes trace once but rotate n× — a
    # collecting telemetry ledger must count every ring hop (n is static:
    # jnp.arange(n) already requires it)
    with T.loop_scope(n):
        (_, _, m_run, l_run, acc), _ = jax.lax.scan(
            jax.checkpoint(step), init, jnp.arange(n))
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]    # (B,hkv,g,sc,hdv)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sc, hq, hdv) \
        .astype(ql.dtype)
