"""Parameter creation with logical axis names.

Params are built as ``ParamLeaf(value, names)`` where ``names`` tags each
array dim with a logical axis ("embed", "heads", "mlp", "vocab", ...).
``split_params`` separates the value tree from the names tree; the sharding
rules in :mod:`repro.sharding.specs` map logical names → mesh axes per
distribution strategy, giving every strategy a single source of truth for
parameter layout.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.tree_util.register_dataclass, data_fields=("value",),
         meta_fields=("names",))
@dataclasses.dataclass(frozen=True)
class ParamLeaf:
    """Registered pytree: ``value`` is the (sole) child, ``names`` rides
    along as static metadata — so ParamLeaf trees pass through jit /
    eval_shape / optimizers transparently while keeping logical axes."""
    value: Any                      # jax.Array or ShapeDtypeStruct
    names: tuple[str | None, ...]   # one logical name per dim


def param(key, shape, names, dtype=jnp.float32, scale: float | None = None,
          init: str = "normal") -> ParamLeaf:
    assert len(shape) == len(names), (shape, names)
    if init == "zeros":
        v = jnp.zeros(shape, dtype)
    elif init == "ones":
        v = jnp.ones(shape, dtype)
    elif init == "normal":
        if scale is None:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
        v = (scale * jax.random.normal(key, shape)).astype(dtype)
    else:
        raise ValueError(init)
    return ParamLeaf(v, tuple(names))


def is_leaf(x):
    return isinstance(x, ParamLeaf)


def split_params(tree):
    """(values_tree, names_tree) from a ParamLeaf tree."""
    values = jax.tree.map(lambda l: l.value, tree, is_leaf=is_leaf)
    names = jax.tree.map(lambda l: l.names, tree, is_leaf=is_leaf)
    return values, names


def map_names_to_specs(names_tree, rule):
    """names tuple → PartitionSpec via ``rule(logical_name) -> mesh axis``."""
    from jax.sharding import PartitionSpec as P

    def to_spec(names):
        return P(*[rule(n) for n in names])

    return jax.tree.map(to_spec, names_tree,
                        is_leaf=lambda x: isinstance(x, tuple))
