from . import layers, attention, moe, ssm, param  # noqa: F401
