"""Transformer NN primitives: norms, projections, embeddings, RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .param import ParamLeaf, param


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6,
             plus_one: bool = False) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    w = weight.astype(jnp.float32)
    if plus_one:                     # gemma-style (1 + w) scaling
        w = 1.0 + w
    return (x * w).astype(dtype)


def init_rms_norm(d: int, plus_one: bool = False) -> ParamLeaf:
    init = "zeros" if plus_one else "ones"
    return param(None, (d,), ("embed",), init=init)


def softcap(x: jax.Array, cap: float | None) -> jax.Array:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                      # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,s,hd/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / embedding initializers (ParamLeaf trees)
# ---------------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, names: tuple,
               bias: bool = False, dtype=jnp.float32, scale=None) -> dict:
    p = {"w": param(key, (d_in, d_out), names, dtype=dtype, scale=scale)}
    if bias:
        p["b"] = param(None, (d_out,), (names[-1],), init="zeros",
                       dtype=dtype)
    return p


def dense(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, d: int, dtype=jnp.float32) -> ParamLeaf:
    return param(key, (vocab, d), ("vocab", "embed"), dtype=dtype, scale=1.0)


def embed(table: jax.Array, tokens: jax.Array) -> jax.Array:
    return jnp.take(table, tokens, axis=0)


def init_mlp(key, d: int, d_ff: int, dtype=jnp.float32) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d, d_ff, ("embed", "mlp"), dtype=dtype),
        "up": init_dense(k2, d, d_ff, ("embed", "mlp"), dtype=dtype),
        "down": init_dense(k3, d_ff, d, ("mlp", "embed"), dtype=dtype),
    }


def mlp(p: dict, x: jax.Array, act: str = "silu") -> jax.Array:
    """Gated MLP (SwiGLU / GeGLU)."""
    return dense(p["down"], activation(act)(dense(p["gate"], x))
                 * dense(p["up"], x))
