"""Mamba2 (SSD — state-space duality) mixing layer [arXiv:2405.21060].

The SSD chunked algorithm is a natural fit for the paper's chunk-scheduling
idea: the sequence is cut into chunks; within a chunk the dual (quadratic)
form runs on the MXU, and a tiny recurrence carries the (H, P, N) state
between chunks — the same "bounded working set + sequential chunk schedule"
structure NeutronTP uses for graph aggregation.

Head sharding note (DESIGN §Arch-applicability): the SSD state is
block-diagonal over heads, so sharding heads over the model axis needs *no*
collectives inside the scan — the analogue of NeutronTP's feature slices.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from . import layers as nl
from .param import param

Sharder = Callable[[jax.Array, str], jax.Array]


def init_mamba2(key, cfg: ArchConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state_dim
    nh = cfg.ssm_heads
    conv_dim = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        # fused input projection → [z, x, B, C, dt]
        "in_proj": param(ks[0], (d, 2 * di + 2 * n + nh), ("embed", "inner"),
                         dtype=dtype),
        "conv_w": param(ks[1], (cfg.conv_kernel, conv_dim),
                        (None, "inner"), dtype=dtype,
                        scale=1.0 / cfg.conv_kernel),
        "conv_b": param(None, (conv_dim,), ("inner",), init="zeros",
                        dtype=dtype),
        "a_log": param(None, (nh,), ("ssm_heads",), init="zeros",
                       dtype=jnp.float32),
        "d_skip": param(None, (nh,), ("ssm_heads",), init="ones",
                        dtype=jnp.float32),
        "dt_bias": param(None, (nh,), ("ssm_heads",), init="zeros",
                         dtype=jnp.float32),
        "norm": nl.init_rms_norm(di),
        "out_proj": param(ks[2], (di, d), ("inner", "embed"), dtype=dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, n, nh = cfg.d_inner, cfg.ssm_state_dim, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: 2 * di + 2 * n]
    dt = zxbcdt[..., 2 * di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C); kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + xbc.shape[1]] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def _segsum(x: jax.Array) -> jax.Array:
    """L[i, j] = sum_{j < k <= i} x[k]  (−inf above diagonal)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int):
    """SSD forward (training / prefill).

    x     : (B, S, H, P)   per-head inputs
    dt    : (B, S, H)      softplus'd step sizes
    a     : (H,)           negative decay rates
    b_mat : (B, S, N)      input  projection (single group)
    c_mat : (B, S, N)      output projection
    Returns (B, S, H, P) and the final state (B, H, P, N).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    s_orig = s
    if s % chunk:
        # pad tail: dt=0 ⇒ decay=1 and zero state update — numerically inert
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    # Head-major (B,nc,H,Q,·) layouts throughout so every contraction is a
    # plain batched matmul — §Perf round 3: the 4-operand einsum forms let
    # XLA insert (B,nc,H,Q,Q)-sized transpose/copy pairs between dots
    # (~2e13 B/step censused on mamba2 train_4k); with consistent layouts
    # only ONE final transpose back to sequence-major remains.
    xc_h = x.reshape(bsz, nc, chunk, h, p).transpose(0, 1, 3, 2, 4)
    dtc_h = dt.reshape(bsz, nc, chunk, h).transpose(0, 1, 3, 2)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    da_h = dtc_h * a[:, None]                          # (B,nc,H,Q)
    seg = _segsum(da_h)                                # (B,nc,H,Q,Q)
    l_mat = jnp.exp(seg)

    # intra-chunk (dual/quadratic) term: M = (C·Bᵀ) ⊙ L ⊙ dt, y = M·X
    scores = jnp.einsum("bcqn,bckn->bcqk", cc, bc)     # (B,nc,Q,Q)
    m_mat = scores[:, :, None] * l_mat * dtc_h[..., None, :]
    y_intra_h = m_mat @ xc_h                           # (B,nc,H,Q,P)

    # per-chunk final states: state[p,n] = Σ_k w[k]·x[k,p]·b[k,n]
    decay_to_end = jnp.exp(jnp.cumsum(da_h[..., ::-1], axis=-1)[..., ::-1]
                           - da_h)                     # (B,nc,H,Q)
    wb = bc[:, :, None] * (decay_to_end * dtc_h)[..., None]
    states = jnp.einsum("bchqp,bchqn->bchpn", xc_h, wb)  # (B,nc,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(jnp.sum(da_h, axis=-1))      # (B,nc,H)

    def scan_fn(carry, inp):
        st, dec = inp
        new = carry * dec[..., None, None] + st
        return new, carry                              # emit state BEFORE chunk

    init = jnp.zeros((bsz, h, p, n), x.dtype)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)      # (B,nc,H,P,N)

    # inter-chunk contribution: decay from chunk start
    decay_from_start = jnp.exp(jnp.cumsum(da_h, axis=-1))  # (B,nc,H,Q)
    ch = cc[:, :, None] * decay_from_start[..., None]      # (B,nc,H,Q,N)
    y_inter_h = ch @ jnp.swapaxes(prev_states, -1, -2)     # (B,nc,H,Q,P)
    y = (y_intra_h + y_inter_h).transpose(0, 1, 3, 2, 4)   # → seq-major
    y = y.reshape(bsz, s, h, p)
    return y[:, :s_orig], final


def mamba2_forward(p: dict, cfg: ArchConfig, x: jax.Array, *,
                   shard: Sharder = lambda a, k: a):
    """Full-sequence mamba2 block (training / prefill).  x: (B, S, D)."""
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    di, n = cfg.d_inner, cfg.ssm_state_dim
    xs = xbc[..., :di]
    b_mat = xbc[..., di: di + n]
    c_mat = xbc[..., di + n:]
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    xh = xs.reshape(*xs.shape[:2], nh, hp)
    xh = shard(xh, "act_ssm_heads")
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    if cfg.ssm_impl == "fused":
        from ..kernels.ssd import ssd_chunked_pallas
        y, _ = ssd_chunked_pallas(
            xh.astype(jnp.float32), dt_sp, a, b_mat.astype(jnp.float32),
            c_mat.astype(jnp.float32), cfg.ssm_chunk,
            interpret=jax.default_backend() != "tpu")
    else:
        y, _ = ssd_chunked(xh.astype(jnp.float32), dt_sp, a,
                           b_mat.astype(jnp.float32),
                           c_mat.astype(jnp.float32), cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]   # D skip
    y = shard(y.astype(x.dtype), "act_ssm_heads")
    y = y.reshape(*x.shape[:2], di)
    y = nl.rms_norm(y * jax.nn.silu(z), p["norm"].astype(jnp.float32),
                    cfg.norm_eps)
    return y @ p["out_proj"].astype(x.dtype)


def mamba2_prefill(p: dict, cfg: ArchConfig, x: jax.Array, *,
                   shard: Sharder = lambda a, k: a):
    """Full-sequence mamba2 that also returns the decode cache."""
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc_raw, dt = _split_proj(cfg, zxbcdt)
    xbc = _causal_conv(xbc_raw, p["conv_w"].astype(x.dtype),
                       p["conv_b"].astype(x.dtype))
    di, n = cfg.d_inner, cfg.ssm_state_dim
    xs = xbc[..., :di]
    b_mat = xbc[..., di: di + n]
    c_mat = xbc[..., di + n:]
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    xh = xs.reshape(*xs.shape[:2], nh, hp)
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, final_state = ssd_chunked(xh.astype(jnp.float32), dt_sp, a,
                                 b_mat.astype(jnp.float32),
                                 c_mat.astype(jnp.float32), cfg.ssm_chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][:, None]
    y = y.astype(x.dtype).reshape(*x.shape[:2], di)
    y = nl.rms_norm(y * jax.nn.silu(z), p["norm"].astype(jnp.float32),
                    cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    k = cfg.conv_kernel
    cache = SSMCache(
        conv_state=xbc_raw[:, -(k - 1):].astype(x.dtype),
        ssm_state=final_state,
        length=jnp.asarray(x.shape[1], jnp.int32))
    return out, cache


# ---------------------------------------------------------------------------
# Decode (O(1) state per step)
# ---------------------------------------------------------------------------

@partial(jax.tree_util.register_dataclass,
         data_fields=("conv_state", "ssm_state", "length"), meta_fields=())
@dataclasses.dataclass
class SSMCache:
    conv_state: jax.Array   # (B, K-1, conv_dim)
    ssm_state: jax.Array    # (B, H, P, N)
    length: jax.Array


def init_ssm_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    conv_dim = cfg.d_inner + 2 * cfg.ssm_state_dim
    return SSMCache(
        conv_state=jnp.zeros((batch, cfg.conv_kernel - 1, conv_dim), dtype),
        ssm_state=jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                             cfg.ssm_state_dim), jnp.float32),
        length=jnp.zeros((), jnp.int32))


def mamba2_decode(p: dict, cfg: ArchConfig, x: jax.Array, cache: SSMCache,
                  *, shard: Sharder = lambda a, k: a):
    """Single-token step.  x: (B, 1, D) → (B, 1, D), new cache."""
    zxbcdt = x @ p["in_proj"].astype(x.dtype)
    z, xbc_new, dt = _split_proj(cfg, zxbcdt)          # (B,1,·)
    window = jnp.concatenate([cache.conv_state,
                              xbc_new.astype(cache.conv_state.dtype)],
                             axis=1)                   # (B, K, conv)
    w = p["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(
        x.dtype)
    xbc = jax.nn.silu(conv_out)[:, None]
    di, n = cfg.d_inner, cfg.ssm_state_dim
    xs = xbc[..., :di]
    b_mat = xbc[..., di: di + n][:, 0]                 # (B, N)
    c_mat = xbc[..., di + n:][:, 0]
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    xh = xs.reshape(x.shape[0], nh, hp).astype(jnp.float32)
    dt_sp = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt_sp * a)                         # (B, H)
    state = cache.ssm_state * decay[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt_sp, xh, b_mat.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", c_mat.astype(jnp.float32), state)
    y = y + xh * p["d_skip"][:, None]
    y = y.reshape(x.shape[0], 1, di).astype(x.dtype)
    y = nl.rms_norm(y * jax.nn.silu(z), p["norm"].astype(jnp.float32),
                    cfg.norm_eps)
    out = y @ p["out_proj"].astype(x.dtype)
    return out, SSMCache(conv_state=window[:, 1:], ssm_state=state,
                         length=cache.length + 1)
