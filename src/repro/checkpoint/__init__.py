"""Checkpointing: flat .npz per pytree + JSON manifest (no orbax offline).

Handles arbitrary registered-dataclass pytrees (TrainState, ParamLeaf
trees, caches) by saving leaves keyed by their flattened index alongside
a treedef fingerprint.

What :func:`restore` actually validates, in order:

1. **leaf count** — manifest ``n_leaves`` vs the template's flattened
   leaves;
2. **tree structure** — the stored ``treedef`` fingerprint
   (``str(treedef)``) must equal the template's: a same-arity pytree
   with different structure (a dict key renamed, a list that became a
   tuple) is rejected instead of silently restoring leaves into the
   wrong slots;
3. **per-leaf shape and dtype** — each saved array against the template
   leaf, errors naming the leaf's tree path (``jax.tree_util.keystr``).

Values are NOT checksummed, and optimizer hyper-state / code version are
whatever the caller put in ``metadata`` — this module validates layout,
not meaning.

Single-host only: :func:`save` requires every leaf to be fully
addressable from this process and raises an actionable error for
multi-process global arrays (per-process *sharded* checkpointing is the
ROADMAP "elastic multi-host" item; gather to host or save replicated
state from the coordinator until then).
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree: Any):
    """(keystr paths, leaves, treedef) of a pytree — paths name leaves in
    errors so "leaf 17" becomes "['layers'][2]['w']"."""
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = [jax.tree_util.keystr(p) or "<root>"
             for p, _ in paths_and_leaves]
    leaves = [l for _, l in paths_and_leaves]
    return paths, leaves, treedef


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    paths, leaves, treedef = _leaf_paths(tree)
    for p, leaf in zip(paths, leaves):
        # fully-replicated global arrays are materializable from any
        # process (np.asarray reads one local copy) — only genuinely
        # sharded-across-hosts leaves are unsaveable from here
        if (isinstance(leaf, jax.Array) and not leaf.is_fully_addressable
                and not leaf.is_fully_replicated):
            raise ValueError(
                f"checkpoint.save: leaf {p} is a global jax.Array that is "
                f"not fully addressable from this process (a multi-process "
                f"mesh shards it across hosts, so np.asarray cannot "
                f"materialize it here).  This module is single-host only; "
                f"per-process sharded checkpointing is the ROADMAP "
                f"'elastic multi-host' item.  Until then: save replicated "
                f"state (params/opt_state placed with P()) from the "
                f"coordinator only, or gather the array to every host "
                f"before saving.")
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    manifest = {
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "metadata": metadata or {},
    }
    with open(_manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=2)


def restore(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (leaf count, treedef
    fingerprint, per-leaf shapes and dtypes validated — module
    docstring)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    t_paths, t_leaves, treedef = _leaf_paths(template)
    with open(_manifest_path(path)) as f:
        manifest = json.load(f)
    if manifest["n_leaves"] != len(t_leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, template has "
            f"{len(t_leaves)}")
    if manifest["treedef"] != str(treedef):
        raise ValueError(
            f"checkpoint tree structure differs from template — same leaf "
            f"count but different treedef, so leaves would restore into "
            f"the wrong slots.\n  stored:   {manifest['treedef']}\n"
            f"  template: {treedef}")
    leaves = []
    for i, (p, tl) in enumerate(zip(t_paths, t_leaves)):
        arr = npz[f"leaf_{i}"]
        if hasattr(tl, "shape") and tuple(arr.shape) != tuple(tl.shape):
            raise ValueError(
                f"leaf {p} (index {i}): checkpoint shape {arr.shape} != "
                f"template {tuple(tl.shape)}")
        if hasattr(tl, "dtype") and arr.dtype != np.dtype(tl.dtype):
            raise ValueError(
                f"leaf {p} (index {i}): checkpoint dtype {arr.dtype} != "
                f"template {np.dtype(tl.dtype)} — a silent cast here "
                f"would corrupt training state (e.g. int step counters "
                f"restored as floats)")
        leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(_manifest_path(path)) as f:
        return json.load(f)["metadata"]


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"
