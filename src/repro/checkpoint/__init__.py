"""Checkpointing: flat .npz per pytree + JSON manifest (no orbax offline).

Handles arbitrary registered-dataclass pytrees (TrainState, ParamLeaf
trees, caches) by saving leaves keyed by their flattened index alongside a
treedef fingerprint; restore validates structure against a template from
the same code version.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def save(path: str, tree: Any, metadata: dict | None = None) -> None:
    leaves, treedef = jax.tree.flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays = {f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)}
    np.savez(path if path.endswith(".npz") else path + ".npz", **arrays)
    manifest = {
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "metadata": metadata or {},
    }
    with open(_manifest_path(path), "w") as f:
        json.dump(manifest, f, indent=2)


def restore(path: str, template: Any) -> Any:
    """Restore into the structure of ``template`` (shapes validated)."""
    npz = np.load(path if path.endswith(".npz") else path + ".npz")
    t_leaves, treedef = jax.tree.flatten(template)
    with open(_manifest_path(path)) as f:
        manifest = json.load(f)
    if manifest["n_leaves"] != len(t_leaves):
        raise ValueError(
            f"checkpoint has {manifest['n_leaves']} leaves, template has "
            f"{len(t_leaves)}")
    leaves = []
    for i, tl in enumerate(t_leaves):
        arr = npz[f"leaf_{i}"]
        if hasattr(tl, "shape") and tuple(arr.shape) != tuple(tl.shape):
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != "
                             f"template {tl.shape}")
        leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, leaves)


def load_metadata(path: str) -> dict:
    with open(_manifest_path(path)) as f:
        return json.load(f)["metadata"]


def _manifest_path(path: str) -> str:
    base = path[:-4] if path.endswith(".npz") else path
    return base + ".manifest.json"
