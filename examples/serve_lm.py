"""Batched serving demo: prefill + token-by-token decode with per-family
caches (dense KV / MLA latent / SSM state / sliding-window ring).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-1.3b-reduced
    PYTHONPATH=src python examples/serve_lm.py --arch gemma2-9b-reduced \
        --long-context
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import transformer as T
from repro.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-reduced")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--long-context", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    print(f"{cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"family={cfg.arch_type}")
    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    prefix = None
    if cfg.modality:
        prefix = rng.normal(size=(args.batch, cfg.num_prefix_embeddings,
                                  cfg.d_model)).astype(np.float32)

    t0 = time.perf_counter()
    result = generate(params, cfg, prompt, args.gen, prefix=prefix,
                      temperature=args.temperature,
                      long_context=args.long_context)
    dt = time.perf_counter() - t0
    toks = args.batch * args.gen
    print(f"generated {toks} tokens in {dt:.1f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    print("sample continuation token ids:",
          result.tokens[0, args.prompt_len:][:16].tolist())


if __name__ == "__main__":
    main()
