"""Quickstart: NeutronTP GNN tensor parallelism in ~60 lines.

Runs on however many devices are visible (1 is fine — the collectives
degenerate); for a real multi-worker run:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import optim
from repro.core import decouple as D
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.runtime import tp_mesh


def main():
    n_workers = len(jax.devices())
    print(f"workers: {n_workers}")

    # 1. a synthetic power-law graph with planted communities
    data = sbm_power_law(n=4096, num_classes=8, feat_dim=64,
                         avg_degree=12, seed=0)
    print(f"graph: {data.graph.n} vertices, {data.graph.e} edges")

    # 2. NeutronTP bundle: graph replicated, features dim-shardable,
    #    chunk schedule + per-chunk communication plan precomputed
    bundle = D.prepare_bundle(data, n_workers=n_workers, n_chunks=4)

    # 3. a decoupled 2-layer GCN (paper §4.1) trained with tensor
    #    parallelism: L NN rounds → split → L aggregations → gather
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=64,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(1e-2)
    mesh = tp_mesh(n_workers)
    train_step, evaluate = D.make_tp_train_fns(
        cfg, bundle, mesh, opt, mode="decoupled_pipelined")

    opt_state = opt.init(params)
    for epoch in range(1, 51):
        params, opt_state, loss = train_step(params, opt_state)
        if epoch % 10 == 0:
            _, val_acc = evaluate(params, "val")
            print(f"epoch {epoch:3d}  loss {float(loss):.4f}  "
                  f"val acc {float(val_acc):.3f}")
    _, test_acc = evaluate(params, "test")
    print(f"test accuracy: {float(test_acc):.3f}")


if __name__ == "__main__":
    main()
