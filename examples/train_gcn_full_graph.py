"""End-to-end driver: distributed full-graph GCN/GAT training (the paper's
workload) with NeutronTP tensor parallelism on 8 workers.

    PYTHONPATH=src python examples/train_gcn_full_graph.py \
        [--model gcn] [--n 20000] [--epochs 100] [--mode decoupled_pipelined]

Trains on a Reddit-like synthetic graph (power-law SBM, 602-d features,
41 classes — Table 1 proportions), logs epoch time + accuracy, saves and
restores a checkpoint, and reports the per-worker balance property.
"""
import os

if "--single-device" not in __import__("sys").argv:
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import checkpoint, optim  # noqa: E402
from repro.core import decouple as D  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.runtime import tp_mesh  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="gcn", choices=["gcn", "gat",
                                                       "sage", "gin"])
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--feat-dim", type=int, default=302)
    ap.add_argument("--classes", type=int, default=41)
    ap.add_argument("--hidden", type=int, default=128)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=100)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--chunks", type=int, default=4)
    ap.add_argument("--mode", default="decoupled_pipelined",
                    choices=["decoupled", "decoupled_pipelined", "naive"])
    ap.add_argument("--ckpt", default="results/gcn_full_graph")
    ap.add_argument("--single-device", action="store_true")
    args = ap.parse_args()

    k = len(jax.devices())
    print(f"devices: {k}  mode: {args.mode}")
    data = sbm_power_law(n=args.n, num_classes=args.classes,
                         feat_dim=args.feat_dim, avg_degree=12, seed=0)
    print(f"graph: V={data.graph.n} E={data.graph.e} "
          f"ftr={args.feat_dim} classes={args.classes}")

    bundle = D.prepare_bundle(data, n_workers=k, n_chunks=args.chunks)
    cfg = D.padded_gnn_config(data, bundle, model=args.model,
                              hidden_dim=args.hidden,
                              num_layers=args.layers)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(args.lr, weight_decay=5e-4)
    mesh = tp_mesh(k)
    train_step, evaluate = D.make_tp_train_fns(cfg, bundle, mesh, opt,
                                               mode=args.mode)
    opt_state = opt.init(params)

    # the paper's load-balance property, by construction:
    print(f"per-worker aggregation load: E×D/N = "
          f"{data.graph.e}×{cfg.hidden_dim}/{k} on every worker "
          f"(imbalance 1.00)")

    times = []
    for epoch in range(1, args.epochs + 1):
        t0 = time.perf_counter()
        params, opt_state, loss = train_step(params, opt_state)
        jax.block_until_ready(loss)
        times.append(time.perf_counter() - t0)
        if epoch % max(1, args.epochs // 10) == 0:
            _, va = evaluate(params, "val")
            print(f"epoch {epoch:4d}  loss {float(loss):.4f}  "
                  f"val {float(va):.3f}  {times[-1]*1e3:.0f} ms/epoch")

    _, test_acc = evaluate(params, "test")
    print(f"test accuracy: {float(test_acc):.3f}  "
          f"median epoch: {np.median(times)*1e3:.0f} ms")

    checkpoint.save(args.ckpt, params,
                    metadata={"model": args.model,
                              "test_acc": float(test_acc)})
    restored = checkpoint.restore(args.ckpt, params)
    _, acc2 = evaluate(restored, "test")
    assert abs(float(acc2) - float(test_acc)) < 1e-6
    print(f"checkpoint round-trip OK → {args.ckpt}.npz")


if __name__ == "__main__":
    main()
