"""Train a decoder LM with the paper's technique generalized to sequence
models (DESIGN §3): token-sharded NN phase / head-sharded mixing phase,
transitions as all-to-alls — the `neutron_tp` strategy.

    PYTHONPATH=src python examples/train_lm_neutron_tp.py [--steps 100]
    PYTHONPATH=src python examples/train_lm_neutron_tp.py --full  # ~100M

Runs on 8 forced host devices: mesh (data=2, model=4).
"""
import os
import sys

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro import optim  # noqa: E402
from repro.configs.base import ArchConfig  # noqa: E402
from repro.data import SyntheticLM  # noqa: E402
from repro.launch.mesh import make_host_mesh  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.sharding.specs import Sharder, ShardingRules  # noqa: E402
from repro.train import init_train_state, make_train_step  # noqa: E402


def small_cfg(full: bool) -> ArchConfig:
    if full:  # ~100M params
        return ArchConfig(name="lm-100m", arch_type="dense", num_layers=10,
                          d_model=640, num_heads=8, num_kv_heads=4,
                          d_ff=2560, vocab_size=32768, dtype="float32")
    return ArchConfig(name="lm-tiny", arch_type="dense", num_layers=4,
                      d_model=256, num_heads=8, num_kv_heads=4,
                      d_ff=1024, vocab_size=4096, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strategy", default="neutron_tp",
                    choices=["neutron_tp", "megatron", "dp"])
    args = ap.parse_args()

    cfg = small_cfg(args.full)
    mesh = make_host_mesh(model=4, data=2)
    rules = ShardingRules(strategy=args.strategy, data_axes=("data",))
    sharder = Sharder(mesh=mesh, rules=rules)
    print(f"arch {cfg.name}: ~{cfg.param_count()/1e6:.0f}M params, "
          f"mesh {dict(mesh.shape)}, strategy {args.strategy}")

    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(3e-4 if args.full else 1e-3)
    state = init_train_state(params, opt)
    with mesh:
        step = make_train_step(cfg, opt, sharder, donate=False)
        data = SyntheticLM(cfg.vocab_size)
        it = data.batches(args.batch, args.seq, cfg)
        t_hist = []
        for i in range(1, args.steps + 1):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            t0 = time.perf_counter()
            state, m = step(state, batch)
            jax.block_until_ready(m["loss"])
            t_hist.append(time.perf_counter() - t0)
            if i % max(1, args.steps // 10) == 0:
                tok_s = args.batch * args.seq / np.median(t_hist[-10:])
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"{tok_s:,.0f} tok/s")
    print(f"final loss {float(m['loss']):.4f} "
          f"(random = {np.log(cfg.vocab_size):.2f})")


if __name__ == "__main__":
    main()
