"""Unit tests for the distributed-discipline AST linter
(:mod:`repro.analysis.lint`) — rule-by-rule on in-memory sources, plus
the resolution machinery (aliases, relative imports, suppression) the
rules share.  The fixture-driven CLI/exit-code contract lives in
tests/test_collectives_chokepoint.py.
"""
import os
import textwrap

from repro.analysis import lint


def _rules(text, path="src/repro/gnn/x.py", module=None):
    src = textwrap.dedent(text)
    return sorted({f.rule for f in lint.lint_text(src, path, module)})


def _lint(text, path="src/repro/gnn/x.py", module=None):
    return lint.lint_text(textwrap.dedent(text), path, module)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_complete_and_unique():
    ids = [r.id for r in lint.all_rules()]
    assert ids == sorted(ids) and len(ids) == len(set(ids))
    assert {"RT001", "RT002", "RT003", "RT004", "RT005",
            "W100"} <= set(ids)
    for r in lint.all_rules():
        assert r.severity in ("error", "warn")
        assert r.invariant


# ---------------------------------------------------------------------------
# RT001 — every spelling resolves
# ---------------------------------------------------------------------------

def test_rt001_from_import():
    assert _rules("""
        from jax.lax import all_to_all
        def f(x, a):
            return all_to_all(x, a, split_axis=0, concat_axis=0)
    """) == ["RT001"]


def test_rt001_alias_import():
    assert _rules("""
        import jax.lax as _l
        def f(x, a):
            return _l.psum(x, a)
    """) == ["RT001"]


def test_rt001_attribute_chain():
    assert _rules("""
        import jax
        def f(x, a):
            return jax.lax.psum(x, a)
    """) == ["RT001"]


def test_rt001_from_jax_import_lax():
    assert _rules("""
        from jax import lax
        def f(x, a):
            return lax.ppermute(x, a, perm=[(0, 1)])
    """) == ["RT001"]


def test_rt001_allowed_in_chokepoint_module():
    assert _rules("""
        import jax
        def f(x, a):
            return jax.lax.psum(x, a)
    """, path="src/repro/runtime/collectives.py") == []


def test_rt001_ignores_non_collective_lax():
    assert _rules("""
        import jax
        def f(x):
            return jax.lax.scan(lambda c, _: (c, None), x, None, length=2)
    """) == []


def test_rt001_ignores_unimported_names():
    # a local helper named psum is not jax.lax.psum
    assert _rules("""
        def psum(x, a):
            return x
        def f(x, a):
            return psum(x, a)
    """) == []


def test_rt001_runtime_collectives_relative_import_ok():
    # engine code importing the *wrapper* is the sanctioned spelling
    assert _rules("""
        from repro.runtime import collectives as C
        def f(x, a):
            return C.psum(x, a)
    """) == []


# ---------------------------------------------------------------------------
# RT002
# ---------------------------------------------------------------------------

def test_rt002_from_import():
    assert _rules("""
        from jax.experimental.shard_map import shard_map
    """) == ["RT002"]


def test_rt002_attribute_use():
    assert _rules("""
        import jax
        def f(g, mesh, s):
            return jax.shard_map(g, mesh=mesh, in_specs=s, out_specs=s)
    """) == ["RT002"]


def test_rt002_allowed_under_runtime():
    assert _rules("""
        from jax.experimental.shard_map import shard_map
    """, path="src/repro/runtime/smap.py") == []


# ---------------------------------------------------------------------------
# RT003 — explicit mirror= in engine code
# ---------------------------------------------------------------------------

_RT003_SRC = """
    from repro.runtime import collectives as C
    def f(h, a):
        return C.all_gather(h, a{suffix})
"""


def test_rt003_missing_mirror_flagged():
    assert "RT003" in _rules(_RT003_SRC.format(suffix=""),
                             path="src/repro/core/x.py")


def test_rt003_explicit_mirror_ok():
    for suffix in (", mirror=True", ", mirror=False"):
        assert _rules(_RT003_SRC.format(suffix=suffix),
                      path="src/repro/core/x.py") == []


def test_rt003_psum_exempt():
    # psum's documented convention is mirror=False; no declaration needed
    assert _rules("""
        from repro.runtime import collectives as C
        def f(h, a):
            return C.psum(h, a)
    """, path="src/repro/core/x.py") == []


def test_rt003_only_engine_segments():
    # the runtime layer owns the defaults; scripts aren't engine code
    for path in ("src/repro/runtime/collectives.py",
                 "src/repro/launch/dryrun.py"):
        assert _rules(_RT003_SRC.format(suffix=""), path=path) == []


def test_rt003_relative_import_resolves():
    # `from ..runtime import collectives as C` inside repro.core
    assert "RT003" in _rules("""
        from ..runtime import collectives as C
        def f(h, a):
            return C.replica_gather(h, a)
    """, path="src/repro/core/x.py")


def test_rt003_layout_cast_requires_mirror():
    assert "RT003" in _rules("""
        from repro.runtime.constraint import layout_cast
        def f(h, spec, src):
            return layout_cast(h, spec, src)
    """, path="src/repro/core/x.py")


# ---------------------------------------------------------------------------
# RT004 — loop_scope around communicating loops
# ---------------------------------------------------------------------------

_SCAN_SRC = """
    import jax
    from repro.runtime import collectives as C
    from repro.runtime import telemetry as T
    def f(k, perm, a, n):
        def step(c, _):
            return C.ppermute(c, a, perm=perm, mirror=True), None
        {body}
        return out
"""


def test_rt004_unscoped_scan_flagged():
    src = _SCAN_SRC.format(
        body="out, _ = jax.lax.scan(step, k, None, length=n)")
    assert _rules(src) == ["RT004"]


def test_rt004_scoped_scan_ok():
    src = _SCAN_SRC.format(body=(
        "with T.loop_scope(n):\n"
        "            out, _ = jax.lax.scan(step, k, None, length=n)"))
    assert _rules(src) == []


def test_rt004_checkpoint_wrapper_unwrapped():
    src = _SCAN_SRC.format(body=(
        "out, _ = jax.lax.scan(jax.checkpoint(step), k, None, length=n)"))
    assert _rules(src) == ["RT004"]


def test_rt004_non_communicating_scan_ok():
    assert _rules("""
        import jax
        def f(k, n):
            def step(c, _):
                return c + 1, None
            out, _ = jax.lax.scan(step, k, None, length=n)
            return out
    """) == []


def test_rt004_fori_and_while():
    base = """
        import jax
        from repro.runtime import collectives as C
        def f(k, a):
            def body({args}):
                return C.psum({ret}, a)
            return jax.lax.{fn}
    """
    fori = base.format(args="i, c", ret="c", fn="fori_loop(0, 4, body, k)")
    assert _rules(fori) == ["RT004"]
    wl = base.format(args="c", ret="c",
                     fn="while_loop(lambda c: c.sum() > 0, body, k)")
    assert _rules(wl) == ["RT004"]


def test_rt004_indirect_helper_call():
    # the loop body calls a local fn that communicates — still flagged
    assert _rules("""
        import jax
        from repro.runtime import collectives as C
        def hop(c, a, perm):
            return C.ppermute(c, a, perm=perm, mirror=True)
        def f(k, perm, a, n):
            def step(c, _):
                return hop(c, a, perm), None
            out, _ = jax.lax.scan(step, k, None, length=n)
            return out
    """) == ["RT004"]


# ---------------------------------------------------------------------------
# RT005
# ---------------------------------------------------------------------------

def test_rt005_env_read_spellings():
    for read in ('os.environ["NUM_PROCESSES"]',
                 'os.environ.get("NUM_PROCESSES")',
                 'os.getenv("COORDINATOR_ADDRESS")'):
        assert _rules(f"""
            import os
            def f():
                return {read}
        """) == ["RT005"], read


def test_rt005_initialize_call():
    assert _rules("""
        import jax
        def f():
            jax.distributed.initialize()
    """) == ["RT005"]


def test_rt005_non_contract_key_ok():
    assert _rules("""
        import os
        def f():
            return os.environ.get("XLA_FLAGS")
    """) == []


def test_rt005_writes_are_not_reads():
    # launchers *set* the contract for children; only reads are owned
    assert _rules("""
        import os
        def f():
            os.environ["NUM_PROCESSES"] = "2"
    """) == []


def test_rt005_allowed_in_distributed_module():
    assert _rules("""
        import os
        def f():
            return os.environ.get("NUM_PROCESSES")
    """, path="src/repro/runtime/distributed.py") == []


# ---------------------------------------------------------------------------
# suppression + drivers
# ---------------------------------------------------------------------------

def test_suppression_matching_rule():
    assert _rules("""
        import jax
        def f(x, a):
            return jax.lax.psum(x, a)  # lint-ok: RT001 negative test
    """) == []


def test_suppression_other_rule_does_not_hide():
    assert _rules("""
        import jax
        def f(x, a):
            return jax.lax.psum(x, a)  # lint-ok: RT005
    """) == ["RT001"]


def test_suppression_bare_comment():
    assert _rules("""
        import jax
        def f(x, a):
            return jax.lax.psum(x, a)  # lint-ok
    """) == []


def test_module_name_for():
    f = lint.module_name_for
    assert f("src/repro/core/tp.py") == "repro.core.tp"
    assert f("src/repro/core/__init__.py") == "repro.core"
    assert f("scripts/lint_dist.py") is None


def test_lint_paths_syntax_error_is_a_finding(tmp_path):
    bad = tmp_path / "broken.py"
    bad.write_text("def f(:\n")
    ok = tmp_path / "ok.py"
    ok.write_text("import jax\n\n\ndef f(x, a):\n"
                  "    return jax.lax.psum(x, a)\n")
    findings = lint.lint_paths([str(tmp_path)])
    rules = {f.rule for f in findings}
    assert "E999" in rules          # reported, not raised
    assert "RT001" in rules         # and the rest still linted


def test_w100_reports_unreferenced_stub(tmp_path):
    src = tmp_path / "src" / "repro"
    cfg = src / "configs"
    os.makedirs(cfg)
    for d in (src, cfg):
        (d / "__init__.py").write_text("")
    (cfg / "dead_model.py").write_text("CONFIG = {}\n")
    (cfg / "live_model.py").write_text("CONFIG = {}\n")
    (src / "user.py").write_text(
        "from repro.configs import live_model  # noqa: F401\n")
    findings = [f for f in lint.lint_paths([str(src)]) if f.rule == "W100"]
    assert [os.path.basename(f.path) for f in findings] == ["dead_model.py"]
    assert all(f.severity == "warn" for f in findings)


def test_finding_format_and_dict():
    f = lint.LintFinding("RT001", "a.py", 3, 7, "msg")
    assert f.format() == "a.py:3:7: RT001 [error] msg"
    assert f.as_dict()["severity"] == "error"
