"""8-device out-of-core streaming equivalence check (repro.core.stream).

The streamed decoupled epoch — host-resident feature store, per-chunk
plan staging through the double-buffered H2D prefetcher, donated device
buffers — must be *indistinguishable on the wire and in the math* from
the in-memory decoupled epoch it replaces:

* losses AND grads match ``repro.core.decouple.make_tp_value_and_grad``
  (mode=decoupled, same backend) to atol 1e-5, for every streaming mode
  × engine backend × aggregation backend combination;
* the collective CommLedger (all_to_all / psum / transition entries,
  i.e. everything except the ``h2d`` column) is **byte-identical** to
  the unpipelined in-memory ledger — streaming moves host↔device
  traffic, never worker↔worker traffic;
* the measured ``h2d`` column of a *post-warmup* epoch equals the
  analytic :func:`repro.core.stream.expected_h2d_bytes` exactly
  (collectives are trace-time and already cached on epoch 2, so the
  second-epoch ledger isolates the per-execution H2D records);
* the :func:`repro.core.stream.device_resident_bytes` staging footprint
  is two items deep and independent of the store size.

``--ci-smoke`` runs the subset wired into scripts/ci.sh
(segment+blocksparse × both engine backends × decoupled).  Run as a
child with --xla_force_host_platform_device_count=8.
"""
import os
import sys

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import decouple as D  # noqa: E402
from repro.core import stream as ST  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.runtime import collect_comm, tp_mesh  # noqa: E402

assert len(jax.devices()) == 8

SMOKE = "--ci-smoke" in sys.argv[1:]
AGGS = ("segment", "blocksparse") if SMOKE else \
    ("segment", "blocksparse", "dense")
MODES = ("decoupled",) if SMOKE else ST.STREAM_MODES
BACKENDS = ("explicit", "constraint")
ATOL = 1e-5

data = sbm_power_law(n=616, num_classes=5, feat_dim=24, avg_degree=8,
                     seed=0)
mesh = tp_mesh(8)


def tree_max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.abs(np.asarray(x) - np.asarray(y)).max()),
        a, b)))


def split_ledger(led):
    """(collective entries, total h2d payload bytes) of a ledger dict."""
    d = led.as_dict()
    coll = {k: v for k, v in d.items() if not k.startswith("h2d|")}
    h2d = sum(v["payload_bytes"] for k, v in d.items()
              if k.startswith("h2d|"))
    return coll, h2d


# host-side stream bundles (n_stripes defaults to n_chunks → identical
# padding to the in-memory prepare_bundle below, so epochs are
# bit-comparable) + one in-memory reference bundle per epoch shape
bundles = {agg: ST.prepare_stream_bundle(data, mesh=mesh, n_chunks=4,
                                         agg=agg, agg_block_size=32)
           for agg in AGGS}
sb0 = bundles["segment"]
cfg = ST.stream_gnn_config(data, sb0, model="gcn", hidden_dim=16,
                           num_layers=2, gamma=0.7)
params = M.init_params(jax.random.PRNGKey(1), cfg)
ref_bundle = D.prepare_bundle(data, n_workers=8, n_chunks=4)
assert ref_bundle.graph.n_padded == sb0.n_padded, \
    "stream/in-memory padding diverged — epochs are no longer comparable"

# footprint contract: the staged double buffer is depth items, not O(V)
foot = ST.device_resident_bytes(sb0, cfg)
assert foot["staged_stripe_bytes"] == 2 * sb0.store.stripe_nbytes
assert foot["staged_stripe_bytes"] * sb0.n_stripes == 2 * sb0.store.nbytes

for backend in BACKENDS:
    ref_vg = D.make_tp_value_and_grad(cfg, ref_bundle, mesh,
                                      mode="decoupled", backend=backend)
    with collect_comm() as led:
        ref_loss, ref_grads = ref_vg(params, ref_bundle.train_mask)
    ref_led, ref_h2d = split_ledger(led)
    assert ref_h2d == 0, "in-memory epoch must not stage host data"
    for agg in AGGS:
        sb = bundles[agg]
        for mode in MODES:
            tag = f"oocstream/{agg}/{backend}/{mode}"
            vg = ST.make_stream_value_and_grad(cfg, sb, mode=mode,
                                               backend=backend)
            with collect_comm() as led:
                loss, grads = vg(params, sb.train_mask)
            coll, h2d = split_ledger(led)
            dl = abs(float(loss) - float(ref_loss))
            dg = tree_max_diff(grads, ref_grads)
            assert dl < ATOL and dg < ATOL, (tag, dl, dg)
            assert coll == ref_led, (
                f"{tag}: collective ledger differs from the in-memory "
                f"decoupled epoch — streaming must not change "
                f"worker↔worker communication\n  streamed: {coll}\n"
                f"  in-mem:   {ref_led}")
            # post-warmup epoch: programs cached → the ledger holds
            # ONLY the per-execution h2d records, which must equal the
            # analytic formula exactly
            with collect_comm() as led2:
                vg(params, sb.train_mask)
            coll2, h2d2 = split_ledger(led2)
            assert coll2 == {}, (tag, "unexpected retrace", coll2)
            expect = ST.expected_h2d_bytes(sb, cfg)
            assert h2d2 == expect, (tag, h2d2, expect)
            assert h2d == h2d2, (tag, "first-epoch h2d differs", h2d)
            print(f"ok {tag}: dloss={dl:.2e} dgrad={dg:.2e} "
                  f"ledger-identical h2d={int(h2d2)}B (analytic exact)")

print("OK check_oocstream")
