"""Multi-process checkpoint.save contract (2 jax.distributed processes).

:func:`repro.checkpoint.save` materializes every leaf with
``np.asarray`` — on a multi-process mesh a host-sharded global
``jax.Array`` cannot be materialized from one process, and before the
guard this crashed deep inside numpy with an opaque RuntimeError.  The
contract pinned here:

* a **sharded** global array (``P(axis)`` across two hosts) is rejected
  eagerly with an actionable ValueError naming the offending leaf path
  and pointing at the ROADMAP 'elastic multi-host' sharded-checkpoint
  item;
* a **fully replicated** global array (``P()`` — params/opt_state as
  every trainer here places them) saves fine from any process: each
  host holds a complete copy, and the restored values round-trip.

Launched by tests/test_checkpoint.py via the multiproc harness
(2 processes × 4 forced devices).
"""
import os
import tempfile

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import checkpoint  # noqa: E402
from repro.runtime import distributed as dist  # noqa: E402
from repro.runtime import tp_mesh  # noqa: E402

assert dist.env_topology().get("num_processes"), \
    "run via harness.run_multiproc(n_processes=2)"
ctx = dist.initialize()          # env contract: COORDINATOR_ADDRESS, ...
assert jax.process_count() == 2
mesh = tp_mesh(jax.device_count())

host = np.arange(jax.device_count() * 3, dtype=np.float32)
sharded = dist.put_global(host, mesh, P("model"))
replicated = dist.put_global(host, mesh, P())
assert not sharded.is_fully_addressable

state = {"w": replicated, "rows": sharded}
tmp = os.path.join(tempfile.gettempdir(),
                   f"ckpt_multiproc_{ctx.process_id}")
try:
    checkpoint.save(tmp, state)
except ValueError as e:
    msg = str(e)
    assert "['rows']" in msg, msg          # names the offending leaf
    assert "elastic multi-host" in msg, msg
    assert "not fully addressable" in msg, msg
else:
    raise AssertionError("save accepted a host-sharded global array")

# replicated-only state saves from every process and round-trips
state = {"w": replicated, "step": jnp.int32(7)}
checkpoint.save(tmp, state, metadata={"who": ctx.process_id})
restored = checkpoint.restore(tmp, state)
np.testing.assert_array_equal(np.asarray(restored["w"]), host)
assert int(restored["step"]) == 7
assert checkpoint.load_metadata(tmp)["who"] == ctx.process_id

print("OK check_checkpoint_multiproc")
