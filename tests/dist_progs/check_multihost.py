"""Multi-host equivalence: 2 processes × 4 fake devices == 1 process × 8.

The same global programs (all four execution modes × both engine
backends, pure TP model=8 and hybrid (data=2, model=4)) must produce
the same losses AND grads whether one process owns all 8 devices or two
``jax.distributed`` processes own 4 each — the launcher converts the
process topology, never the math.

Dual-role program, driven by the harness (tests/dist_progs/harness.py):

* **reference mode** (no ``NUM_PROCESSES`` env; 8 forced devices): the
  PR 3 single-process suite's configurations are evaluated and their
  losses/grads written as JSON to ``$CHECK_MULTIHOST_REF``.
* **distributed mode** (harness env contract set; N×M forced devices):
  ``repro.runtime.distributed.initialize()`` joins the job from the env
  alone, every bundle is committed per-host (``prepare_bundle(...,
  mesh=)``), and every configuration must match the reference file to
  atol 1e-5.  Also exercises the multihost device-accounting error text
  and emits this process's telemetry ledger as a harness JSON verdict
  (merged at the coordinator by tests/test_multihost.py).
* **failure modes** (``$CHECK_MULTIHOST_MODE``): ``unreachable`` and
  ``mismatch`` assert that a bad coordinator address / process id fails
  fast with an actionable error instead of hanging.
"""
import json
import os

MODE = os.environ.get("CHECK_MULTIHOST_MODE", "")

if MODE == "mismatch":
    # topology validation is eager — no sockets, no backend
    from repro.runtime import distributed as dist

    for kwargs, needle in (
            (dict(coordinator_address="127.0.0.1:9", num_processes=2,
                  process_id=7), "process_id=7 out of range"),
            (dict(coordinator_address=None, num_processes=2,
                  process_id=1), "coordinator address"),
            (dict(coordinator_address="nocolon", num_processes=2,
                  process_id=1), "host:port"),
    ):
        try:
            dist.initialize(**kwargs)
        except ValueError as e:
            assert needle in str(e), (needle, str(e))
        else:
            raise AssertionError(f"no error for {kwargs}")
    print("OK check_multihost")
    raise SystemExit(0)

if MODE == "unreachable":
    # a worker pointed at a dead coordinator must fail within the
    # timeout, naming the address and the env contract — never hang
    import time

    from repro.runtime import distributed as dist

    t0 = time.monotonic()
    try:
        dist.initialize(coordinator_address="127.0.0.1:9",
                        num_processes=2, process_id=1, timeout=3)
    except RuntimeError as e:
        msg = str(e)
        assert "127.0.0.1:9" in msg and "NUM_PROCESSES" in msg, msg
        assert time.monotonic() - t0 < 60, "error not within timeout"
    else:
        raise AssertionError("unreachable coordinator did not raise")
    print("OK check_multihost")
    raise SystemExit(0)

from repro.runtime import distributed as dist  # noqa: E402

REF_PATH = os.environ["CHECK_MULTIHOST_REF"]
# env_topology owns the multihost env contract (RT005) — {} means
# single-process
DISTRIBUTED = "num_processes" in dist.env_topology()

if DISTRIBUTED:
    ctx = dist.initialize()          # env contract: COORDINATOR_ADDRESS...
else:
    assert "--xla_force_host_platform_device_count=8" in \
        os.environ.get("XLA_FLAGS", "")
    ctx = None

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro import optim  # noqa: E402
from repro.core import decouple as D  # noqa: E402
from repro.gnn import dp_baseline as DP  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.runtime import collect_comm, hybrid_mesh, tp_mesh  # noqa: E402

assert len(jax.devices()) == 8, jax.devices()
if DISTRIBUTED:
    assert ctx.num_processes == 2 and ctx.local_device_count == 4, ctx
    assert len(jax.local_devices()) == 4

ATOL = 1e-5
TP_MODES = ("decoupled", "decoupled_pipelined", "naive")
BACKENDS = ("explicit", "constraint")

data = sbm_power_law(n=240, num_classes=8, feat_dim=16, avg_degree=8, seed=0)
opt_mesh = (lambda m: m if DISTRIBUTED else None)   # place only multihost


def tp_cases():
    for tag, mesh, mm, dd in (("tp8", tp_mesh(8), 8, 1),
                              ("d2xm4", hybrid_mesh(model=4, data=2), 4, 2)):
        bundle = D.prepare_bundle(data, n_workers=mm, n_chunks=2,
                                  n_replicas=dd, mesh=opt_mesh(mesh))
        cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=16,
                                  num_layers=2)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        if DISTRIBUTED:
            params = dist.replicate(params, mesh)
        for mode in TP_MODES:
            for backend in BACKENDS:
                # the jitted value-and-grad handle: on a multi-process
                # mesh every collective must live in ONE in-flight
                # executable (eager autodiff's separate fwd/bwd
                # executables race their collectives on the shared
                # gloo transport — see make_tp_value_and_grad)
                fn = D.make_tp_value_and_grad(cfg, bundle, mesh,
                                              mode=mode, backend=backend)
                yield (f"{tag}:{mode}:{backend}", fn, params,
                       bundle.train_mask)


def dp_cases():
    cfg = M.GNNConfig(model="gcn", in_dim=16, hidden_dim=16, num_classes=8,
                      num_layers=2, decoupled=False)
    params0 = M.init_params(jax.random.PRNGKey(0), cfg)
    for tag, mesh, kk, dd in (("tp8", tp_mesh(8), 8, 1),
                              ("d2xm4", hybrid_mesh(model=4, data=2), 4, 2)):
        bundle = DP.prepare_dp_bundle(data, k=kk, n_replicas=dd,
                                      mesh=opt_mesh(mesh))
        params = dist.replicate(params0, mesh) if DISTRIBUTED else params0
        for backend in BACKENDS:
            fn = DP.make_dp_value_and_grad(cfg, bundle, mesh,
                                           backend=backend)
            yield f"{tag}:dp:{backend}", fn, params, bundle.train_mask


def evaluate(fn, params, mask):
    loss, grads = fn(params, mask)
    leaves = [np.asarray(g) for g in jax.tree.leaves(grads)]
    return float(loss), leaves


if not DISTRIBUTED:
    # ---- reference: the PR 3 single-process suite's values ----
    ref = {}
    for key, fn, params, mask in list(tp_cases()) + list(dp_cases()):
        loss, leaves = evaluate(fn, params, mask)
        ref[key] = {"loss": loss, "grads": [g.tolist() for g in leaves]}
        print(f"ref {key} loss={loss:.6f}", flush=True)
    with open(REF_PATH, "w") as f:
        json.dump(ref, f)
    print("OK check_multihost")
    raise SystemExit(0)

# ---- distributed mode: 2 × 4 must reproduce the reference ----
with open(REF_PATH) as f:
    ref = json.load(f)

for key, fn, params, mask in list(tp_cases()) + list(dp_cases()):
    loss, leaves = evaluate(fn, params, mask)
    want = ref[key]
    dl = abs(loss - want["loss"])
    dg = max(float(np.abs(g - np.asarray(w)).max())
             for g, w in zip(leaves, want["grads"]))
    assert len(leaves) == len(want["grads"])
    assert dl < ATOL and dg < ATOL, (key, dl, dg)
    if ctx.is_coordinator:
        print(f"match {key} dloss={dl:.2e} dgrad={dg:.2e}", flush=True)

# ---- device-accounting errors name the per-process topology ----
try:
    hybrid_mesh(model=16)
except ValueError as e:
    msg = str(e)
    assert "2 processes" in msg and "4 local devices" in msg, msg
else:
    raise AssertionError("over-subscribed mesh did not raise")
try:
    tp_mesh(16)
except ValueError as e:
    assert "2 processes" in str(e) and "4 local devices" in str(e), str(e)
else:
    raise AssertionError("tp_mesh(16) did not raise")

# ---- a few real train steps make progress through the full stack ----
mesh = tp_mesh(8)
bundle = D.prepare_bundle(data, n_chunks=2, mesh=mesh)
cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=16,
                          num_layers=2)
opt = optim.adamw(1e-2)
params = dist.replicate(M.init_params(jax.random.PRNGKey(0), cfg), mesh)
step, ev = D.make_tp_train_fns(cfg, bundle, mesh, opt, mode="decoupled",
                               backend="explicit")
p, o = params, dist.replicate(opt.init(params), mesh)
# per-process trace-time ledger, merged at the coordinator by the test
with collect_comm() as ledger:
    lowered = step.lower(p, o)
losses = []
for _ in range(5):
    p, o, loss = step(p, o)
    losses.append(float(loss))
assert losses[-1] < losses[0], losses
_, acc = ev(p, "train")
assert 0.0 <= float(acc) <= 1.0

print("VERDICT " + json.dumps({
    "process_id": ctx.process_id,
    "ledger": ledger.as_dict(),
    "losses": losses,
}), flush=True)
# synchronize exits: a process tearing down the coordination service
# while a peer still talks to it turns a clean pass into an abort
from jax.experimental import multihost_utils  # noqa: E402

multihost_utils.sync_global_devices("check_multihost done")
print("OK check_multihost")
