"""8-device check: ExplicitSharder (shard_map all-to-all mixing + EP MoE)
must be numerically equivalent to the constraint-based Sharder AND to the
single-device no-shard oracle — forward and gradients.

Covers both GQA regimes:
  * kv_heads % n == 0  → k/v also travel by all-to-all
  * kv_heads % n != 0  → k/v all-gather + static kv-group slice
and the expert-parallel MoE dispatch (E % n == 0).
"""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "run via test_distributed.py"

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.sharding.explicit import ExplicitSharder
from repro.sharding.specs import Sharder, ShardingRules

DATA, MODEL = 2, 4


def make_cfg(**kw):
    base = dict(
        name="tiny", arch_type="dense", num_layers=2, d_model=64,
        num_heads=8, num_kv_heads=8, head_dim=8, d_ff=128, vocab_size=64,
        act="silu", dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def run_case(name, cfg, batch=2, seq=32):
    mesh = make_host_mesh(model=MODEL, data=DATA)
    rules = ShardingRules(strategy="neutron_tp", data_axes=("data",))
    plain = Sharder(mesh=mesh, rules=rules)
    explicit = ExplicitSharder(mesh=mesh, rules=rules)

    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (batch, seq), 0,
                                cfg.vocab_size)
    targets = jnp.roll(tokens, -1, axis=1)

    def loss(params, shard):
        logits, aux = T.forward(params, cfg, tokens, shard=shard,
                                remat=False)
        return T.lm_loss(logits, targets) + 0.01 * aux

    with mesh:
        l_oracle = jax.jit(lambda p: loss(p, T.no_shard))(params)
        l_plain = jax.jit(lambda p: loss(p, plain))(params)
        l_expl = jax.jit(lambda p: loss(p, explicit))(params)
        g_plain = jax.jit(jax.grad(lambda p: loss(p, plain)))(params)
        g_expl = jax.jit(jax.grad(lambda p: loss(p, explicit)))(params)

    np.testing.assert_allclose(float(l_plain), float(l_oracle),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(float(l_expl), float(l_oracle),
                               rtol=2e-5, atol=2e-5)
    flat_p, _ = jax.tree.flatten(jax.tree.map(
        lambda x: np.asarray(x, np.float64), g_plain))
    flat_e, _ = jax.tree.flatten(jax.tree.map(
        lambda x: np.asarray(x, np.float64), g_expl))
    for a, b in zip(flat_p, flat_e):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-5), name
    print(f"  case {name}: loss {float(l_expl):.5f} == oracle ok")


def main():
    assert jax.device_count() == 8
    # kv % n == 0: a2a for k/v too
    run_case("gqa-kv-a2a", make_cfg())
    # kv % n != 0: all-gather + kv-group slice (hq_l=2 divides g=4)
    run_case("gqa-kv-gather", make_cfg(num_kv_heads=2))
    # blockwise attention inside the shard_map mixing phase
    run_case("gqa-blockwise", make_cfg(attn_impl="blockwise",
                                       attn_block_q=8, attn_block_kv=16))
    # heads (6) don't divide model axis (4) → ring attention path
    run_case("ring-attn", make_cfg(num_heads=6, num_kv_heads=6,
                                   d_model=48, head_dim=8))
    # ring + GQA + sliding window (gemma2-style local/global alternation)
    run_case("ring-gqa-window", make_cfg(num_heads=6, num_kv_heads=2,
                                         d_model=48, head_dim=8,
                                         sliding_window=24,
                                         local_global_pattern=True))
    # EP MoE: 8 experts over model=4 → 2 local experts; cf large → no drop
    run_case("moe-ep", make_cfg(
        arch_type="moe", moe=True, num_experts=8, num_experts_per_tok=2,
        num_shared_experts=1, moe_d_ff=32, moe_capacity_factor=8.0,
        first_dense_layers=0))
    print("OK check_explicit_collectives")


if __name__ == "__main__":
    main()
