"""8-worker split/gather round-trip through repro.runtime (real all-to-alls).

Absorbs the old test_tp_engine.py::test_split_gather_roundtrip, upgraded
from the N=1 degenerate collective to a forced 8-host-device mesh.
"""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", ""), "run via test_runtime.py"

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.core import tp  # noqa: E402
from repro.runtime import collectives as C  # noqa: E402
from repro.runtime import engine, tp_mesh  # noqa: E402

assert len(jax.devices()) == 8

mesh = tp_mesh(8)
assert mesh.size == 8
mesh.validate_divisible(n_vertices=64, dim=16)

h = jnp.arange(64 * 16, dtype=jnp.float32).reshape(64, 16)

# split then gather must be the identity on the vertex-sharded layout
f = engine(lambda x: tp.gather(tp.split(x)), mesh=mesh,
           in_specs=P("model", None), out_specs=P("model", None))
np.testing.assert_array_equal(f(h), h)

# split really lands the dim-sharded layout: worker i holds h[:, i*D/8 ...]
g = engine(lambda x: tp.split(x)[None], mesh=mesh,
           in_specs=P("model", None), out_specs=P("model", None, None))
z = np.asarray(g(h))                       # (8, 64, 2) — one slice per worker
for i in range(8):
    np.testing.assert_array_equal(z[i], np.asarray(h)[:, i * 2:(i + 1) * 2])

# collectives wrappers agree with the mesh's static degree
sizes = engine(
    lambda: (C.axis_size("model") * jnp.ones(()),
             C.axis_index("model")[None].astype(jnp.float32)),
    mesh=mesh, in_specs=(), out_specs=(P(), P("model")))()
assert float(sizes[0]) == 8.0
np.testing.assert_array_equal(np.asarray(sizes[1]), np.arange(8.0))

print("OK check_runtime_roundtrip")
