"""8-worker TP engine == single-device decoupled reference (run as child
process with --xla_force_host_platform_device_count=8)."""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import optim  # noqa: E402
from repro.core import decouple as D  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.runtime import engine, tp_mesh  # noqa: E402

assert len(jax.devices()) == 8

data = sbm_power_law(n=616, num_classes=5, feat_dim=24, avg_degree=8, seed=0)
bundle = D.prepare_bundle(data, n_workers=8, n_chunks=4)
mesh = tp_mesh(8)
g = bundle.graph
n = data.graph.n

for model in ("gcn", "gat"):
    for pipelined in (False, True):
        cfg = D.padded_gnn_config(data, bundle, model=model, hidden_dim=32,
                                  num_layers=3)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        ref = M.decoupled_forward(params, cfg, g.edges, bundle.features)
        f = engine(
            lambda p, gr, x, c=cfg, pl=pipelined:
                D.tp_decoupled_forward(p, c, gr, x, pipelined=pl),
            mesh=mesh, in_specs=(P(), P(), P("model", None)),
            out_specs=P("model", None))
        out = f(params, g, bundle.features)
        err = float(jnp.abs(ref[:n] - out[:n]).max())
        assert err < 1e-4, (model, pipelined, err)

# naive (coupled) TP vs coupled reference
cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=32,
                          num_layers=2)
cfg_ref = M.GNNConfig(**{**cfg.__dict__, "decoupled": False})
params = M.init_params(jax.random.PRNGKey(2), cfg)
ref = M.coupled_forward(params, cfg_ref, g.edges, bundle.features)
f = engine(lambda p, gr, x: D.tp_naive_forward(p, cfg, gr, x),
           mesh=mesh, in_specs=(P(), P(), P("model", None)),
           out_specs=P("model", None))
out = f(params, g, bundle.features)
err = float(jnp.abs(ref[:n] - out[:n]).max())
assert err < 1e-4, ("naive", err)

# training converges under real 8-way collectives
opt = optim.adamw(1e-2)
step, ev = D.make_tp_train_fns(cfg, bundle, mesh, opt,
                               mode="decoupled_pipelined")
p, o = params, opt.init(params)
for _ in range(25):
    p, o, loss = step(p, o)
_, acc = ev(p, "test")
assert float(acc) > 0.8, float(acc)
print("OK check_tp_equivalence")
