"""Reusable multi-process launcher for the dist_prog checks.

One function, :func:`run_multiproc`, launches a ``tests/dist_progs``
program as **N coordinator+worker subprocesses** with pinned
``XLA_FLAGS`` (M forced host devices each) and the
``runtime.distributed`` env contract (``COORDINATOR_ADDRESS`` on a
fresh localhost port, ``NUM_PROCESSES``, per-child ``PROCESS_ID``) —
the supported no-cluster CI topology of
:mod:`repro.runtime.distributed`.  It

* collects each process's stdout/stderr and any **JSON verdicts** the
  program emitted (lines of the form ``VERDICT {...}`` — e.g. the
  per-process telemetry ledgers that test_multihost merges at the
  coordinator);
* kills stragglers as soon as any process fails (a dead peer leaves
  the others blocked in a gloo collective forever — first failure wins,
  the rest get SIGTERM then SIGKILL);
* enforces a **hard wall-clock timeout** on the whole group, so a hung
  barrier (unreachable coordinator, mismatched ``NUM_PROCESSES``) can
  never hang the test suite past it.

``conftest.run_dist_prog`` is the N=1 case of this launcher (no
distributed env, 8 forced devices): the pre-existing single-process
checks (check_hybrid_mesh, check_telemetry, ...) run under it
unmodified.
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import socket
import subprocess
import sys
import tempfile
import time

PROGS = os.path.dirname(os.path.abspath(__file__))
SRC = os.path.abspath(os.path.join(PROGS, "..", "..", "src"))

#: Prefix a dist prog uses to hand a JSON verdict back to the harness.
VERDICT_PREFIX = "VERDICT "

#: The default forced device count of the single-process checks (the one
#: place the number 8 is spelled — conftest re-exports it).
DEFAULT_DEVICES = 8


def xla_flags(devices: int) -> str:
    return f"--xla_force_host_platform_device_count={devices}"


def free_port() -> int:
    """A currently-free localhost TCP port for the coordinator."""
    with socket.socket(socket.AF_INET, socket.SOCK_STREAM) as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@dataclasses.dataclass
class ProcResult:
    """Outcome of one process of a :func:`run_multiproc` group."""

    process_id: int
    #: Exit status; killed stragglers record the signal (-15/-9), so
    #: after run_multiproc's final kill this is never None.
    returncode: int | None
    stdout: str
    stderr: str

    @property
    def verdicts(self) -> list[dict]:
        """JSON verdicts the program emitted (``VERDICT {...}`` lines)."""
        out = []
        for line in self.stdout.splitlines():
            if line.startswith(VERDICT_PREFIX):
                out.append(json.loads(line[len(VERDICT_PREFIX):]))
        return out

    def summary(self, tail: int = 4000) -> str:
        return (f"--- process {self.process_id} "
                f"(rc={self.returncode}) ---\n"
                f"STDOUT:\n{self.stdout[-tail:]}\n"
                f"STDERR:\n{self.stderr[-tail:]}")


def _kill(procs) -> None:
    for p in procs:
        if p.poll() is None:
            try:
                p.terminate()
            except OSError:
                pass
    deadline = time.monotonic() + 5.0
    for p in procs:
        while p.poll() is None and time.monotonic() < deadline:
            time.sleep(0.05)
        if p.poll() is None:
            try:
                p.send_signal(signal.SIGKILL)
            except OSError:
                pass
            p.wait()


def run_multiproc(name: str, n_processes: int = 1,
                  devices_per_process: int = DEFAULT_DEVICES,
                  timeout: int = 600, env: dict | None = None,
                  check: bool = True) -> list[ProcResult]:
    """Run ``tests/dist_progs/<name>`` as ``n_processes`` subprocesses.

    ``n_processes == 1`` launches the classic single-process check: no
    distributed env at all (any inherited ``NUM_PROCESSES``/... is
    scrubbed), just pinned XLA_FLAGS.  ``n_processes > 1`` additionally
    exports the ``runtime.distributed`` env contract with a fresh
    localhost coordinator port.

    ``check=True`` (default) asserts every process exited 0 with stdout
    ending in the conventional ``OK <progname>`` line, raising with all
    logs otherwise; ``check=False`` returns the results for the caller
    to inspect (failure-mode tests).  Either way, the first failing
    process gets the rest killed, and ``timeout`` seconds is a hard cap
    on the whole group (stragglers are killed, TimeoutError raised).
    """
    base = dict(os.environ)
    base["XLA_FLAGS"] = xla_flags(devices_per_process)
    base["PYTHONPATH"] = SRC + os.pathsep + base.get("PYTHONPATH", "")
    for key in ("COORDINATOR_ADDRESS", "NUM_PROCESSES", "PROCESS_ID",
                "DIST_INIT_TIMEOUT"):
        base.pop(key, None)
    if env:
        base.update(env)
    if n_processes > 1:
        base.setdefault("COORDINATOR_ADDRESS", f"127.0.0.1:{free_port()}")
        base.setdefault("NUM_PROCESSES", str(n_processes))

    prog = os.path.join(PROGS, name)
    procs, files = [], []
    try:
        for i in range(n_processes):
            child_env = dict(base)
            if n_processes > 1:
                child_env["PROCESS_ID"] = str(i)
            out = tempfile.TemporaryFile(mode="w+")
            err = tempfile.TemporaryFile(mode="w+")
            files.append((out, err))
            procs.append(subprocess.Popen(
                [sys.executable, prog], stdout=out, stderr=err,
                env=child_env, text=True))

        deadline = time.monotonic() + timeout
        timed_out = False
        while any(p.poll() is None for p in procs):
            if any(p.poll() not in (None, 0) for p in procs):
                _kill(procs)             # first failure kills stragglers
                break
            if time.monotonic() > deadline:
                timed_out = True
                _kill(procs)             # hard cap: no silent hang past it
                break
            time.sleep(0.1)

        results = []
        for i, (p, (out, err)) in enumerate(zip(procs, files)):
            out.seek(0)
            err.seek(0)
            results.append(ProcResult(
                process_id=i, returncode=p.poll(),
                stdout=out.read(), stderr=err.read()))
    finally:
        _kill(procs)
        for out, err in files:
            out.close()
            err.close()

    if timed_out:
        raise TimeoutError(
            f"{name} (x{n_processes}) exceeded the {timeout}s hard "
            f"timeout; stragglers killed.\n"
            + "\n".join(r.summary() for r in results))
    if check:
        logs = "\n".join(r.summary() for r in results)
        assert all(r.returncode == 0 for r in results), \
            f"{name} (x{n_processes}) failed:\n{logs}"
        for r in results:
            assert r.stdout.strip().endswith(f"OK {name[:-3]}"), \
                f"{name} process {r.process_id} missing OK line:\n{logs}"
    return results
