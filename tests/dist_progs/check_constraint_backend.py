"""8-worker backend equivalence: engine(..., backend="constraint") must
reproduce the explicit shard_map backend's losses AND grads (atol 1e-5)
for GCN and GAT in all three TP modes, and for the DP baseline (run as a
child process with --xla_force_host_platform_device_count=8)."""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import decouple as D  # noqa: E402
from repro.gnn import dp_baseline as DP  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.runtime import tp_mesh  # noqa: E402

assert len(jax.devices()) == 8

ATOL = 1e-5


def max_tree_diff(a, b):
    return max(jax.tree.leaves(
        jax.tree.map(lambda x, y: float(jnp.abs(x - y).max()), a, b)))


data = sbm_power_law(n=616, num_classes=5, feat_dim=24, avg_degree=8, seed=0)
bundle = D.prepare_bundle(data, n_workers=8, n_chunks=4)
mesh = tp_mesh(8)

for model in ("gcn", "gat"):
    for mode in ("decoupled", "decoupled_pipelined", "naive"):
        cfg = D.padded_gnn_config(data, bundle, model=model, hidden_dim=32,
                                  num_layers=3)
        params = M.init_params(jax.random.PRNGKey(1), cfg)
        grad_e = jax.value_and_grad(D.make_tp_loss_fn(
            cfg, bundle, mesh, mode=mode, backend="explicit"))
        grad_c = jax.value_and_grad(D.make_tp_loss_fn(
            cfg, bundle, mesh, mode=mode, backend="constraint"))
        le, ge = grad_e(params, bundle.train_mask)
        lc, gc = grad_c(params, bundle.train_mask)
        dl = abs(float(le) - float(lc))
        dg = max_tree_diff(ge, gc)
        assert dl < ATOL and dg < ATOL, (model, mode, dl, dg)

# DP baseline (halo exchange as a constraint-lowered transition)
dp_bundle = DP.prepare_dp_bundle(data, k=8)
cfg = M.GNNConfig(model="gcn", in_dim=24, hidden_dim=32, num_classes=5,
                  num_layers=2, decoupled=False)
params = M.init_params(jax.random.PRNGKey(0), cfg)
le, ge = jax.value_and_grad(DP.make_dp_loss_fn(
    cfg, dp_bundle, mesh, backend="explicit"))(params, dp_bundle.train_mask)
lc, gc = jax.value_and_grad(DP.make_dp_loss_fn(
    cfg, dp_bundle, mesh, backend="constraint"))(params,
                                                 dp_bundle.train_mask)
dl = abs(float(le) - float(lc))
dg = max_tree_diff(ge, gc)
assert dl < ATOL and dg < ATOL, ("dp", dl, dg)

# training end-to-end on the constraint backend converges identically
from repro import optim  # noqa: E402

cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=32,
                          num_layers=2)
params = M.init_params(jax.random.PRNGKey(0), cfg)
opt = optim.adamw(1e-2)
step, ev = D.make_tp_train_fns(cfg, bundle, mesh, opt, mode="decoupled",
                               backend="constraint")
p, o = params, opt.init(params)
for _ in range(25):
    p, o, loss = step(p, o)
_, acc = ev(p, "test")
assert float(acc) > 0.8, float(acc)

print("OK check_constraint_backend")
