"""8-device cross-mode equivalence for hybrid DP×TP on multi-axis meshes.

On 8 forced host devices, losses AND grads of hybrid (data=2, model=4)
and (data=4, model=2) training must match pure TP (model=8) and a
single-device reference to atol 1e-5, for GCN/GAT × all four execution
modes × both engine backends:

* TP modes (decoupled, decoupled_pipelined, naive) are compared against
  the pure-TP run of the *same* mode family (decoupled and naive are
  different models — decoupled applies all propagations after the MLP);
* mode "dp" (the partition-parallel baseline, GCN only — it has no GAT
  variant) is exact full-graph training at any partition count, so its
  hybrid runs are compared against pure dp (k=8) and the same
  single-device reference as naive TP (coupled GCN ≡ dp ≡ naive TP).

Run as a child process with --xla_force_host_platform_device_count=8.
"""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import decouple as D  # noqa: E402
from repro.gnn import dp_baseline as DP  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.runtime import hybrid_mesh, tp_mesh  # noqa: E402

assert len(jax.devices()) == 8

ATOL = 1e-5
SHAPES = ((2, 4), (4, 2))          # (data, model), both factorizations of 8
TP_MODES = ("decoupled", "decoupled_pipelined", "naive")


def max_tree_diff(a, b):
    # via numpy: operands come from different meshes (1-device reference
    # vs 8-device runs), which jnp binary ops refuse to mix
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.abs(np.asarray(x) - np.asarray(y)).max()),
        a, b)))


def check(tag, ref, got):
    dl = abs(float(ref[0]) - float(got[0]))
    dg = max_tree_diff(ref[1], got[1])
    assert dl < ATOL and dg < ATOL, (tag, dl, dg)


# dims chosen so every padding contract is a no-op across all device
# shapes (240 % (model·chunks·data) == 0 for every shape below), keeping
# params shape-identical and grads directly comparable
data = sbm_power_law(n=240, num_classes=8, feat_dim=16, avg_degree=8, seed=0)

# --- references: single device + pure TP (model=8), explicit backend ---
bundle1 = D.prepare_bundle(data, n_workers=1, n_chunks=2)
bundle8 = D.prepare_bundle(data, n_workers=8, n_chunks=2)
mesh1, mesh8 = tp_mesh(1), tp_mesh(8)
refs = {}
for model in ("gcn", "gat"):
    cfg = D.padded_gnn_config(data, bundle1, model=model, hidden_dim=16,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    for mode in ("decoupled", "naive"):
        single = jax.value_and_grad(D.make_tp_loss_fn(
            cfg, bundle1, mesh1, mode=mode, backend="explicit"))(
            params, bundle1.train_mask)
        pure = jax.value_and_grad(D.make_tp_loss_fn(
            cfg, bundle8, mesh8, mode=mode, backend="explicit"))(
            params, bundle8.train_mask)
        # pure TP must itself agree with the single-device oracle
        check(f"pure8-vs-single:{model}:{mode}", single, pure)
        refs[(model, mode)] = (single, pure, params)
    print(f"refs {model} ok", flush=True)

# --- hybrid TP modes: both (data, model) shapes × both backends ---
for dd, mm in SHAPES:
    hm = hybrid_mesh(model=mm, data=dd)
    assert hm.size == mm and hm.data_size == dd and hm.data_axes == ("data",)
    bh = D.prepare_bundle(data, n_workers=mm, n_chunks=2, n_replicas=dd)
    for model in ("gcn", "gat"):
        cfgh = D.padded_gnn_config(data, bh, model=model, hidden_dim=16,
                                   num_layers=2)
        for backend in ("explicit", "constraint"):
            for mode in TP_MODES:
                family = "decoupled" if mode.startswith("decoupled") \
                    else "naive"
                single, pure, params = refs[(model, family)]
                got = jax.value_and_grad(D.make_tp_loss_fn(
                    cfgh, bh, hm, mode=mode, backend=backend))(
                    params, bh.train_mask)
                tag = f"d{dd}xm{mm}:{model}:{backend}:{mode}"
                check(tag + ":vs-pure8", pure, got)
                check(tag + ":vs-single", single, got)
        print(f"hybrid d{dd}xm{mm} {model} ok", flush=True)

# --- mode "dp": partition-parallel baseline under the same hybrid meshes ---
cfg_dp = M.GNNConfig(model="gcn", in_dim=16, hidden_dim=16, num_classes=8,
                     num_layers=2, decoupled=False)
params_dp = M.init_params(jax.random.PRNGKey(0), cfg_dp)
dp8 = DP.prepare_dp_bundle(data, k=8)
pure_dp = jax.value_and_grad(DP.make_dp_loss_fn(
    cfg_dp, dp8, mesh8, backend="explicit"))(params_dp, dp8.train_mask)
# coupled GCN is the same model as naive TP: anchor dp to that oracle too
naive_single = jax.value_and_grad(D.make_tp_loss_fn(
    D.padded_gnn_config(data, bundle1, model="gcn", hidden_dim=16,
                        num_layers=2),
    bundle1, mesh1, mode="naive", backend="explicit"))(
    params_dp, bundle1.train_mask)
check("pure-dp8-vs-single", naive_single, pure_dp)
for dd, mm in SHAPES:
    hm = hybrid_mesh(model=mm, data=dd)
    bh = DP.prepare_dp_bundle(data, k=mm, n_replicas=dd)
    for backend in ("explicit", "constraint"):
        got = jax.value_and_grad(DP.make_dp_loss_fn(
            cfg_dp, bh, hm, backend=backend))(params_dp, bh.train_mask)
        check(f"dp:d{dd}xm{mm}:{backend}:vs-pure8", pure_dp, got)
        check(f"dp:d{dd}xm{mm}:{backend}:vs-single", naive_single, got)
print("dp hybrid ok", flush=True)

# --- 3-axis (pod=2, data=2, model=2): two replica axes, same numerics ---
pm = hybrid_mesh(model=2, data=2, pod=2)
assert pm.mesh.axis_names == ("pod", "data", "model")
assert pm.data_axes == ("pod", "data") and pm.data_size == 4
bp = D.prepare_bundle(data, n_workers=2, n_chunks=2, n_replicas=4)
cfgp = D.padded_gnn_config(data, bp, model="gcn", hidden_dim=16,
                           num_layers=2)
single, pure, params = refs[("gcn", "decoupled")]
for backend in ("explicit", "constraint"):
    got = jax.value_and_grad(D.make_tp_loss_fn(
        cfgp, bp, pm, mode="decoupled", backend=backend))(
        params, bp.train_mask)
    check(f"pod2x2x2:gcn:{backend}:vs-pure8", pure, got)
    check(f"pod2x2x2:gcn:{backend}:vs-single", single, got)
bp_dp = DP.prepare_dp_bundle(data, k=2, n_replicas=4)
got = jax.value_and_grad(DP.make_dp_loss_fn(
    cfg_dp, bp_dp, pm, backend="explicit"))(params_dp, bp_dp.train_mask)
check("pod2x2x2:dp:explicit:vs-pure8", pure_dp, got)
print("pod mesh ok", flush=True)

# --- end-to-end: a few hybrid train steps reduce the loss, eval works ---
from repro import optim  # noqa: E402

hm = hybrid_mesh(model=4, data=2)
bh = D.prepare_bundle(data, n_workers=4, n_chunks=2, n_replicas=2)
cfg = D.padded_gnn_config(data, bh, model="gcn", hidden_dim=16,
                          num_layers=2)
params = M.init_params(jax.random.PRNGKey(0), cfg)
opt = optim.adamw(1e-2)
step, ev = D.make_tp_train_fns(cfg, bh, hm, opt, mode="decoupled_pipelined",
                               backend="explicit")
p, o = params, opt.init(params)
losses = []
for _ in range(15):
    p, o, loss = step(p, o)
    losses.append(float(loss))
assert losses[-1] < losses[0], losses
_, acc = ev(p, "train")
assert 0.0 <= float(acc) <= 1.0

print("OK check_hybrid_mesh")
