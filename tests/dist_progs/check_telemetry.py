"""8-device byte-for-byte check of the trace-time collective telemetry.

On 8 forced host devices, for the bench workload (n=4096, feat=128,
hidden=64, classes=16, L=2 — the Fig. 8 measurement), the telemetry
ledger collected while the train program traces must agree exactly with

  (a) the analytic §3.2 formulas (benchmarks.bench_comm_volume.
      expected_ledger — per-device ring wire bytes and collective
      counts: decoupled 4 a2a/epoch, naive 2L+2(L−1), dp L+(L−1)), and
  (b) the compiled-HLO census (repro.launch.roofline.hlo_census — the
      demoted regex cross-check),

for every GCN mode × both engine backends, pure TP (model=8) and a
(data=2, model=4) hybrid mesh (where the data-axis replica_gather bytes
must additionally equal the census all-gather + reduce-scatter columns).
The pipelined mode pins the ledger's loop multipliers against the
census's while-loop trip constants (its padded chunk tables are an upper
bound on the analytic ideal, so it is census-only).  GAT decoupled pins
the model-axis all-gather accounting (the O(V) score share).  Also
covered: the identity (zero-entry) ledger of data_axes=() replica ops
and the replica_slice no-silent-truncation guard.

Every traced program additionally passes the tier-2 structural audit
(repro.analysis.jaxpr_audit): collective primitives counted in the
closed jaxpr (scan trip multipliers included) must equal what the
ledger implies, per (op, axis, dtype) — all four modes × both backends
× the hybrid mesh — and a deliberately unledgered collective plus a
forged phantom entry are both caught (the negative tests at the end).

Run as a child process with --xla_force_host_platform_device_count=8.
"""
import math
import os
import sys

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
if REPO not in sys.path:
    sys.path.insert(0, REPO)          # for the benchmarks package

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from benchmarks.bench_comm_volume import expected_ledger  # noqa: E402
from repro.analysis import jaxpr_audit as A  # noqa: E402
from repro.core import decouple as D  # noqa: E402
from repro.gnn import dp_baseline as DP  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.launch.roofline import hlo_census  # noqa: E402
from repro.runtime import (collect_comm, engine, hybrid_mesh,  # noqa: E402
                           tp_mesh)
from repro.runtime import collectives as C  # noqa: E402

assert len(jax.devices()) == 8

N, FEAT, HIDDEN, CLASSES, L, CHUNKS = 4096, 128, 64, 16, 2, 4


def close(a, b):
    return math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-6)


def trace_train(loss_fn, params, mask, *, backend="explicit", tag=""):
    """(ledger, census) of the full fwd+bwd train program, after the
    tier-2 structural audit: jaxpr collective counts == ledger counts
    (exact, incl. scan trip multipliers).  The jaxpr is re-traced
    *outside* collect_comm — the telemetry wrappers no-op without an
    active ledger, so the audit trace records nothing."""
    f = jax.jit(jax.value_and_grad(loss_fn))
    with collect_comm() as ledger:
        lowered = f.lower(params, mask)
    assert len(ledger), "empty ledger: collection did not see the trace"
    census = hlo_census(lowered.compile().as_text())["collectives"]
    jxp = jax.make_jaxpr(jax.value_and_grad(loss_fn))(params, mask)
    A.assert_clean(jxp, ledger, backend=backend, tag=tag)
    return ledger, census


def check_three_way(tag, ledger, census, expected, data_axes=()):
    led_a2a = ledger.wire_bytes("all_to_all", "model", train=True)
    led_n = ledger.call_count("all_to_all", "model", train=True)
    led_agd = sum(ledger.wire_bytes("all_gather", a, train=True)
                  for a in data_axes)
    assert close(led_a2a, expected["a2a_wire"]), \
        (tag, "ledger vs analytic", led_a2a, expected["a2a_wire"])
    assert led_n == expected["a2a_calls"], \
        (tag, "a2a count", led_n, expected["a2a_calls"])
    assert close(led_a2a, census["all-to-all"]), \
        (tag, "ledger vs census", led_a2a, census["all-to-all"])
    if data_axes:
        assert led_agd > 0 and close(led_agd, expected["ag_data_wire"]), \
            (tag, "data-axis ag vs analytic", led_agd,
             expected["ag_data_wire"])
        # the mirrored replica_gather lowers as all-gather + its
        # psum-scatter transpose (reduce-scatter), or as two all-gathers
        # under the constraint partitioner — either way the HLO gather
        # traffic must equal the ledger's data-axis total
        hlo_ag = census["all-gather"] + census["reduce-scatter"]
        assert close(led_agd, hlo_ag), \
            (tag, "data-axis ag vs census", led_agd, hlo_ag)
    else:
        assert led_agd == 0.0, (tag, led_agd)
    print(f"ok {tag}: a2a={led_a2a:.6e} n={led_n:.0f} agd={led_agd:.6e}")


data = sbm_power_law(n=N, num_classes=CLASSES, feat_dim=FEAT,
                     avg_degree=16, seed=7)

# --- pure TP (model=8), GCN, both backends ------------------------------
mesh8 = tp_mesh(8)
bundle = D.prepare_bundle(data, n_workers=8, n_chunks=CHUNKS)
cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=HIDDEN,
                          num_layers=L)
params = M.init_params(jax.random.PRNGKey(0), cfg)
for mode in ("decoupled", "naive"):
    exp = expected_ledger(mode, n=bundle.n_padded, feat=cfg.in_dim,
                          hidden=cfg.hidden_dim, classes=cfg.num_classes,
                          L=L, model=8)
    for backend in ("explicit", "constraint"):
        loss_fn = D.make_tp_loss_fn(cfg, bundle, mesh8, mode=mode,
                                    backend=backend)
        ledger, census = trace_train(loss_fn, params, bundle.train_mask,
                                     backend=backend,
                                     tag=f"{mode}/{backend}")
        check_three_way(f"{mode}/{backend}", ledger, census, exp)

# decoupled counters are the paper's frequency claim verbatim
assert expected_ledger("decoupled", n=bundle.n_padded, feat=cfg.in_dim,
                       hidden=cfg.hidden_dim, classes=cfg.num_classes,
                       L=L, model=8)["a2a_calls"] == 4

# --- pipelined: loop multipliers vs the census's while-loop trips -------
loss_fn = D.make_tp_loss_fn(cfg, bundle, mesh8, mode="decoupled_pipelined")
ledger, census = trace_train(loss_fn, params, bundle.train_mask,
                             tag="decoupled_pipelined")
led_a2a = ledger.wire_bytes("all_to_all", "model", train=True)
assert close(led_a2a, census["all-to-all"]), \
    ("pipelined ledger vs census", led_a2a, census["all-to-all"])
# 2 scans × CHUNKS trips forward, all mirrored
assert ledger.call_count("all_to_all", train=True) == 4 * CHUNKS
print(f"ok decoupled_pipelined: a2a={led_a2a:.6e} (trip-multiplied)")

# --- dp baseline (halo exchange), both backends -------------------------
dp_bundle = DP.prepare_dp_bundle(data, k=8)
dp_cfg = M.GNNConfig(model="gcn", in_dim=FEAT, hidden_dim=HIDDEN,
                     num_classes=CLASSES, num_layers=L, decoupled=False)
dp_params = M.init_params(jax.random.PRNGKey(0), dp_cfg)
exp = expected_ledger("dp", n=N, feat=FEAT, hidden=HIDDEN,
                      classes=CLASSES, L=L, model=8,
                      halo_slots=8 * 8 * dp_bundle.graph.m)
for backend in ("explicit", "constraint"):
    loss_fn = DP.make_dp_loss_fn(dp_cfg, dp_bundle, mesh8, backend=backend)
    ledger, census = trace_train(loss_fn, dp_params, dp_bundle.train_mask,
                                 backend=backend, tag=f"dp/{backend}")
    check_three_way(f"dp/{backend}", ledger, census, exp)

# --- hybrid (data=2, model=4): model-axis a2a + data-axis gathers -------
meshh = hybrid_mesh(data=2)
bundleh = D.prepare_bundle(data, n_workers=4, n_chunks=CHUNKS,
                           n_replicas=2)
cfgh = D.padded_gnn_config(data, bundleh, model="gcn", hidden_dim=HIDDEN,
                           num_layers=L)
paramsh = M.init_params(jax.random.PRNGKey(0), cfgh)
for mode in ("decoupled", "naive"):
    exp = expected_ledger(mode, n=bundleh.n_padded, feat=cfgh.in_dim,
                          hidden=cfgh.hidden_dim,
                          classes=cfgh.num_classes, L=L, model=4, data=2)
    for backend in ("explicit", "constraint"):
        loss_fn = D.make_tp_loss_fn(cfgh, bundleh, meshh, mode=mode,
                                    backend=backend)
        ledger, census = trace_train(loss_fn, paramsh,
                                     bundleh.train_mask, backend=backend,
                                     tag=f"{mode}/{backend}/d2x4")
        check_three_way(f"{mode}/{backend}/d2x4", ledger, census, exp,
                        data_axes=meshh.data_axes)

# --- GAT decoupled: the model-axis O(V) score all-gathers ---------------
gat_data = sbm_power_law(n=1024, num_classes=CLASSES, feat_dim=32,
                         avg_degree=8, seed=7)
gat_bundle = D.prepare_bundle(gat_data, n_workers=8, n_chunks=CHUNKS)
gat_cfg = D.padded_gnn_config(gat_data, gat_bundle, model="gat",
                              hidden_dim=32, num_layers=L)
gat_params = M.init_params(jax.random.PRNGKey(0), gat_cfg)
loss_fn = D.make_tp_loss_fn(gat_cfg, gat_bundle, mesh8, mode="decoupled")
ledger, census = trace_train(loss_fn, gat_params, gat_bundle.train_mask,
                             tag="gat/decoupled")
led_a2a = ledger.wire_bytes("all_to_all", "model", train=True)
assert close(led_a2a, census["all-to-all"]), \
    ("gat ledger vs census a2a", led_a2a, census["all-to-all"])
led_ag = ledger.wire_bytes("all_gather", "model", train=True)
hlo_ag = census["all-gather"] + census["reduce-scatter"]
assert led_ag > 0 and close(led_ag, hlo_ag), \
    ("gat score all-gathers vs census", led_ag, hlo_ag)
print(f"ok gat decoupled: a2a={led_a2a:.6e} ag={led_ag:.6e}")

# --- identity ledger: data_axes=() replica ops --------------------------
with collect_comm() as ledger:
    x = jnp.arange(8.0).reshape(4, 2)
    assert C.replica_gather(x, ()) is x
    assert C.replica_slice(x, ()) is x
    assert C.psum_replicas(x, ()) is x
assert len(ledger) == 0, ledger.as_dict()

# --- replica_slice: no silent truncation on a real data axis ------------
def bad_body(x):
    return C.replica_slice(x, ("data",))


bad = engine(bad_body, in_specs=P(), out_specs=P(), mesh=meshh)
try:
    bad(jnp.zeros((7, 2)))
except ValueError as e:
    msg = str(e)
    assert "length 7" in msg and "replica count 2" in msg, msg
else:
    raise AssertionError("replica_slice silently truncated 7 rows over "
                         "2 replicas")

# --- tier-2 negative tests ----------------------------------------------
# (1) unledgered collective: a rogue engine body that bypasses the
# runtime choke point — trace-time telemetry sees nothing; the
# structural audit must.
perm = [(i, (i + 1) % 8) for i in range(8)]


def rogue_body(x):
    return jax.lax.ppermute(  # lint-ok: RT001 deliberate violation
        x, "model", perm=perm)


rogue = engine(rogue_body, in_specs=P("model"), out_specs=P("model"),
               mesh=mesh8)
with collect_comm() as rogue_ledger:
    rogue_jxp = jax.make_jaxpr(rogue)(jnp.ones((64, 8), jnp.float32))
findings = A.audit(rogue_jxp, rogue_ledger)
assert [f.kind for f in findings] == ["unledgered_collective"], findings
assert findings[0].op == "ppermute" and findings[0].actual == 1.0
try:
    A.assert_clean(rogue_jxp, rogue_ledger, tag="rogue")
except AssertionError as e:
    assert "unledgered_collective" in str(e), e
else:
    raise AssertionError("audit missed the unledgered ppermute")

# (2) phantom ledger entry: a forged counter with no jaxpr counterpart
# (the shape a wrong mirror= declaration or a bad merge would take).


def routed_body(x):
    return C.ppermute(x, "model", perm=perm, mirror=False)


routed = engine(routed_body, in_specs=P("model"), out_specs=P("model"),
                mesh=mesh8)
with collect_comm() as led_ok:
    jxp_ok = jax.make_jaxpr(routed)(jnp.ones((64, 8), jnp.float32))
A.assert_clean(jxp_ok, led_ok, tag="routed")          # sanity: clean
led_ok.add("all_to_all", "model", "float32", payload=1.0, wire=1.0)
findings = A.audit(jxp_ok, led_ok)
assert [f.kind for f in findings] == ["phantom_ledger_entry"], findings
assert findings[0].op == "all_to_all"
print("ok audit negative tests")

print("OK check_telemetry")
