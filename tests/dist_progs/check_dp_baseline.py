"""8-worker DP (halo-exchange) baseline == single-device coupled reference."""
import os

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro import optim  # noqa: E402
from repro.gnn import dp_baseline as DP  # noqa: E402
from repro.gnn import layers as L  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import chunk_partition, sbm_power_law  # noqa: E402
from repro.runtime import engine, tp_mesh  # noqa: E402

assert len(jax.devices()) == 8

data = sbm_power_law(n=616, num_classes=5, feat_dim=24, avg_degree=8, seed=0)
bundle = DP.prepare_dp_bundle(data, k=8)
mesh = tp_mesh(8)
cfg = M.GNNConfig(model="gcn", in_dim=24, hidden_dim=32, num_classes=5,
                  num_layers=2, decoupled=False)
params = M.init_params(jax.random.PRNGKey(1), cfg)

gd = L.edge_list_dev(data.graph)
ref = M.coupled_forward(params, cfg, gd, jnp.asarray(data.features))
f = engine(
    lambda p, g, x: DP.dp_coupled_forward(p, cfg, g, x[0], axis="model")[None],
    mesh=mesh, in_specs=(P(), P(), P("model", None, None)),
    out_specs=P("model", None, None))
out = np.asarray(f(params, bundle.graph, bundle.features))

part = chunk_partition(data.graph, 8)
rows = []
for i in range(8):
    n_i = part.bounds[i + 1] - part.bounds[i]
    rows.append(out[i][:n_i])
err = float(np.abs(np.concatenate(rows) - np.asarray(ref)).max())
assert err < 1e-4, err

opt = optim.adamw(1e-2)
step, ev = DP.make_dp_train_fns(cfg, bundle, mesh, opt)
p, o = params, opt.init(params)
for _ in range(25):
    p, o, loss = step(p, o)
_, acc = ev(p, "test")
assert float(acc) > 0.8, float(acc)
print("OK check_dp_baseline")
