"""8-device aggregation-backend equivalence + comm-invariance check.

For the GCN, every pluggable aggregation backend (``repro.core.agg``:
segment / blocksparse / dense) must produce the same losses AND grads
(atol 1e-5) as the segment baseline, for

  * the TP engine: decoupled, decoupled_pipelined and naive modes,
  * the DP baseline: coupled halo-exchange forward,

each under both engine backends (explicit shard_map / constraint
partitioner), on pure TP (model=8) and a (data=2, model=4) hybrid mesh.

The backend choice is pure local compute — NeutronTP's communication all
happens in the split/gather all-to-alls (TP) or the halo exchange (DP)
*around* the multiply — so the trace-time CommLedger must be
byte-identical (``as_dict`` equality) across backends for every program,
and the blocksparse programs must additionally pass the tier-2 jaxpr
collective audit (``repro.analysis.jaxpr_audit.assert_clean``).

``--ci-smoke`` runs the fast subset wired into scripts/ci.sh: pure TP,
decoupled GCN, both engine backends, blocksparse vs segment, plus the
DP explicit path.  Run as a child with
--xla_force_host_platform_device_count=8.
"""
import os
import sys

assert "--xla_force_host_platform_device_count=8" in \
    os.environ.get("XLA_FLAGS", "")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.analysis import jaxpr_audit as A  # noqa: E402
from repro.core import decouple as D  # noqa: E402
from repro.gnn import dp_baseline as DP  # noqa: E402
from repro.gnn import models as M  # noqa: E402
from repro.graph import sbm_power_law  # noqa: E402
from repro.runtime import collect_comm, hybrid_mesh, tp_mesh  # noqa: E402

assert len(jax.devices()) == 8

SMOKE = "--ci-smoke" in sys.argv[1:]
AGGS = ("segment", "blocksparse") if SMOKE else \
    ("segment", "blocksparse", "dense")
MODES = ("decoupled",) if SMOKE else \
    ("decoupled", "decoupled_pipelined", "naive")
BACKENDS = ("explicit", "constraint")
ATOL = 1e-5

data = sbm_power_law(n=616, num_classes=5, feat_dim=24, avg_degree=8,
                     seed=0)


def tree_max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.abs(np.asarray(x) - np.asarray(y)).max()),
        a, b)))


def run_one(tag, make_vg, make_loss, params, mask, backend, audit):
    """(loss, grads, ledger-dict) of one program; blocksparse programs
    additionally pass the structural jaxpr audit."""
    with collect_comm() as led:
        jxp = jax.make_jaxpr(jax.value_and_grad(make_loss()))(params, mask)
    if audit:
        A.assert_clean(jxp, led, backend=backend, tag=tag)
    loss, grads = make_vg()(params, mask)
    return float(loss), grads, led.as_dict()


def check_group(tag, programs, params, mask):
    """programs: agg → (make_vg, make_loss, backend).  Asserts loss/grad
    equality and ledger byte-identity against the segment entry."""
    ref = None
    for agg, (make_vg, make_loss, backend) in programs.items():
        loss, grads, led = run_one(f"{tag}/{agg}", make_vg, make_loss,
                                   params, mask, backend,
                                   audit=agg == "blocksparse")
        if ref is None:
            ref = (loss, grads, led)
            continue
        dl = abs(loss - ref[0])
        dg = tree_max_diff(grads, ref[1])
        assert dl < ATOL and dg < ATOL, (tag, agg, dl, dg)
        assert led == ref[2], (
            f"{tag}/{agg}: CommLedger differs from segment baseline — "
            f"aggregation backends must not change communication")
        print(f"ok {tag}/{agg}: dloss={dl:.2e} dgrad={dg:.2e} "
              f"ledger-identical")


# --- TP engine: meshes × modes × engine backends × agg backends ---------
tp_meshes = [("tp8", tp_mesh(8), dict(n_workers=8))]
if not SMOKE:
    tp_meshes.append(("d2x4", hybrid_mesh(data=2),
                      dict(n_workers=4, n_replicas=2)))

for mesh_tag, mesh, prep_kw in tp_meshes:
    bundles = {agg: D.prepare_bundle(data, n_chunks=4, agg=agg,
                                     agg_block_size=32, **prep_kw)
               for agg in AGGS}
    cfg = D.padded_gnn_config(data, bundles["segment"], model="gcn",
                              hidden_dim=32, num_layers=2)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    for mode in MODES:
        for backend in BACKENDS:
            progs = {
                agg: (
                    lambda a=agg, b=backend, m=mode: D.make_tp_value_and_grad(
                        cfg, bundles[a], mesh, mode=m, backend=b),
                    lambda a=agg, b=backend, m=mode: D.make_tp_loss_fn(
                        cfg, bundles[a], mesh, mode=m, backend=b),
                    backend)
                for agg in AGGS}
            check_group(f"tp/{mesh_tag}/{mode}/{backend}", progs, params,
                        bundles["segment"].train_mask)

# --- DP baseline: meshes × engine backends × agg backends ---------------
dp_meshes = [("tp8", tp_mesh(8), dict(k=8))]
dp_backends = ("explicit",) if SMOKE else BACKENDS
if not SMOKE:
    dp_meshes.append(("d2x4", hybrid_mesh(data=2),
                      dict(k=4, n_replicas=2)))

dp_cfg = M.GNNConfig(model="gcn", in_dim=24, hidden_dim=32, num_classes=5,
                     num_layers=2, decoupled=False)
dp_params = M.init_params(jax.random.PRNGKey(1), dp_cfg)
for mesh_tag, mesh, prep_kw in dp_meshes:
    bundles = {agg: DP.prepare_dp_bundle(data, agg=agg, agg_block_size=32,
                                         **prep_kw)
               for agg in AGGS}
    for backend in dp_backends:
        progs = {
            agg: (
                lambda a=agg, b=backend: DP.make_dp_value_and_grad(
                    dp_cfg, bundles[a], mesh, backend=b),
                lambda a=agg, b=backend: DP.make_dp_loss_fn(
                    dp_cfg, bundles[a], mesh, backend=b),
                backend)
            for agg in AGGS}
        check_group(f"dp/{mesh_tag}/{backend}", progs, dp_params,
                    bundles["segment"].train_mask)

print("OK check_agg_backends")
