"""``runtime.collectives`` is the one choke point for wire bytes.

Every collective the engine (either backend) executes must route through
:mod:`repro.runtime.collectives` — that is what makes per-axis byte/op
counters (the trace-time telemetry now measuring bench_comm_volume's
Fig. 8 rows — see tests/test_telemetry.py) and backend/mesh changes
local to one module.  The invariant was originally pinned by a line
regex over ``src/repro``; that check was blind to ``from jax.lax import
psum`` and ``import jax.lax as _l`` spellings (proven below against the
seeded fixtures), so it now rides the AST linter
(:mod:`repro.analysis.lint`, rule RT001 — with RT002 for shard_map).
These tests drive the linter over the real tree and pin the analytic
data-axis terms of the comm-volume accounting.
"""
import os
import re
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(REPO, "src", "repro")
FIXTURES = os.path.join(REPO, "tests", "fixtures", "lint")

from repro.analysis import lint  # noqa: E402


def _findings(paths, rule):
    return [f for f in lint.lint_paths(paths) if f.rule == rule]


def test_no_direct_lax_collectives_outside_runtime():
    offenders = _findings([SRC], "RT001")
    assert not offenders, (
        "jax.lax collectives must route through runtime.collectives "
        "(the telemetry/backends choke point):\n"
        + "\n".join(f.format() for f in offenders))


def test_no_direct_shard_map_outside_runtime():
    """Companion invariant (runtime/__init__ docstring): only the runtime
    layer may import/call shard_map, any spelling."""
    offenders = _findings([SRC], "RT002")
    assert not offenders, "\n".join(f.format() for f in offenders)


def test_no_lint_errors_anywhere():
    """The full registry over the linted tree: src/repro and the dist
    programs carry zero error-severity findings (warn rules like W100
    may report — they never gate)."""
    paths = [SRC, os.path.join(REPO, "tests", "dist_progs")]
    errors = [f for f in lint.lint_paths(paths) if f.severity == "error"]
    assert not errors, "\n".join(f.format() for f in errors)


# ---------------------------------------------------------------------------
# regression: the spellings the retired line regex was blind to
# ---------------------------------------------------------------------------

#: The retired check, verbatim — kept only to prove what it misses.
_OLD_COLLECTIVE_RE = re.compile(
    r"\blax\.(psum|pmean|pmax|pmin|all_gather|all_to_all|ppermute|"
    r"psum_scatter|axis_index|axis_size)\s*\(")


@pytest.mark.parametrize("fixture", [
    "bad_from_import.py",   # from jax.lax import all_to_all
    "bad_alias_import.py",  # import jax.lax as _l; _l.psum(...)
])
def test_rt001_catches_spellings_the_old_regex_missed(fixture):
    path = os.path.join(FIXTURES, fixture)
    with open(path, encoding="utf-8") as fh:
        text = fh.read()
    assert not any(_OLD_COLLECTIVE_RE.search(line)
                   for line in text.splitlines()), \
        "fixture no longer demonstrates the regex blind spot"
    assert _findings([path], "RT001"), \
        f"RT001 must flag {fixture} (the linter's reason to exist)"


def test_rt001_still_catches_the_attribute_spelling():
    """Sanity: the one spelling the old regex did catch is not lost."""
    path = os.path.join(FIXTURES, "bad_attr_call.py")
    with open(path, encoding="utf-8") as fh:
        assert any(_OLD_COLLECTIVE_RE.search(line) for line in fh)
    assert _findings([path], "RT001")


def test_every_fixture_trips_its_rule():
    """scripts/lint_dist.py must exit nonzero on the seeded-bad tree —
    each fixture file produces at least one error finding, and the per-
    file rules match the README table."""
    expected = {
        "bad_from_import.py": "RT001",
        "bad_alias_import.py": "RT001",
        "bad_attr_call.py": "RT001",
        "bad_shard_map.py": "RT002",
        "bad_multihost.py": "RT005",
        os.path.join("core", "bad_missing_mirror.py"): "RT003",
        os.path.join("core", "bad_scan_no_loop_scope.py"): "RT004",
    }
    findings = [f for f in lint.lint_paths([FIXTURES])
                if f.severity == "error"]
    by_file = {}
    for f in findings:
        by_file.setdefault(os.path.relpath(f.path, FIXTURES), set()).add(
            f.rule)
    for rel, rule in expected.items():
        assert rule in by_file.get(rel, set()), \
            f"{rel}: expected {rule}, got {sorted(by_file.get(rel, []))}"


def test_lint_cli_exit_codes():
    """The CLI contract the ci.sh lint stage relies on: nonzero on the
    fixtures, zero on the real tree."""
    import subprocess

    cli = os.path.join(REPO, "scripts", "lint_dist.py")
    bad = subprocess.run([sys.executable, cli, FIXTURES],
                         capture_output=True, text=True)
    assert bad.returncode == 1, bad.stdout + bad.stderr
    good = subprocess.run([sys.executable, cli],
                          capture_output=True, text=True)
    assert good.returncode == 0, good.stdout + good.stderr


def test_engine_collectives_are_module_routed():
    """The engine bodies' collective vocabulary exists on the module and
    the replica ops degrade to identities for pure TP (data_axes=())."""
    import jax.numpy as jnp
    from repro.runtime import collectives as C

    for name in ("psum", "all_gather", "all_to_all", "ppermute",
                 "axis_index", "axis_size", "replica_gather",
                 "replica_slice", "psum_replicas", "replica_index",
                 "replica_size"):
        assert callable(getattr(C, name)), name
    x = jnp.arange(6.0).reshape(3, 2)
    # pure-TP identities need no mesh/axis context at all
    assert C.replica_gather(x, ()) is x
    assert C.replica_slice(x, ()) is x
    assert C.psum_replicas(x, ()) is x


# ---------------------------------------------------------------------------
# analytic comm-volume: the data-axis grad all-reduce term
# ---------------------------------------------------------------------------

def _analytic_volumes():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks.bench_comm_volume import analytic_volumes
    return analytic_volumes


def test_analytic_data_axis_grad_psum_term():
    """Regression: hybrid DP×TP must account the data-axis gradient
    all-reduce bytes — pure TP has none, replicas add ring-all-reduce
    bytes per model group, linear in (data−1)."""
    analytic_volumes = _analytic_volumes()
    kw = dict(n=1024, feat=32, hidden=16, classes=8, L=2, halo_rows=100)
    pure = analytic_volumes(**kw, data=1, model=4, param_bytes=1000)
    hyb2 = analytic_volumes(**kw, data=2, model=4, param_bytes=1000)
    hyb4 = analytic_volumes(**kw, data=4, model=4, param_bytes=1000)
    assert pure["grad_allreduce_data"] == 0
    # ring all-reduce: 2·(data−1)·param_bytes per model group, model groups
    assert hyb2["grad_allreduce_data"] == 2 * 1 * 1000 * 4
    assert hyb4["grad_allreduce_data"] == 2 * 3 * 1000 * 4
    # fleet-total convention: every replica group redundantly executes
    # the model-axis a2a/halo traffic, so those columns scale ×data
    for key in ("naive", "decoupled", "dp"):
        assert hyb2[key] == 2 * pure[key]
        assert hyb4[key] == 4 * pure[key]


def test_analytic_hybrid_guards():
    """data>1 without the model-group count or param bytes must raise —
    silent defaults would zero/undercount the data-axis term."""
    analytic_volumes = _analytic_volumes()
    kw = dict(n=64, feat=8, hidden=4, classes=2, L=2, halo_rows=10)
    with pytest.raises(ValueError, match="model"):
        analytic_volumes(**kw, data=2, param_bytes=100)
    with pytest.raises(ValueError, match="param_bytes"):
        analytic_volumes(**kw, data=2, model=4)


def test_analytic_default_is_pure_tp():
    analytic_volumes = _analytic_volumes()
    vols = analytic_volumes(n=64, feat=8, hidden=4, classes=2, L=2,
                            halo_rows=10)
    assert vols["grad_allreduce_data"] == 0
    assert vols["naive"] > vols["decoupled"] > 0
