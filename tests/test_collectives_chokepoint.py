"""``runtime.collectives`` is the one choke point for wire bytes.

Every collective the engine (either backend) executes must route through
:mod:`repro.runtime.collectives` — that is what makes per-axis byte/op
counters (the trace-time telemetry now measuring bench_comm_volume's
Fig. 8 rows — see tests/test_telemetry.py) and backend/mesh changes
local to one module.  These tests pin the invariant at the source level
(no stray ``jax.lax`` collective calls anywhere else in ``src/repro``)
and pin the data-axis terms of the analytic comm-volume accounting.
"""
import os
import re
import sys

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
SRC = os.path.join(REPO, "src", "repro")

#: The ops that put bytes on the wire (plus the axis introspection the
#: engine bodies rely on).  ``with_sharding_constraint`` is exempt: it is
#: the constraint backend's transition op and lives in runtime/constraint.
_COLLECTIVE_RE = re.compile(
    r"\blax\.(psum|pmean|pmax|pmin|all_gather|all_to_all|ppermute|"
    r"psum_scatter|axis_index|axis_size)\s*\(")

#: Modules allowed to touch jax.lax collectives directly.
_ALLOWED = {
    os.path.join("runtime", "collectives.py"),
}


def _py_files():
    for root, _, files in os.walk(SRC):
        for f in files:
            if f.endswith(".py"):
                yield os.path.join(root, f)


def test_no_direct_lax_collectives_outside_runtime():
    offenders = []
    for path in _py_files():
        rel = os.path.relpath(path, SRC)
        if rel in _ALLOWED:
            continue
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                if _COLLECTIVE_RE.search(line):
                    offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, (
        "jax.lax collectives must route through runtime.collectives "
        "(the telemetry/backends choke point):\n" + "\n".join(offenders))


def test_no_direct_shard_map_outside_runtime():
    """Companion invariant (runtime/__init__ docstring): only the runtime
    layer may call shard_map, any spelling."""
    pat = re.compile(r"^\s*(from|import)\s+[\w.]*shard_map"
                     r"|^\s*from\s+[\w.]+\s+import\s+.*\bshard_map\b")
    offenders = []
    for path in _py_files():
        rel = os.path.relpath(path, SRC)
        if rel.startswith("runtime" + os.sep):
            continue
        with open(path, encoding="utf-8") as fh:
            for lineno, line in enumerate(fh, 1):
                if pat.search(line):
                    offenders.append(f"{rel}:{lineno}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


def test_engine_collectives_are_module_routed():
    """The engine bodies' collective vocabulary exists on the module and
    the replica ops degrade to identities for pure TP (data_axes=())."""
    import jax.numpy as jnp
    from repro.runtime import collectives as C

    for name in ("psum", "all_gather", "all_to_all", "ppermute",
                 "axis_index", "axis_size", "replica_gather",
                 "replica_slice", "psum_replicas", "replica_index",
                 "replica_size"):
        assert callable(getattr(C, name)), name
    x = jnp.arange(6.0).reshape(3, 2)
    # pure-TP identities need no mesh/axis context at all
    assert C.replica_gather(x, ()) is x
    assert C.replica_slice(x, ()) is x
    assert C.psum_replicas(x, ()) is x


# ---------------------------------------------------------------------------
# analytic comm-volume: the data-axis grad all-reduce term
# ---------------------------------------------------------------------------

def _analytic_volumes():
    if REPO not in sys.path:
        sys.path.insert(0, REPO)
    from benchmarks.bench_comm_volume import analytic_volumes
    return analytic_volumes


def test_analytic_data_axis_grad_psum_term():
    """Regression: hybrid DP×TP must account the data-axis gradient
    all-reduce bytes — pure TP has none, replicas add ring-all-reduce
    bytes per model group, linear in (data−1)."""
    analytic_volumes = _analytic_volumes()
    kw = dict(n=1024, feat=32, hidden=16, classes=8, L=2, halo_rows=100)
    pure = analytic_volumes(**kw, data=1, model=4, param_bytes=1000)
    hyb2 = analytic_volumes(**kw, data=2, model=4, param_bytes=1000)
    hyb4 = analytic_volumes(**kw, data=4, model=4, param_bytes=1000)
    assert pure["grad_allreduce_data"] == 0
    # ring all-reduce: 2·(data−1)·param_bytes per model group, model groups
    assert hyb2["grad_allreduce_data"] == 2 * 1 * 1000 * 4
    assert hyb4["grad_allreduce_data"] == 2 * 3 * 1000 * 4
    # fleet-total convention: every replica group redundantly executes
    # the model-axis a2a/halo traffic, so those columns scale ×data
    for key in ("naive", "decoupled", "dp"):
        assert hyb2[key] == 2 * pure[key]
        assert hyb4[key] == 4 * pure[key]


def test_analytic_hybrid_guards():
    """data>1 without the model-group count or param bytes must raise —
    silent defaults would zero/undercount the data-axis term."""
    analytic_volumes = _analytic_volumes()
    kw = dict(n=64, feat=8, hidden=4, classes=2, L=2, halo_rows=10)
    with pytest.raises(ValueError, match="model"):
        analytic_volumes(**kw, data=2, param_bytes=100)
    with pytest.raises(ValueError, match="param_bytes"):
        analytic_volumes(**kw, data=2, model=4)


def test_analytic_default_is_pure_tp():
    analytic_volumes = _analytic_volumes()
    vols = analytic_volumes(n=64, feat=8, hidden=4, classes=2, L=2,
                            halo_rows=10)
    assert vols["grad_allreduce_data"] == 0
    assert vols["naive"] > vols["decoupled"] > 0
