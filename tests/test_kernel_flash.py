"""Flash-attention Pallas kernel vs the pure-jnp oracle (interpret mode).

Sweeps shapes (ragged seq lens vs block sizes), dtypes, GQA group sizes,
and the mask variants (causal / sliding-window / softcap)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention
from repro.kernels.flash_attn.ref import flash_ref


def _mk(b, sq, skv, hq, hkv, hd, hdv=None, dtype=jnp.float32, seed=0):
    hdv = hdv or hd
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, sq, hq, hd), dtype)
    k = jax.random.normal(ks[1], (b, skv, hkv, hd), dtype)
    v = jax.random.normal(ks[2], (b, skv, hkv, hdv), dtype)
    return q, k, v


def _check(q, k, v, rtol=2e-5, atol=2e-5, **kw):
    got = flash_attention(q, k, v, interpret=True, **kw)
    want = flash_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                     v.transpose(0, 2, 1, 3),
                     **{a: kw[a] for a in ("causal", "window", "softcap",
                                           "scale") if a in kw}
                     ).transpose(0, 2, 1, 3)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=rtol, atol=atol)


@pytest.mark.parametrize("sq,skv,bq,bkv", [
    (64, 64, 16, 16),        # exact tiling
    (60, 60, 16, 16),        # ragged: padding in both q and kv
    (33, 65, 16, 32),        # ragged + uneven blocks
    (128, 128, 128, 128),    # single block
])
def test_shape_sweep(sq, skv, bq, bkv):
    q, k, v = _mk(2, sq, skv, 4, 4, 32)
    _check(q, k, v, causal=True, block_q=bq, block_kv=bkv)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2), (8, 1), (6, 2)])
def test_gqa_groups(hq, hkv):
    q, k, v = _mk(2, 48, 48, hq, hkv, 16)
    _check(q, k, v, causal=True, block_q=16, block_kv=16)


@pytest.mark.parametrize("dtype,rtol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 2e-2)])
def test_dtypes(dtype, rtol):
    q, k, v = _mk(1, 64, 64, 4, 2, 32, dtype=dtype)
    _check(q, k, v, causal=True, rtol=rtol, atol=rtol,
           block_q=32, block_kv=32)


def test_window_and_softcap():
    q, k, v = _mk(2, 96, 96, 4, 4, 16, seed=3)
    _check(q, k, v, causal=True, window=24, block_q=16, block_kv=16)
    _check(q, k, v, causal=True, softcap=30.0, block_q=32, block_kv=16)


def test_non_causal():
    q, k, v = _mk(1, 40, 72, 4, 2, 16, seed=4)
    _check(q, k, v, causal=False, block_q=16, block_kv=16)


def test_mla_asymmetric_head_dims():
    """MLA: v head dim differs from qk head dim."""
    q, k, v = _mk(1, 64, 64, 4, 4, 32, hdv=16, seed=5)
    _check(q, k, v, causal=True, block_q=16, block_kv=32)


def test_model_forward_with_flash_impl():
    """End-to-end: transformer forward with attn_impl='flash' (Pallas
    interpret) ≡ the naive path."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("internlm2-1.8b").reduced()
    cfg = dataclasses.replace(cfg, attn_impl="naive")
    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0,
                                cfg.vocab_size)
    ref, _ = T.forward(params, cfg, tokens)
    cfg_f = dataclasses.replace(cfg, attn_impl="flash", attn_block_q=16,
                                attn_block_kv=16)
    got, _ = T.forward(params, cfg_f, tokens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_matches_model_blockwise_path():
    """Kernel ≡ the model's jnp blockwise schedule (token-major API)."""
    from repro.nn.attention import attention_blockwise
    q, k, v = _mk(2, 64, 64, 8, 2, 32, seed=6)
    got = flash_attention(q, k, v, causal=True, block_q=16, block_kv=32)
    want = attention_blockwise(q, k, v, causal=True, block_q=16,
                               block_kv=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
