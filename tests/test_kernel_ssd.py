"""SSD Pallas kernel vs the dense dual-form oracle AND the chunked jnp
implementation (interpret mode).  Shape/chunk/state sweeps + the
end-to-end mamba2 block with ssm_impl='fused'."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ssd import ssd_chunked_pallas, ssd_dense_ref
from repro.nn.ssm import ssd_chunked


def _mk(b, s, h, p, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(0.3 * jax.random.normal(ks[2], (h,)))
    b_mat = jax.random.normal(ks[3], (b, s, n)) / np.sqrt(n)
    c_mat = jax.random.normal(ks[4], (b, s, n)) / np.sqrt(n)
    return x, dt, a, b_mat, c_mat


@pytest.mark.parametrize("s,chunk", [(32, 8), (40, 16), (64, 64), (17, 8)])
def test_kernel_matches_dense_oracle(s, chunk):
    args = _mk(2, s, 3, 8, 16)
    y, _ = ssd_chunked_pallas(*args, chunk, interpret=True)
    want = ssd_dense_ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("h,p,n", [(1, 4, 8), (4, 16, 32), (2, 8, 8)])
def test_kernel_matches_chunked_jnp(h, p, n):
    args = _mk(1, 48, h, p, n, seed=3)
    y_k, st_k = ssd_chunked_pallas(*args, 16, interpret=True)
    y_j, st_j = ssd_chunked(*args, 16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_j),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_j),
                               rtol=2e-4, atol=2e-4)


def test_chunked_jnp_matches_dense_oracle():
    """The R3.1-restructured jnp path against the independent oracle."""
    args = _mk(2, 56, 2, 8, 16, seed=5)
    y, _ = ssd_chunked(*args, 8)
    want = ssd_dense_ref(*args)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_final_state_feeds_decode():
    """Kernel's final state == jnp path's (it seeds decode caches)."""
    args = _mk(1, 32, 2, 8, 16, seed=7)
    _, st_k = ssd_chunked_pallas(*args, 8, interpret=True)
    _, st_j = ssd_chunked(*args, 8)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_j),
                               rtol=2e-4, atol=2e-4)


def test_mamba2_block_with_fused_impl():
    """End-to-end mamba2 mixing block: ssm_impl='fused' ≡ 'jnp'."""
    from repro.configs import get_config
    from repro.nn import ssm as ssm_lib
    cfg = get_config("mamba2-1.3b").reduced()
    leafs = ssm_lib.init_mamba2(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda l: l.value, leafs,
                     is_leaf=lambda x: hasattr(x, "names"))
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
    y_ref = ssm_lib.mamba2_forward(p, cfg, x)
    cfg_f = dataclasses.replace(cfg, ssm_impl="fused")
    y_fused = ssm_lib.mamba2_forward(p, cfg_f, x)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
