"""repro.checkpoint layout validation (the restore bugfix sweep).

``restore`` used to validate only leaf count and shapes: a same-arity
pytree with a different *structure* (dict key renamed, list vs tuple)
restored leaves into the wrong slots, and a dtype drift (int step
counter saved, float template) silently cast.  Each failure mode is
pinned here with its actionable error; the multi-process ``save``
contract (host-sharded global arrays rejected eagerly, replicated ones
saved) runs under the real 2-process harness in the slow lane
(tests/dist_progs/check_checkpoint_multiproc.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_dist_prog
from dist_progs import harness
from repro import checkpoint


def params(dtype=jnp.float32, step=jnp.int32(3)):
    return {"layers": [{"w": jnp.ones((4, 2), dtype), "b": jnp.zeros(2)}],
            "step": step}


def test_roundtrip(tmp_path):
    p = params()
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, p, metadata={"epoch": 9})
    out = checkpoint.restore(path, jax.tree.map(jnp.zeros_like, p))
    assert jax.tree.map(lambda a, b: np.array_equal(a, b), p, out)
    assert all(jax.tree.leaves(
        jax.tree.map(lambda a, b: bool(np.array_equal(a, b)), p, out)))
    assert checkpoint.load_metadata(path)["epoch"] == 9


def test_restore_rejects_leaf_count_mismatch(tmp_path):
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, params())
    with pytest.raises(ValueError, match="leaves"):
        checkpoint.restore(path, {"w": jnp.ones((4, 2))})


def test_restore_rejects_treedef_mismatch(tmp_path):
    """Same leaf count, different structure: before the fingerprint
    check this silently restored leaves into the wrong slots."""
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, params())
    renamed = params()
    renamed["step_count"] = renamed.pop("step")     # same arity
    with pytest.raises(ValueError) as ei:
        checkpoint.restore(path, renamed)
    msg = str(ei.value)
    assert "tree structure" in msg
    # both fingerprints shown, so the drift is diagnosable from the error
    assert "stored:" in msg and "template:" in msg


def test_restore_rejects_shape_mismatch_naming_path(tmp_path):
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, params())
    bad = params()
    bad["layers"][0]["w"] = jnp.ones((4, 3))
    with pytest.raises(ValueError) as ei:
        checkpoint.restore(path, bad)
    assert "['layers'][0]['w']" in str(ei.value)
    assert "(4, 2)" in str(ei.value) and "(4, 3)" in str(ei.value)


def test_restore_rejects_dtype_mismatch_naming_path(tmp_path):
    """The int-step-counter-restored-as-float corruption, pinned."""
    path = str(tmp_path / "ckpt")
    checkpoint.save(path, params())
    with pytest.raises(ValueError) as ei:
        checkpoint.restore(path, params(step=jnp.float32(3)))
    msg = str(ei.value)
    assert "['step']" in msg and "int32" in msg and "float32" in msg


def test_save_accepts_plain_host_leaves(tmp_path):
    """numpy / python scalars have no is_fully_addressable — the
    multihost guard must not trip over them."""
    path = str(tmp_path / "ckpt")
    tree = {"a": np.arange(3), "b": 1.5}
    checkpoint.save(path, tree)
    out = checkpoint.restore(path, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), tree["a"])


@pytest.mark.slow
def test_multiproc_save_contract():
    harness.run_multiproc("check_checkpoint_multiproc.py", n_processes=2,
                          devices_per_process=4, timeout=600)
