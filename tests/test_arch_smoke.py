"""Per-architecture smoke tests: REDUCED variant (≤2 eff. layers, d≤512,
≤4 experts), one forward + one train step on CPU; asserts shapes + no NaNs.
Decode smoke: prefill + 2 decode steps consistent shapes/finiteness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.configs import INPUT_SHAPES, get_config, list_archs
from repro.data import SyntheticLM
from repro.models import transformer as T
from repro.train import init_train_state, make_train_step

ARCHS = list_archs()


def _batch(cfg, b=2, s=32, seed=0):
    data = SyntheticLM(cfg.vocab_size, seed=seed)
    item = next(data.batches(b, s, cfg))
    return {k: jnp.asarray(v) for k, v in item.items()}


def test_registry_complete():
    assert len(ARCHS) == 10
    kinds = {get_config(a).arch_type for a in ARCHS}
    assert kinds == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "deepseek-v2-lite-16b": (27, 2048, 16, 16, 10944, 102400),
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma2-9b": (42, 3584, 16, 8, 14336, 256000),
        "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.d_ff, cfg.vocab_size)
    assert got == spec, (arch, got, spec)
    assert cfg.source, f"{arch} missing source citation"


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_variant_bounds(arch):
    r = get_config(arch).reduced()
    assert r.d_model <= 512
    assert r.num_experts <= 4
    # ≤ 2 effective layers (hybrid needs one full period)
    assert r.num_layers <= max(2, 2 * max(1, r.hybrid_attn_every))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)
    logits, aux = T.forward(params, cfg, batch["tokens"],
                            batch.get("prefix"))
    off = cfg.num_prefix_embeddings if cfg.modality else 0
    assert logits.shape == (2, 32 + off, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: NaN in logits"

    opt = optim.adamw(1e-3)
    state = init_train_state(params, opt)
    step = make_train_step(cfg, opt, donate=False)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    assert int(state.step) == 1
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    b, sp = 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, sp), 0,
                                cfg.vocab_size)
    prefix = None
    off = 0
    if cfg.modality:
        off = cfg.num_prefix_embeddings
        prefix = jax.random.normal(jax.random.PRNGKey(2),
                                   (b, off, cfg.d_model))
    logits, caches = T.prefill(params, cfg, tokens, prefix,
                               max_len=sp + off + 4)
    assert bool(jnp.isfinite(logits).all())
    for t in range(2):
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        logits, caches = T.decode_step(params, cfg, tok, caches)
        assert logits.shape == (b, 1, cfg.vocab_size)
        assert bool(jnp.isfinite(logits).all()), f"{arch} step {t}"


@pytest.mark.parametrize("arch", sorted(["gemma2-9b", "mamba2-1.3b",
                                         "zamba2-2.7b"]))
def test_long_context_ring_cache_decode(arch):
    """The sub-quadratic archs decode with long_context caches (ring window
    for gemma2 local layers; O(1) state for SSM)."""
    cfg = get_config(arch).reduced()
    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    b = 1
    sp = 96 if cfg.sliding_window else 24   # exceed the reduced window (64)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, sp), 0,
                                cfg.vocab_size)
    logits, caches = T.prefill(params, cfg, tokens, max_len=sp + 8,
                               long_context=True)
    for _ in range(3):
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        logits, caches = T.decode_step(params, cfg, tok, caches)
        assert bool(jnp.isfinite(logits).all())


def test_gemma2_window_ring_matches_dense_cache():
    """Ring-buffer decode == dense-cache decode while within the window."""
    cfg = get_config("gemma2-9b").reduced()
    params = T.init_transformer(jax.random.PRNGKey(0), cfg)
    b, sp, n_gen = 1, 16, 4
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, sp + n_gen), 0,
                                cfg.vocab_size)
    lo_d, caches_d = T.prefill(params, cfg, tokens[:, :sp],
                               max_len=sp + n_gen)
    lo_r, caches_r = T.prefill(params, cfg, tokens[:, :sp],
                               max_len=sp + n_gen, long_context=True)
    np.testing.assert_allclose(np.asarray(lo_d), np.asarray(lo_r),
                               atol=1e-4)
    for t in range(n_gen):
        tok = tokens[:, sp + t: sp + t + 1]
        ld, caches_d = T.decode_step(params, cfg, tok, caches_d)
        lr, caches_r = T.decode_step(params, cfg, tok, caches_r)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(lr),
                                   atol=1e-4)


def test_param_counts_in_expected_range():
    """Sanity: param_count should land near the nameplate sizes."""
    expect = {
        "minitron-8b": (6e9, 10.5e9),
        "qwen1.5-4b": (3e9, 5e9),
        "gemma2-9b": (7.5e9, 11e9),
        "internlm2-1.8b": (1.4e9, 2.3e9),
        "mamba2-1.3b": (0.9e9, 1.7e9),
        "deepseek-v2-lite-16b": (12e9, 18e9),
        "zamba2-2.7b": (2.0e9, 3.4e9),
        "granite-moe-1b-a400m": (0.8e9, 1.7e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)
    # MoE active params well below total
    ds = get_config("deepseek-v2-lite-16b")
    assert ds.active_param_count() < 0.35 * ds.param_count()
