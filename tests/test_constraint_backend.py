"""Backend equivalence: ``engine(..., backend="constraint")`` (jit +
sharding constraints, runtime/constraint.py) vs the explicit shard_map
backend.

The real 8-worker check runs as a subprocess (pinned XLA_FLAGS, see
conftest.run_dist_prog); the fast tests here cover the single-device
fallback (constraints on a 1-device mesh are degenerate but exercise the
same code path) and the engine's dispatch/validation surface.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import max_tree_diff, run_dist_prog
from repro.core import decouple as D
from repro.gnn import dp_baseline as DP
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.runtime import constrain, current_mesh, engine, tp_mesh


@pytest.fixture(scope="module")
def setup():
    data = sbm_power_law(n=500, num_classes=5, feat_dim=24, avg_degree=8,
                         seed=0)
    bundle = D.prepare_bundle(data, n_workers=1, n_chunks=3)
    return data, bundle, tp_mesh(1)


@pytest.mark.parametrize("mode", ["decoupled", "decoupled_pipelined",
                                  "naive"])
def test_single_device_losses_and_grads_match(setup, mode):
    data, bundle, mesh = setup
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=32,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    le, ge = jax.value_and_grad(D.make_tp_loss_fn(
        cfg, bundle, mesh, mode=mode, backend="explicit"))(
        params, bundle.train_mask)
    lc, gc = jax.value_and_grad(D.make_tp_loss_fn(
        cfg, bundle, mesh, mode=mode, backend="constraint"))(
        params, bundle.train_mask)
    assert abs(float(le) - float(lc)) < 1e-5
    assert max_tree_diff(ge, gc) < 1e-5


def test_single_device_dp_matches(setup):
    data, bundle, mesh = setup
    dp_bundle = DP.prepare_dp_bundle(data, k=1)
    cfg = M.GNNConfig(model="gcn", in_dim=24, hidden_dim=32, num_classes=5,
                      num_layers=2, decoupled=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    le, ge = jax.value_and_grad(DP.make_dp_loss_fn(
        cfg, dp_bundle, mesh, backend="explicit"))(
        params, dp_bundle.train_mask)
    lc, gc = jax.value_and_grad(DP.make_dp_loss_fn(
        cfg, dp_bundle, mesh, backend="constraint"))(
        params, dp_bundle.train_mask)
    assert abs(float(le) - float(lc)) < 1e-5
    assert max_tree_diff(ge, gc) < 1e-5


def test_constraint_training_converges(setup):
    from repro import optim
    data, bundle, mesh = setup
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=32,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(1e-2)
    step, ev = D.make_tp_train_fns(cfg, bundle, mesh, opt,
                                   mode="decoupled", backend="constraint")
    p, o = params, opt.init(params)
    losses = []
    for _ in range(25):
        p, o, loss = step(p, o)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5
    _, acc = ev(p, "test")
    assert float(acc) > 0.8


def test_engine_backend_dispatch_and_validation():
    mesh = tp_mesh(1)
    with pytest.raises(ValueError, match="backend"):
        engine(lambda x: x, in_specs=(P(),), out_specs=P(), mesh=mesh,
               backend="bogus")
    # bad axis names fail eagerly on the constraint backend too
    with pytest.raises(ValueError, match="nope"):
        engine(lambda x: x, in_specs=(P("nope"),), out_specs=P(),
               mesh=mesh, backend="constraint")
    f = engine(lambda x: x * 2.0, in_specs=(P("model", None),),
               out_specs=P("model", None), mesh=mesh, backend="constraint")
    x = jnp.ones((4, 4))
    np.testing.assert_allclose(f(x), x * 2.0)


def test_constrain_is_noop_outside_engine():
    assert current_mesh() is None
    x = jnp.ones((4, 4))
    assert constrain(x, P("model", None)) is x


@pytest.mark.slow
def test_constraint_backend_8_workers():
    # compiles grads of both backends for GCN+GAT × 3 modes + DP: the
    # heaviest dist prog, give it headroom over the default 600 s
    run_dist_prog("check_constraint_backend.py", timeout=1500)
