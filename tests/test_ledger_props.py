"""Round-trip and algebraic properties of the CommLedger serialization
(``as_dict``/``from_dict``) and the coordinator-side ``merge_from`` —
the path multihost telemetry travels (each process serializes its
trace-time ledger; the coordinator rebuilds and merges, PR 5).

Deterministic cases always run; the randomized sweeps additionally run
when the optional hypothesis dep is installed (same convention as
tests/test_properties.py, but without skipping the whole module — the
deterministic half is the tier-1 coverage).
"""
import dataclasses

import pytest

from repro.runtime import telemetry as T

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="randomized sweep needs optional "
    "hypothesis dep (deterministic cases cover the invariants)")

OPS = ("psum", "all_gather", "all_to_all", "ppermute", "psum_scatter")


def _ledger(entries):
    """entries: [(op, axes, dtype, payload, wire, calls, mirror)]"""
    led = T.CommLedger()
    for op, axes, dtype, payload, wire, calls, mirror in entries:
        led.add(op, axes, dtype, payload=payload, wire=wire,
                calls=calls, mirror=mirror)
    return led


# Integer-valued counters so merge-associativity asserts exactly (float
# addition of integers this small is exact in binary64).
_SAMPLE_A = [
    ("all_to_all", "model", "float32", 1024.0, 896.0, 4.0, True),
    ("all_gather", ("model", "data"), "float32", 64.0, 448.0, 2.0, False),
    ("psum", "data", "float32", 4.0, 6.0, 1.0, False),
]
_SAMPLE_B = [
    ("all_to_all", "model", "float32", 512.0, 448.0, 2.0, False),
    ("ppermute", "model", "bfloat16", 256.0, 256.0, 8.0, True),
]
_SAMPLE_C = [
    ("psum_scatter", "data", "float32", 128.0, 896.0, 1.0, False),
    ("psum", "data", "float32", 4.0, 6.0, 3.0, False),
]


def _totals(led):
    """The scalar totals merge must be linear over."""
    return (led.wire_bytes(), led.wire_bytes(train=True),
            led.payload_bytes(), led.call_count(),
            led.call_count(train=True), len(led))


# ---------------------------------------------------------------------------
# round-trip identity
# ---------------------------------------------------------------------------

def test_round_trip_identity_deterministic():
    led = _ledger(_SAMPLE_A + _SAMPLE_B)
    back = T.CommLedger.from_dict(led.as_dict())
    assert back.as_dict() == led.as_dict()
    assert back.entries() == led.entries()


def test_round_trip_preserves_every_counter_field():
    led = _ledger(_SAMPLE_A)
    back = T.CommLedger.from_dict(led.as_dict())
    for key, entry in led.entries().items():
        assert dataclasses.asdict(back.entries()[key]) == \
            dataclasses.asdict(entry)


def test_round_trip_multi_axis_keys():
    # '+'-joined labels survive the '|' key encoding
    led = _ledger([("psum", ("model", "data"), "float32",
                    8.0, 12.0, 1.0, False)])
    back = T.CommLedger.from_dict(led.as_dict())
    assert back.call_count("psum", "model") == 1.0
    assert back.call_count("psum", "data") == 1.0


def test_from_dict_rejects_malformed_keys():
    with pytest.raises(T.TelemetryError, match="malformed"):
        T.CommLedger.from_dict({"no-pipes-here": {}})


# ---------------------------------------------------------------------------
# merge algebra over the totals
# ---------------------------------------------------------------------------

def test_merge_totals_are_sums():
    a, b = _ledger(_SAMPLE_A), _ledger(_SAMPLE_B)
    merged = T.CommLedger().merge_from(a).merge_from(b)
    for i, (ta, tb, tm) in enumerate(zip(_totals(a), _totals(b),
                                         _totals(merged))):
        if i == len(_totals(a)) - 1:      # len: union of keys, not sum
            continue
        assert tm == ta + tb, i


def test_merge_commutative():
    ab = T.CommLedger().merge_from(_ledger(_SAMPLE_A)) \
                       .merge_from(_ledger(_SAMPLE_B))
    ba = T.CommLedger().merge_from(_ledger(_SAMPLE_B)) \
                       .merge_from(_ledger(_SAMPLE_A))
    assert ab.as_dict() == ba.as_dict()


def test_merge_associative():
    a, b, c = _SAMPLE_A, _SAMPLE_B, _SAMPLE_C
    left = T.CommLedger().merge_from(
        T.CommLedger().merge_from(_ledger(a)).merge_from(_ledger(b))
    ).merge_from(_ledger(c))
    right = T.CommLedger().merge_from(_ledger(a)).merge_from(
        T.CommLedger().merge_from(_ledger(b)).merge_from(_ledger(c)))
    assert left.as_dict() == right.as_dict()


def test_merge_identity_element():
    a = _ledger(_SAMPLE_A)
    merged = T.CommLedger().merge_from(a).merge_from(T.CommLedger())
    assert merged.as_dict() == a.as_dict()


def test_merge_does_not_mutate_source():
    a, b = _ledger(_SAMPLE_A), _ledger(_SAMPLE_B)
    before = b.as_dict()
    a.merge_from(b)
    assert b.as_dict() == before


def test_transitions_are_trace_local():
    # TransitionRecords are evidence for the jaxpr audit of THIS trace —
    # they do not serialize and do not merge
    led = T.CommLedger()
    led.add_transition(T.TransitionRecord(
        (64, 8), "float32", ("model",), (None, "model"),
        calls=1.0, mirror=True, anchored=True))
    assert "transitions" not in str(led.as_dict())
    back = T.CommLedger.from_dict(led.as_dict())
    assert back.transitions() == ()
    other = T.CommLedger().merge_from(led)
    assert other.transitions() == ()
    assert led.transitions()[0].anchored


# ---------------------------------------------------------------------------
# randomized sweeps (optional hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _entry = st.tuples(
        st.sampled_from(OPS),
        st.sampled_from(["model", "data", ("model", "data")]),
        st.sampled_from(["float32", "bfloat16"]),
        st.integers(0, 2**20).map(float),      # payload
        st.integers(0, 2**20).map(float),      # wire
        st.integers(1, 64).map(float),         # calls
        st.booleans())
    _entries = st.lists(_entry, max_size=8)

    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(_entries)
    def test_round_trip_identity_random(entries):
        led = _ledger(entries)
        assert T.CommLedger.from_dict(led.as_dict()).as_dict() == \
            led.as_dict()

    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(_entries, _entries)
    def test_merge_commutative_random(ea, eb):
        ab = T.CommLedger().merge_from(_ledger(ea)).merge_from(_ledger(eb))
        ba = T.CommLedger().merge_from(_ledger(eb)).merge_from(_ledger(ea))
        assert ab.as_dict() == ba.as_dict()

    @needs_hypothesis
    @settings(max_examples=25, deadline=None)
    @given(_entries, _entries, _entries)
    def test_merge_associative_random(ea, eb, ec):
        def L(e):
            return _ledger(e)
        left = T.CommLedger().merge_from(
            T.CommLedger().merge_from(L(ea)).merge_from(L(eb))
        ).merge_from(L(ec))
        right = T.CommLedger().merge_from(L(ea)).merge_from(
            T.CommLedger().merge_from(L(eb)).merge_from(L(ec)))
        assert left.as_dict() == right.as_dict()
