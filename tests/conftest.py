import os
import sys

# src/ layout import path (tests also work without `pip install -e .`)
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, SRC)
# tests/ itself, so the dist_progs harness is importable as a module
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from dist_progs import harness  # noqa: E402

# NOTE: no XLA_FLAGS device-count forcing here — unit tests and benches run
# on the single real CPU device.  Multi-device behaviour is covered by the
# subprocess checks under tests/dist_progs/, launched via the harness
# (tests/dist_progs/harness.py): ``run_dist_prog`` below is its N=1
# (single-process) case, and ``harness.run_multiproc`` spawns the real
# N-process ``jax.distributed`` topology with a localhost coordinator
# (tests/test_multihost.py).  Children pin XLA_FLAGS so the
# runtime-engine collectives execute across real device buffers.

#: The one place the forced device count is spelled; the dist_progs assert
#: they were launched with exactly this value.
DIST_XLA_FLAGS = harness.xla_flags(harness.DEFAULT_DEVICES)

PROGS = harness.PROGS


def max_tree_diff(a, b) -> float:
    """Largest elementwise |a−b| over two pytrees of arrays.

    Goes through numpy so operands committed to *different* meshes (a
    single-device reference vs an 8-device run) can be compared — jnp
    binary ops refuse mixed device sets.
    """
    import jax
    import numpy as np

    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.abs(np.asarray(x) - np.asarray(y)).max()),
        a, b)))


def run_dist_prog(name: str, timeout: int = 600) -> None:
    """Run tests/dist_progs/<name> as a child with pinned XLA_FLAGS —
    the N=1 case of :func:`dist_progs.harness.run_multiproc`."""
    harness.run_multiproc(name, n_processes=1,
                          devices_per_process=harness.DEFAULT_DEVICES,
                          timeout=timeout, check=True)
