import os
import sys

# src/ layout import path (tests also work without `pip install -e .`)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: no XLA_FLAGS device-count forcing here — unit tests and benches run
# on the single real CPU device.  Multi-device behaviour is covered by the
# subprocess tests in test_distributed.py, which set
# --xla_force_host_platform_device_count=8 for their child processes only.
