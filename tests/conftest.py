import os
import subprocess
import sys

# src/ layout import path (tests also work without `pip install -e .`)
SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, SRC)

# NOTE: no XLA_FLAGS device-count forcing here — unit tests and benches run
# on the single real CPU device.  Multi-device behaviour is covered by the
# subprocess checks under tests/dist_progs/, launched via ``run_dist_prog``
# below, whose children pin DIST_XLA_FLAGS so the runtime-engine
# collectives (all_to_all gather/split, halo exchange, psum) execute
# across 8 real device buffers.

#: The one place the forced device count is spelled; the dist_progs assert
#: they were launched with exactly this value.
DIST_XLA_FLAGS = "--xla_force_host_platform_device_count=8"

PROGS = os.path.join(os.path.dirname(__file__), "dist_progs")


def max_tree_diff(a, b) -> float:
    """Largest elementwise |a−b| over two pytrees of arrays.

    Goes through numpy so operands committed to *different* meshes (a
    single-device reference vs an 8-device run) can be compared — jnp
    binary ops refuse mixed device sets.
    """
    import jax
    import numpy as np

    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(np.abs(np.asarray(x) - np.asarray(y)).max()),
        a, b)))


def run_dist_prog(name: str, timeout: int = 600) -> None:
    """Run tests/dist_progs/<name> as a child with pinned XLA_FLAGS."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = DIST_XLA_FLAGS
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(PROGS, name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, \
        f"{name} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert proc.stdout.strip().endswith(f"OK {name[:-3]}")
