"""Property-based tests (hypothesis) for the system's invariants.

Each property is a system invariant the design relies on:
  * layout round-trips (the paper's gather/split must be lossless)
  * mesh factory contracts (exact device accounting — no silent
    truncation — and the hybrid DP×TP model-major vertex layout)
  * online-softmax streaming == monolithic softmax (flash/ring kernels)
  * blockwise/flash attention == dense oracle under arbitrary raggedness
  * chunked aggregation == monolithic (chunk scheduling §4.2)
  * graph normalization spectral bound (convergence theorem §4.1.3)
  * MoE dispatch conservation (combine weights, dropless totals)
  * loss invariants (shift-invariance of the vocab-sharded lse form)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional hypothesis dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

SET = dict(max_examples=15, deadline=None)


# ---------------------------------------------------------------------------
# gather/split layout round-trip (single-host simulation of the a2a pair)
# ---------------------------------------------------------------------------

def _sim_split(vs, n):
    """Dense simulation of ``core.tp.split`` (tiled a2a, split_axis=1,
    concat_axis=0): worker j ends with h[:, j·d/n:(j+1)·d/n]."""
    d = vs.shape[-1]
    return jnp.stack([
        jnp.concatenate([vs[i][:, j * (d // n):(j + 1) * (d // n)]
                         for i in range(n)], axis=0) for j in range(n)])


def _sim_gather(ds, n):
    """Dense simulation of ``core.tp.gather`` (tiled a2a, split_axis=0,
    concat_axis=1): worker i ends with h[i·v/n:(i+1)·v/n, :]."""
    v = ds.shape[1]
    return jnp.stack([
        jnp.concatenate([ds[j][i * (v // n):(i + 1) * (v // n), :]
                         for j in range(n)], axis=1) for i in range(n)])


@settings(**SET)
@given(st.integers(1, 6), st.integers(1, 8), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_gather_split_roundtrip(n, v_mult, d_mult, seed):
    """gather ∘ split == identity on vertex-sharded layouts, and split
    lands every worker on its exact feature slice (paper §3.1).  The real
    collectives run in tests/dist_progs; this pins the index math."""
    v, d = n * v_mult, n * d_mult
    h = jax.random.normal(jax.random.PRNGKey(seed % 2**31), (v, d))
    vs = h.reshape(n, v // n, d)            # vertex-sharded: worker i rows
    ds = _sim_split(vs, n)
    for j in range(n):                      # dim-sharded: worker j cols
        np.testing.assert_array_equal(
            np.asarray(ds[j]),
            np.asarray(h[:, j * (d // n):(j + 1) * (d // n)]))
    back = _sim_gather(ds, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(vs))


# ---------------------------------------------------------------------------
# multi-axis mesh factory: exact device accounting + hybrid layout contract
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(1, 64), st.integers(1, 5), st.integers(1, 3),
       st.one_of(st.none(), st.integers(1, 8)))
def test_mesh_shape_resolution_never_truncates(n_devices, data, pod, model):
    """resolve_mesh_shape either consumes exactly n_devices or raises —
    the old make_host_mesh silently used devs[:data*model]."""
    from repro.runtime import resolve_mesh_shape
    try:
        p, d, m = resolve_mesh_shape(n_devices, model=model, data=data,
                                     pod=pod)
    except ValueError:
        # the request must be a genuine misfit, never a fixable-by-
        # truncation one that got refused arbitrarily
        if model is not None:
            assert pod * data * model != n_devices
        else:
            assert n_devices % (pod * data) != 0
        return
    assert (p, d) == (pod, data)
    assert p * d * m == n_devices
    if model is not None:
        assert m == model


@settings(**SET)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(1, 120),
       st.integers(1, 48))
def test_mesh_divisibility_contract_or_rectangular_error(n, dd, v, d):
    """Arbitrary (V, D, data, model) either satisfies the padding
    contract (vertices divide model·data, features divide model) or
    validate_divisible raises the rectangular-gather error."""
    from repro.runtime import TPMesh, tp_mesh

    class Fake(TPMesh):
        @property
        def size(self):
            return n

        @property
        def data_size(self):
            return dd

    fake = Fake(tp_mesh(1).mesh)
    fits = (v % (n * dd) == 0) and (d % n == 0)
    if fits:
        fake.validate_divisible(n_vertices=v, dim=d)
    else:
        with pytest.raises(ValueError, match="rectangular gather/split"):
            fake.validate_divisible(n_vertices=v, dim=d)


@settings(**SET)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4),
       st.integers(1, 4), st.integers(0, 2 ** 31 - 1))
def test_hybrid_vertex_layout_roundtrip(n, dd, v_mult, d_mult, seed):
    """The hybrid vertex layout is model-major over (model, data): a
    replica-gather must reconstruct each model worker's *contiguous*
    pure-TP shard, the gather/split round-trip holds on it, and a
    replica-slice lands every device back on its original block."""
    k = n * dd
    v, d = k * v_mult, n * d_mult
    h = jax.random.normal(jax.random.PRNGKey(seed % 2**31), (v, d))
    vk = v // k
    # device (replica r, model worker j) owns model-major block j·dd + r
    blocks = [[h[(j * dd + r) * vk:(j * dd + r + 1) * vk]
               for r in range(dd)] for j in range(n)]
    # replica_gather (concat over the data axis) → contiguous TP shards
    gathered = jnp.stack(
        [jnp.concatenate(blocks[j], axis=0) for j in range(n)])
    np.testing.assert_array_equal(
        np.asarray(gathered.reshape(v, d)), np.asarray(h))
    # the pure-TP split/gather round-trip on the reconstructed shards
    back = _sim_gather(_sim_split(gathered, n), n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(gathered))
    # replica_slice recovers each device's original rows
    for j in range(n):
        for r in range(dd):
            np.testing.assert_array_equal(
                np.asarray(back[j][r * vk:(r + 1) * vk]),
                np.asarray(blocks[j][r]))


# ---------------------------------------------------------------------------
# online softmax == monolithic (the flash/ring accumulation core)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(1, 5), st.integers(1, 12), st.integers(1, 7),
       st.integers(0, 2 ** 31 - 1))
def test_online_softmax_streaming(chunks, rows, cols_per_chunk, seed):
    key = jax.random.PRNGKey(seed % 2**31)
    s = jax.random.normal(key, (rows, chunks * cols_per_chunk)) * 10
    v = jax.random.normal(jax.random.split(key)[0],
                          (chunks * cols_per_chunk, 4))
    want = jax.nn.softmax(s, axis=-1) @ v

    m = jnp.full((rows,), -jnp.inf)
    l = jnp.zeros((rows,))
    acc = jnp.zeros((rows, 4))
    for c in range(chunks):
        sc = s[:, c * cols_per_chunk:(c + 1) * cols_per_chunk]
        vc = v[c * cols_per_chunk:(c + 1) * cols_per_chunk]
        m_new = jnp.maximum(m, sc.max(axis=1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(sc - m_new[:, None])
        l = l * alpha + p.sum(axis=1)
        acc = acc * alpha[:, None] + p @ vc
        m = m_new
    got = acc / l[:, None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# blockwise + flash kernel == dense oracle under hypothesis-drawn shapes
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(8, 70), st.integers(1, 3), st.integers(0, 1),
       st.sampled_from([8, 16, 24]), st.integers(0, 2 ** 31 - 1))
def test_blockwise_attention_matches_dense(seq, g, windowed, block, seed):
    from repro.nn.attention import attention_blockwise, attention_core, \
        _causal_mask, _window_mask
    hkv, hd = 2, 8
    hq = hkv * g
    key = jax.random.PRNGKey(seed % 2**31)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, seq, hq, hd))
    k = jax.random.normal(ks[1], (1, seq, hkv, hd))
    v = jax.random.normal(ks[2], (1, seq, hkv, hd))
    window = 16 if windowed else None
    got = attention_blockwise(q, k, v, causal=True, window=window,
                              block_q=block, block_kv=block)
    mask = (_window_mask(seq, seq, 0, window) if window
            else _causal_mask(seq, seq, 0))[None]
    want = attention_core(q, k, v, mask)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(st.integers(8, 48), st.integers(8, 48), st.integers(1, 4),
       st.sampled_from([8, 16]), st.integers(0, 2 ** 31 - 1))
def test_flash_kernel_matches_ref(sq, skv, g, block, seed):
    from repro.kernels.flash_attn import flash_attention
    from repro.kernels.flash_attn.ref import flash_ref
    hkv, hd = 2, 8
    key = jax.random.PRNGKey(seed % 2**31)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (1, sq, hkv * g, hd))
    k = jax.random.normal(ks[1], (1, skv, hkv, hd))
    v = jax.random.normal(ks[2], (1, skv, hkv, hd))
    got = flash_attention(q, k, v, causal=False, block_q=block,
                          block_kv=block, interpret=True)
    want = flash_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                     v.transpose(0, 2, 1, 3), causal=False
                     ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# chunked aggregation == monolithic (memory-efficient scheduling §4.2)
# ---------------------------------------------------------------------------

def _random_edges(n, deg, rng):
    e = max(1, n * deg)
    return (rng.integers(0, n, e, dtype=np.int32),
            rng.integers(0, n, e, dtype=np.int32))


@settings(**SET)
@given(st.integers(6, 60), st.integers(1, 6), st.integers(2, 10),
       st.integers(0, 2 ** 31 - 1))
def test_chunked_aggregation_matches(n, n_chunks, deg, seed):
    from repro.graph.format import build_graph, chunk_graph
    from repro.gnn import layers as L
    rng = np.random.default_rng(seed % 2**31)
    src, dst = _random_edges(n, deg, rng)
    g = build_graph(src, dst, n)
    h = jnp.asarray(rng.normal(size=(n, 5)), jnp.float32)
    mono = L.aggregate(L.edge_list_dev(g), h)
    chunked = L.aggregate_chunked(
        L.chunked_dev(chunk_graph(g, min(n_chunks, n))), h)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(mono),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Â spectral bound — the convergence theorem's premise (§4.1.3)
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(2, 40), st.integers(1, 6), st.integers(0, 2 ** 31 - 1))
def test_sym_norm_adjacency_spectral_radius_le_1(n, deg, seed):
    from repro.graph.format import build_graph
    rng = np.random.default_rng(seed % 2**31)
    src, dst = _random_edges(n, deg, rng)
    g = build_graph(src, dst, n)
    a = np.asarray(g.dense_adjacency())
    eig = np.max(np.abs(np.linalg.eigvals(a)))
    assert eig <= 1.0 + 1e-5, eig


# ---------------------------------------------------------------------------
# MoE dispatch conservation
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3), st.integers(4, 40),
       st.integers(0, 2 ** 31 - 1))
def test_moe_dropless_conserves_tokens(e_pow, k, tokens, seed):
    """Dropless MoE: every token's output = Σ_k p_k · expert_k(token) —
    identical to the dense per-token oracle."""
    import dataclasses
    from repro.configs import get_config
    from repro.nn import moe as moe_lib
    cfg = get_config("granite-moe-1b-a400m").reduced()
    e = 2 ** e_pow
    cfg = dataclasses.replace(cfg, num_experts=e,
                              num_experts_per_tok=min(k, e),
                              num_shared_experts=0, d_model=16, moe_d_ff=8)
    key = jax.random.PRNGKey(seed % 2**31)
    p = moe_lib.init_moe(key, cfg)
    p = jax.tree.map(lambda l: l.value if hasattr(l, "value") else l, p,
                     is_leaf=lambda l: hasattr(l, "value"))
    x = jax.random.normal(jax.random.split(key)[0], (1, tokens, 16))
    y, _ = moe_lib.moe_apply(p, cfg, x, dropless=True)

    # dense oracle
    xf = x.reshape(tokens, 16)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    act = jax.nn.silu
    want = jnp.zeros_like(xf)
    for t in range(tokens):
        acc = jnp.zeros((16,))
        for j in range(cfg.num_experts_per_tok):
            eid = top_e[t, j]
            h = act(xf[t] @ p["gate"][eid]) * (xf[t] @ p["up"][eid])
            acc += top_p[t, j] * (h @ p["down"][eid])
        want = want.at[t].set(acc)
    np.testing.assert_allclose(np.asarray(y.reshape(tokens, 16)),
                               np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# loss: vocab-reduction form == naive cross-entropy, shift-invariant
# ---------------------------------------------------------------------------

@settings(**SET)
@given(st.integers(2, 32), st.integers(3, 50), st.floats(-50, 50),
       st.integers(0, 2 ** 31 - 1))
def test_lm_loss_matches_naive_and_shift_invariant(t, v, shift, seed):
    from repro.models.transformer import lm_loss
    key = jax.random.PRNGKey(seed % 2**31)
    logits = jax.random.normal(key, (1, t, v)) * 5
    targets = jax.random.randint(jax.random.split(key)[0], (1, t), 0, v)
    got = lm_loss(logits, targets)
    probs = jax.nn.log_softmax(logits, -1)
    want = -jnp.take_along_axis(probs, targets[..., None], -1).mean()
    np.testing.assert_allclose(float(got), float(want), rtol=1e-5,
                               atol=1e-5)
    got_shifted = lm_loss(logits + shift, targets)
    np.testing.assert_allclose(float(got_shifted), float(want), rtol=1e-4,
                               atol=1e-4)
