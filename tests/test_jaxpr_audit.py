"""Unit tests for the trace-time jaxpr collective audit
(:mod:`repro.analysis.jaxpr_audit`) on small synthetic programs — the
walk (sub-jaxprs, scan multipliers, while detection), the ledger diff
in both directions, and the constraint-backend checks.  Full four-mode
× two-backend engine coverage runs on 8 forced devices in
tests/dist_progs/check_telemetry.py (slow lane + ci.sh).
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import jaxpr_audit as A
from repro.runtime import collectives as C
from repro.runtime import telemetry as T
from repro.runtime.smap import smap

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="audit unit tests want >=4 forced host devices")

N = len(jax.devices())
AXIS = "model"


def _mesh():
    return jax.make_mesh((N,), (AXIS,))


def _traced(body, in_specs, out_specs, grad=False):
    """(jaxpr, ledger) of body smapped over the test mesh."""
    f = smap(body, _mesh(), in_specs, out_specs)
    if grad:
        g = jax.value_and_grad(lambda x: f(x))
    else:
        g = f
    x = jnp.ones((8 * N, 4), jnp.float32)
    with T.collect_comm() as ledger:
        jxp = jax.make_jaxpr(g)(x)
    return jxp, ledger


# ---------------------------------------------------------------------------
# the walk
# ---------------------------------------------------------------------------

def test_counts_forward_collective():
    jxp, _ = _traced(lambda x: C.all_gather(x, AXIS, mirror=False),
                     P(AXIS), P())
    counts = A.collective_counts(jxp)
    assert counts == {("all_gather", AXIS, "float32"): 1.0}


def test_counts_autodiff_mirror_as_transposed_primitive():
    jxp, _ = _traced(
        lambda x: C.all_gather(x, AXIS, mirror=True).sum(),
        P(AXIS), P(), grad=True)
    counts = A.collective_counts(jxp)
    # forward all_gather + its transpose (reduce_scatter → psum_scatter)
    assert counts[("all_gather", AXIS, "float32")] == 1.0
    assert counts[("psum_scatter", AXIS, "float32")] == 1.0


def test_scan_trip_multiplier():
    perm = [(i, (i + 1) % N) for i in range(N)]

    def body(x):
        def step(c, _):
            return C.ppermute(c, AXIS, perm=perm, mirror=False), None
        with T.loop_scope(3):
            out, _ = jax.lax.scan(step, x, None, length=3)
        return out

    jxp, ledger = _traced(body, P(AXIS), P(AXIS))
    assert A.collective_counts(jxp) == {("ppermute", AXIS, "float32"): 3.0}
    assert not A.audit(jxp, ledger)


def test_nested_scan_multipliers_compose():
    perm = [(i, (i + 1) % N) for i in range(N)]

    def body(x):
        def inner(c, _):
            return C.ppermute(c, AXIS, perm=perm, mirror=False), None

        def outer(c, _):
            with T.loop_scope(2):
                out, _ = jax.lax.scan(inner, c, None, length=2)
            return out, None

        with T.loop_scope(3):
            out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    jxp, ledger = _traced(body, P(AXIS), P(AXIS))
    assert A.collective_counts(jxp) == {("ppermute", AXIS, "float32"): 6.0}
    assert not A.audit(jxp, ledger)


def test_while_body_collective_is_unbounded_finding():
    perm = [(i, (i + 1) % N) for i in range(N)]

    def body(x):
        def cond(c):
            return c[0].sum() < 100.0

        def step(c):
            return (jax.lax.ppermute(  # lint-ok: RT001 negative test
                c[0], AXIS, perm=perm),)
        return jax.lax.while_loop(cond, step, (x,))[0]

    jxp, ledger = _traced(body, P(AXIS), P(AXIS))
    findings = A.audit(jxp, ledger)
    assert [f.kind for f in findings] == ["unbounded_loop"]
    # and the unbounded collective is NOT double-reported as unledgered
    assert A.collective_counts(jxp) == {}


def test_empty_axes_psum_skipped():
    # value_and_grad of a plain jit fn emits psum{axes=()} equations;
    # they move no bytes and must not show up
    jxp = jax.make_jaxpr(jax.value_and_grad(
        lambda x: (x * x).sum()))(jnp.ones((4,)))
    assert A.collective_counts(jxp) == {}


# ---------------------------------------------------------------------------
# the diff
# ---------------------------------------------------------------------------

def test_clean_program_audits_clean():
    jxp, ledger = _traced(
        lambda x: C.all_to_all(x, AXIS, split_axis=0, concat_axis=1,
                               mirror=True).sum(),
        P(AXIS), P(), grad=True)
    assert A.audit(jxp, ledger) == []
    A.assert_clean(jxp, ledger, tag="unit")    # and the raising form


def test_unledgered_collective_detected():
    perm = [(i, (i + 1) % N) for i in range(N)]
    jxp, ledger = _traced(
        lambda x: jax.lax.ppermute(  # lint-ok: RT001 negative test
            x, AXIS, perm=perm),
        P(AXIS), P(AXIS))
    findings = A.audit(jxp, ledger)
    assert [f.kind for f in findings] == ["unledgered_collective"]
    assert findings[0].op == "ppermute" and findings[0].actual == 1.0
    with pytest.raises(AssertionError, match="unledgered_collective"):
        A.assert_clean(jxp, ledger, tag="unit")


def test_missing_loop_scope_shows_as_undercount():
    perm = [(i, (i + 1) % N) for i in range(N)]

    def body(x):
        def step(c, _):
            return C.ppermute(c, AXIS, perm=perm, mirror=False), None
        out, _ = jax.lax.scan(  # lint-ok: RT004 negative test
            step, x, None, length=3)
        return out

    jxp, ledger = _traced(body, P(AXIS), P(AXIS))
    findings = A.audit(jxp, ledger)
    assert [f.kind for f in findings] == ["unledgered_collective"]
    assert findings[0].expected == 1.0 and findings[0].actual == 3.0


def test_phantom_ledger_entry_detected():
    jxp, ledger = _traced(lambda x: C.all_gather(x, AXIS, mirror=False),
                          P(AXIS), P())
    fake = T.CommLedger.from_dict(ledger.as_dict())
    fake.add("ppermute", AXIS, "float32", payload=1.0, wire=1.0)
    findings = A.audit(jxp, fake)
    assert [f.kind for f in findings] == ["phantom_ledger_entry"]
    assert findings[0].op == "ppermute"


def test_wrong_mirror_declaration_is_phantom():
    # mirror=True on a non-differentiated path: ledger promises a
    # backward psum_scatter the program never contains
    jxp, ledger = _traced(lambda x: C.all_gather(x, AXIS, mirror=True),
                          P(AXIS), P())   # no grad
    findings = A.audit(jxp, ledger)
    assert [f.kind for f in findings] == ["phantom_ledger_entry"]
    assert findings[0].op == "psum_scatter"


def test_backward_param_psums_tolerated():
    # psum is one-directional: jaxpr-side surplus (grad all-reduces with
    # no forward counterpart) is fine...
    def body(x):
        return C.psum(x.sum(), AXIS)

    jxp, ledger = _traced(body, P(AXIS), P(), grad=True)
    assert A.audit(jxp, ledger) == []
    # ...but ledger-side surplus is still a phantom
    fake = T.CommLedger.from_dict(ledger.as_dict())
    fake.add("psum", AXIS, "float32", payload=4.0, wire=8.0, calls=5.0)
    assert [f.kind for f in A.audit(jxp, fake)] == ["phantom_ledger_entry"]


# ---------------------------------------------------------------------------
# constraint backend
# ---------------------------------------------------------------------------

def test_constraint_program_with_collective_flagged():
    jxp, ledger = _traced(lambda x: C.all_gather(x, AXIS, mirror=False),
                          P(AXIS), P())
    findings = A.audit(jxp, ledger, backend="constraint")
    assert any(f.kind == "collective_in_constraint_program"
               for f in findings)


def test_constraint_anchored_transitions_verified():
    from repro.runtime import constraint as K

    mesh = _mesh()
    dst, src = P(None, AXIS), P(AXIS)

    def body(x):
        return K.layout_cast(x, dst, src, mirror=False)

    x = jnp.ones((8 * N, 4), jnp.float32)
    with K.mesh_context(mesh):
        with T.collect_comm() as ledger:
            jxp = jax.make_jaxpr(body)(x)
    recs = ledger.transitions()
    assert len(recs) == 1 and recs[0].anchored
    assert recs[0].src_spec == (AXIS,)
    assert recs[0].dst_spec == (None, AXIS)
    A.assert_clean(jxp, ledger, backend="constraint", tag="unit")

    # drop the program's constraints → missing_constraint findings
    jxp_bare = jax.make_jaxpr(lambda v: v * 1.0)(x)
    findings = A.audit(jxp_bare, ledger, backend="constraint")
    assert {f.kind for f in findings} == {"missing_constraint"}
    assert len(findings) == 2      # src and dst side


def test_unanchored_note_transition_not_required():
    # raw constrain-pair sites record anchored=False — audit must not
    # demand constraint equations for them
    from repro.runtime import constraint as K

    mesh = _mesh()
    x = jnp.ones((8 * N, 4), jnp.float32)
    with K.mesh_context(mesh):
        with T.collect_comm() as ledger:
            K.note_transition(x, P(AXIS), P(None, AXIS), mirror=False)
    jxp = jax.make_jaxpr(lambda v: v * 1.0)(x)
    assert A.audit(jxp, ledger, backend="constraint") == []


# ---------------------------------------------------------------------------
# plumbing
# ---------------------------------------------------------------------------

def test_finding_format_mentions_counts():
    f = A.AuditFinding("unledgered_collective", "ppermute", AXIS,
                       2.0, 3.0, "extra")
    s = f.format()
    assert "ledger=2" in s and "jaxpr=3" in s and "extra" in s


def test_expected_from_ledger_mirror_mapping():
    led = T.CommLedger()
    led.add("all_gather", AXIS, "float32", payload=1.0, wire=1.0,
            mirror=True)
    exp = A.expected_from_ledger(led)
    assert exp[("all_gather", AXIS, "float32")] == 1.0
    assert exp[("psum_scatter", AXIS, "float32")] == 1.0
