"""Graph substrate: formats, normalization, chunking, partitioners."""
import numpy as np
import pytest

from repro.graph import (Graph, build_graph, chunk_graph, block_sparse,
                         block_sparse_transpose, rect_block_sparse,
                         chunk_block_sparse, stack_plans,
                         sbm_power_law, barabasi_albert, chunk_partition,
                         hash_partition, greedy_edge_cut_partition,
                         workload_stats, tensor_parallel_stats, halo_plan)


def small_graph(n=50, seed=0):
    rng = np.random.default_rng(seed)
    e = 6 * n
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    return build_graph(src, dst, n)


def test_build_graph_sorted_and_self_loops():
    g = small_graph()
    assert np.all(np.diff(g.dst) >= 0)
    # self loops present
    self_edges = g.src == g.dst
    assert self_edges.sum() == g.n
    # CSR consistency
    assert g.indptr[-1] == g.e
    for v in [0, 7, 23, g.n - 1]:
        seg = g.dst[g.indptr[v]:g.indptr[v + 1]]
        assert np.all(seg == v)


def test_sym_normalization_weights():
    g = small_graph()
    deg_in = g.in_degrees().astype(np.float64)
    deg_out = g.out_degrees().astype(np.float64)
    expect = 1.0 / np.sqrt(deg_in[g.dst] * deg_out[g.src])
    np.testing.assert_allclose(g.weight, expect, rtol=1e-6)


def test_mean_normalization_rows_sum_to_one():
    rng = np.random.default_rng(1)
    n = 40
    src = rng.integers(0, n, 200).astype(np.int32)
    dst = rng.integers(0, n, 200).astype(np.int32)
    g = build_graph(src, dst, n, normalization="mean")
    a = g.dense_adjacency()
    np.testing.assert_allclose(a.sum(axis=1), 1.0, rtol=1e-5)


@pytest.mark.parametrize("n_chunks", [1, 2, 3, 5])
def test_chunk_graph_covers_all_edges(n_chunks):
    g = small_graph(60, seed=2)
    cg = chunk_graph(g, n_chunks)
    # reconstruct dense adjacency from chunks
    a = np.zeros((cg.n_chunks * cg.chunk_size, g.n), np.float32)
    for c in range(cg.n_chunks):
        lo = c * cg.chunk_size
        for s, d, w in zip(cg.src[c], cg.dst_local[c], cg.weight[c]):
            if d < cg.chunk_size and w != 0:
                a[lo + d, s] += w
    np.testing.assert_allclose(a[: g.n], g.dense_adjacency(), rtol=1e-6)


def test_chunk_new_src_dedup_union_and_disjoint():
    g = small_graph(80, seed=3)
    cg = chunk_graph(g, 4)
    seen = set()
    for c in range(cg.n_chunks):
        fresh = cg.new_src[c][: cg.new_src_count[c]].tolist()
        assert not (set(fresh) & seen), "src communicated twice"
        seen |= set(fresh)
        # every src used by this chunk was communicated by some chunk <= c
        used = {int(s) for s, w in zip(cg.src[c], cg.weight[c]) if w != 0}
        assert used <= seen
    all_srcs = set(g.src.tolist())
    assert seen == all_srcs


@pytest.mark.parametrize("bs", [16, 32])
def test_block_sparse_equals_dense(bs):
    g = small_graph(70, seed=4)
    bsg = block_sparse(g, bs=bs)
    dense = np.zeros((bsg.n_padded, bsg.n_padded), np.float32)
    for k in range(bsg.nnzb):
        bi, bj = bsg.block_rows[k], bsg.block_cols[k]
        dense[bi * bs:(bi + 1) * bs, bj * bs:(bj + 1) * bs] += bsg.blocks[k]
    ref = g.dense_adjacency()
    np.testing.assert_allclose(dense[: g.n, : g.n], ref, rtol=1e-6)
    # row_first flags: exactly one per distinct destination block row
    assert bsg.row_first.sum() == len(np.unique(bsg.block_rows))
    assert np.all(np.diff(bsg.block_rows) >= 0)


def test_partitioners_and_stats():
    data = barabasi_albert(n=800, m=6, seed=0)
    g = data.graph
    for part in (chunk_partition(g, 4), hash_partition(g, 4),
                 greedy_edge_cut_partition(g, 4, passes=1)):
        assert part.owner.shape == (g.n,)
        assert part.owner.min() >= 0 and part.owner.max() < 4
        st = workload_stats(g, part)
        assert st.edges.sum() == g.e
        assert st.compute_imbalance >= 1.0
    # TP stats: perfectly balanced by construction
    st = tensor_parallel_stats(g, 4, d=64)
    assert st.compute_imbalance == 1.0 and st.comm_imbalance == 1.0
    # power-law chunk partitioning should show imbalance > TP's 1.0
    st_chunk = workload_stats(g, chunk_partition(g, 4))
    assert st_chunk.compute_imbalance > 1.0


def test_halo_plan_consistency():
    data = sbm_power_law(n=200, seed=1)
    g = data.graph
    part = chunk_partition(g, 4)
    plan = halo_plan(g, part)
    # every remote src of worker i appears exactly once in the recv plan
    for i in range(4):
        lo, hi = part.bounds[i], part.bounds[i + 1]
        e_lo, e_hi = g.indptr[lo], g.indptr[hi]
        s = g.src[e_lo:e_hi]
        remote = np.unique(s[(s < lo) | (s >= hi)])
        planned = plan.send_idx[:, i][plan.send_idx[:, i] >= 0]
        assert set(planned.tolist()) == set(remote.tolist())
        # owners actually own what they send
        for j in range(4):
            rows = plan.send_idx[j, i][plan.send_idx[j, i] >= 0]
            assert np.all(part.owner[rows] == j)


def _tiles_dense(block_rows, block_cols, blocks, n_row_blocks,
                 n_col_blocks, bs):
    """Reconstruct the dense slice a tile list encodes."""
    dense = np.zeros((n_row_blocks * bs, n_col_blocks * bs), np.float32)
    for k in range(len(block_rows)):
        bi, bj = block_rows[k], block_cols[k]
        dense[bi * bs:(bi + 1) * bs, bj * bs:(bj + 1) * bs] += blocks[k]
    return dense


def _plan_dense(plan, c=None, transpose=False):
    """Dense (rows_padded, cols_padded) slice of plan instance ``c``."""
    sel = (lambda a: a) if c is None else (lambda a: a[c])
    if transpose:
        return _tiles_dense(sel(plan.block_rows_t), sel(plan.block_cols_t),
                            sel(plan.blocks_t), plan.cols_padded // plan.bs,
                            plan.rows_padded // plan.bs, plan.bs)
    return _tiles_dense(sel(plan.block_rows), sel(plan.block_cols),
                        sel(plan.blocks), plan.rows_padded // plan.bs,
                        plan.cols_padded // plan.bs, plan.bs)


def test_block_sparse_duplicate_edges_accumulate():
    """Parallel edges hitting the same tile element must SUM — the
    buffered fancy-index ``+=`` silently kept only one contribution."""
    dst = np.array([0, 0, 0, 5], np.int32)
    src = np.array([1, 1, 1, 2], np.int32)
    w = np.array([0.5, 0.25, 0.25, 2.0], np.float32)
    ref = np.zeros((8, 8), np.float32)
    np.add.at(ref, (dst, src), w)
    assert ref[0, 1] == 1.0  # the duplicates really collide
    plan = rect_block_sparse(dst, src, w, n_rows=8, n_cols=8, bs=8)
    np.testing.assert_allclose(_plan_dense(plan), ref, rtol=1e-6)
    np.testing.assert_allclose(_plan_dense(plan, transpose=True), ref.T,
                               rtol=1e-6)
    # same through a hand-built Graph (build_graph dedupes, so parallel
    # edges only reach block_sparse via direct construction)
    indptr = np.zeros(9, np.int64)
    np.cumsum(np.bincount(dst, minlength=8), out=indptr[1:])
    g = Graph(n=8, src=src, dst=dst, weight=w, indptr=indptr)
    bsg = block_sparse(g, bs=8)
    np.testing.assert_allclose(
        _tiles_dense(bsg.block_rows, bsg.block_cols, bsg.blocks,
                     bsg.n_padded // 8, bsg.n_padded // 8, 8)[:8, :8],
        ref, rtol=1e-6)
    np.testing.assert_allclose(g.dense_adjacency(), ref, rtol=1e-6)


@pytest.mark.parametrize("bs", [16, 32])
def test_block_sparse_transpose_plan(bs):
    g = small_graph(70, seed=5)
    bsg = block_sparse(g, bs=bs)
    t = block_sparse_transpose(bsg)
    fwd = _tiles_dense(bsg.block_rows, bsg.block_cols, bsg.blocks,
                       bsg.n_padded // bs, bsg.n_padded // bs, bs)
    bwd = _tiles_dense(t.block_rows, t.block_cols, t.blocks,
                       t.n_padded // bs, t.n_padded // bs, bs)
    np.testing.assert_allclose(bwd, fwd.T, rtol=1e-6)
    # transposed tiles keep the kernel's scheduling invariants
    assert np.all(np.diff(t.block_rows) >= 0)
    assert t.row_first.sum() == len(np.unique(t.block_rows))


@pytest.mark.parametrize("n_chunks", [2, 3])
def test_chunk_block_sparse_matches_chunk_graph(n_chunks):
    """Per-chunk plans tile exactly the rows ChunkedGraph owns, including
    when n_chunks does not divide n (clamped trailing chunk)."""
    g = small_graph(70, seed=6)              # n_chunks ∤ 70 for 3
    cg = chunk_graph(g, n_chunks)
    plan = chunk_block_sparse(g, n_chunks, bs=16)
    assert plan.n_rows == cg.chunk_size and plan.n_cols == g.n
    a = g.dense_adjacency()
    for c in range(n_chunks):
        lo = min(g.n, c * cg.chunk_size)
        hi = min(g.n, (c + 1) * cg.chunk_size)
        ref = np.zeros((plan.rows_padded, plan.cols_padded), np.float32)
        ref[: hi - lo, : g.n] = a[lo:hi]
        np.testing.assert_allclose(_plan_dense(plan, c), ref, rtol=1e-6)
        np.testing.assert_allclose(_plan_dense(plan, c, transpose=True),
                                   ref.T, rtol=1e-6)


def test_stack_plans_pads_with_zero_tiles():
    """stack_plans pads short instances with harmless zero tiles."""
    p1 = rect_block_sparse(np.array([0], np.int32), np.array([1], np.int32),
                           np.array([1.0], np.float32),
                           n_rows=8, n_cols=16, bs=8)
    dst = np.array([0, 3, 7], np.int32)
    src = np.array([4, 9, 15], np.int32)
    w = np.array([1.0, 2.0, 3.0], np.float32)
    p2 = rect_block_sparse(dst, src, w, n_rows=8, n_cols=16, bs=8)
    stacked = stack_plans([p1, p2])
    assert stacked.nnzb == max(p1.nnzb, p2.nnzb)
    ref1 = np.zeros((8, 16), np.float32)
    ref1[0, 1] = 1.0
    ref2 = np.zeros((8, 16), np.float32)
    np.add.at(ref2, (dst, src), w)
    np.testing.assert_allclose(_plan_dense(stacked, 0), ref1, rtol=1e-6)
    np.testing.assert_allclose(_plan_dense(stacked, 1), ref2, rtol=1e-6)
    np.testing.assert_allclose(_plan_dense(stacked, 0, transpose=True),
                               ref1.T, rtol=1e-6)


def test_edge_id_int32_end_to_end():
    """The chunked edge_id contract: int32 from construction through
    padding (no int64 build + silent downcast), pad value == E."""
    g = small_graph(60, seed=3)
    cg = chunk_graph(g, 3)
    assert cg.edge_id.dtype == np.int32
    # every real edge id appears exactly once; pads are exactly E
    ids = cg.edge_id.ravel()
    real = ids[ids < g.e]
    assert sorted(real.tolist()) == list(range(g.e))
    assert np.all(ids[ids >= g.e] == g.e)


def test_edge_id_overflow_rejected():
    """E at/after the int32 ceiling must raise eagerly, naming E —
    not overflow into negative ids during padding."""
    from repro.graph import require_int32_edge_ids
    require_int32_edge_ids(np.iinfo(np.int32).max - 1)  # largest legal
    with pytest.raises(ValueError) as ei:
        require_int32_edge_ids(np.iinfo(np.int32).max)
    msg = str(ei.value)
    assert str(np.iinfo(np.int32).max) in msg and "edge_id" in msg


def test_host_feature_store_worker_major_stripes():
    from repro.graph import HostFeatureStore
    n_workers, n_stripes, rs, d = 3, 4, 2, 5
    n = n_workers * n_stripes * rs
    x = np.arange(n * d, dtype=np.float32).reshape(n, d)
    store = HostFeatureStore(x, n_workers=n_workers, n_stripes=n_stripes)
    assert store.stripe_rows == rs
    assert store.stripe_nbytes == n_workers * rs * d * 4
    # stripe s stacks worker i's rows [i·V/N + s·rs, i·V/N + (s+1)·rs)
    seen = np.zeros(n, bool)
    for s in range(n_stripes):
        st = store.stripe(s)
        assert st.shape == (n_workers * rs, d)
        for i in range(n_workers):
            lo = i * (n // n_workers) + s * rs
            np.testing.assert_array_equal(st[i * rs:(i + 1) * rs],
                                          x[lo:lo + rs])
            seen[lo:lo + rs] = True
    assert seen.all()          # the stripes tile the store exactly
    with pytest.raises(IndexError):
        store.stripe(n_stripes)
    with pytest.raises(ValueError, match="divide"):
        HostFeatureStore(x, n_workers=n_workers, n_stripes=5)
