"""Out-of-core chunk streaming (repro.core.stream + runtime.streaming).

Fast lane: single-device (tp_mesh(1)) streamed-vs-in-memory loss+grad
equivalence across engine backends and streaming modes, the analytic
H2D-byte formula against the measured telemetry column, the staging
primitives (prefetch ordering/depth, global_zeros placement), and the
streamability gates.  The real 8-device matrix (3 agg backends × both
engine backends × both streaming modes, collective-ledger byte-identity
with the in-memory epoch) lives in
tests/dist_progs/check_oocstream.py (slow lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import max_tree_diff, run_dist_prog
from repro.core import decouple as D
from repro.core import stream as ST
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.runtime import collect_comm, tp_mesh
from repro.runtime import streaming as RS


@pytest.fixture(scope="module")
def setup():
    data = sbm_power_law(n=96, num_classes=3, feat_dim=12, avg_degree=6,
                         seed=0)
    sb = ST.prepare_stream_bundle(data, n_workers=1, n_chunks=3,
                                  agg="segment")
    cfg = ST.stream_gnn_config(data, sb, hidden_dim=16, num_layers=2,
                               gamma=0.7)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ref = D.prepare_bundle(data, n_workers=1, n_chunks=3)
    assert ref.graph.n_padded == sb.n_padded
    return data, sb, cfg, params, ref


@pytest.mark.parametrize("mode", ST.STREAM_MODES)
@pytest.mark.parametrize("backend", ["explicit", "constraint"])
def test_streamed_matches_in_memory(setup, mode, backend):
    data, sb, cfg, params, ref = setup
    ref_vg = D.make_tp_value_and_grad(cfg, ref, tp_mesh(1),
                                      mode="decoupled", backend=backend)
    ref_loss, ref_grads = ref_vg(params, ref.train_mask)
    vg = ST.make_stream_value_and_grad(cfg, sb, mode=mode,
                                       backend=backend)
    loss, grads = vg(params, sb.train_mask)
    assert abs(float(loss) - float(ref_loss)) < 1e-5
    assert max_tree_diff(grads, ref_grads) < 1e-5


def test_h2d_column_matches_analytic_formula(setup):
    data, sb, cfg, params, ref = setup
    vg = ST.make_stream_value_and_grad(cfg, sb)
    vg(params, sb.train_mask)                     # warmup: trace + stage
    with collect_comm() as led:
        vg(params, sb.train_mask)
    d = led.as_dict()
    assert all(k.startswith("h2d|") for k in d), d  # programs all cached
    measured = sum(v["payload_bytes"] for v in d.values())
    assert measured == ST.expected_h2d_bytes(sb, cfg)


def test_footprint_contract(setup):
    data, sb, cfg, params, ref = setup
    foot = ST.device_resident_bytes(sb, cfg)
    # the double buffer is 2 items deep, each 1/S (1/C) of the store
    assert foot["staged_stripe_bytes"] == 2 * sb.store.stripe_nbytes
    assert sb.store.nbytes == sb.n_stripes * sb.store.stripe_nbytes
    per_chunk = ST.chunk_input_nbytes(sb)
    assert foot["staged_chunk_bytes"] >= 2 * max(per_chunk) > 0
    assert len(per_chunk) == sb.n_chunks


def test_streamability_gates(setup):
    data, sb, cfg, params, ref = setup
    with pytest.raises(ValueError, match="naive"):
        ST.make_stream_value_and_grad(cfg, sb, mode="naive")
    gat = ST.stream_gnn_config(data, sb, model="gat")
    with pytest.raises(ValueError, match="GAT"):
        ST.make_stream_value_and_grad(gat, sb)
    with pytest.raises(ValueError, match="blocksparse"):
        ST.make_stream_value_and_grad(cfg, sb, agg="blocksparse")


def test_prefetched_is_double_buffered():
    staged, order = [], []

    def stage(x):
        staged.append(x)
        return x

    for item in RS.prefetched(range(5), stage, depth=2):
        order.append(item)
        # when the consumer receives c, c+1 has already been staged
        assert len(staged) >= min(len(order) + 1, 5)
        # ...but never more than depth items ahead of consumption
        assert len(staged) - len(order) <= 2
    assert order == staged == list(range(5))
    with pytest.raises(ValueError, match="depth"):
        list(RS.prefetched(range(3), stage, depth=0))


def test_global_zeros_places_without_host_roundtrip():
    mesh = tp_mesh(1)
    z = RS.global_zeros(mesh, P(), (3, 4))
    assert z.shape == (3, 4) and float(jnp.sum(z)) == 0.0
    # cached program: same (sharding, shape, dtype) → same executable
    z2 = RS.global_zeros(mesh, P(), (3, 4))
    assert z2.sharding == z.sharding


def test_stage_records_h2d_bytes():
    mesh = tp_mesh(1)
    tree = {"a": np.ones((4, 4), np.float32), "b": np.ones(2, np.int32)}
    with collect_comm() as led:
        out = RS.stage(tree, mesh, P(), label="unit")
    jax.block_until_ready(out)
    d = led.as_dict()
    assert sum(v["payload_bytes"] for k, v in d.items()
               if k.startswith("h2d|unit")) == 64 + 8


@pytest.mark.slow
def test_oocstream_8dev_matrix():
    run_dist_prog("check_oocstream.py", timeout=1800)
