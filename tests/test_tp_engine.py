"""TP engine semantics on the single-device mesh (N=1 degenerate collectives);
true multi-worker behaviour is covered by test_distributed.py subprocesses.

All sharded execution enters via ``repro.runtime.engine`` — the split/gather
round-trip of the underlying collectives is covered by test_runtime.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import optim
from repro.core import decouple as D
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.runtime import engine, tp_mesh


@pytest.fixture(scope="module")
def setup():
    data = sbm_power_law(n=500, num_classes=5, feat_dim=24, avg_degree=8,
                         seed=0)
    bundle = D.prepare_bundle(data, n_workers=1, n_chunks=3)
    mesh = tp_mesh(1)
    return data, bundle, mesh


@pytest.mark.parametrize("model", ["gcn", "gat"])
@pytest.mark.parametrize("pipelined", [False, True])
def test_tp_forward_matches_reference(setup, model, pipelined):
    data, bundle, mesh = setup
    cfg = D.padded_gnn_config(data, bundle, model=model, hidden_dim=32,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    g = bundle.graph
    ref = M.decoupled_forward(params, cfg, g.edges, bundle.features)
    f = engine(
        lambda p, gr, x: D.tp_decoupled_forward(p, cfg, gr, x,
                                                pipelined=pipelined),
        mesh=mesh, in_specs=(P(), P(), P("model", None)),
        out_specs=P("model", None))
    out = f(params, g, bundle.features)
    np.testing.assert_allclose(out[: data.graph.n], ref[: data.graph.n],
                               atol=1e-4)


def test_naive_tp_matches_coupled_reference(setup):
    data, bundle, mesh = setup
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=32,
                              num_layers=2)
    cfg_ref = M.GNNConfig(**{**cfg.__dict__, "decoupled": False})
    params = M.init_params(jax.random.PRNGKey(2), cfg)
    g = bundle.graph
    ref = M.coupled_forward(params, cfg_ref, g.edges, bundle.features)
    f = engine(
        lambda p, gr, x: D.tp_naive_forward(p, cfg, gr, x),
        mesh=mesh, in_specs=(P(), P(), P("model", None)),
        out_specs=P("model", None))
    out = f(params, g, bundle.features)
    np.testing.assert_allclose(out[: data.graph.n], ref[: data.graph.n],
                               atol=1e-4)


@pytest.mark.parametrize("mode", ["decoupled", "decoupled_pipelined",
                                  "naive"])
def test_tp_training_converges(setup, mode):
    data, bundle, mesh = setup
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=32,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    opt = optim.adamw(1e-2)
    step, ev = D.make_tp_train_fns(cfg, bundle, mesh, opt, mode=mode)
    o = opt.init(params)
    p = params
    losses = []
    for _ in range(25):
        p, o, loss = step(p, o)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5
    _, acc = ev(p, "test")
    assert float(acc) > 0.8


def test_padding_divisibility_properties():
    from repro.core import tp
    assert tp.padded_size(10, 4) == 12
    assert tp.padded_size(8, 4) == 8
    x = jnp.ones((10, 3))
    assert tp.pad_to_multiple(x, 4, axis=0).shape == (12, 3)
    assert tp.pad_to_multiple(x, 3, axis=1).shape == (10, 3)
