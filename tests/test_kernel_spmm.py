"""Pallas block-sparse SpMM kernel: shape/dtype sweeps vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph import build_graph, block_sparse, sbm_power_law
from repro.kernels.spmm import (aggregate_pallas, block_sparse_dev,
                                spmm_block_sparse, spmm_ref, spmm_dense_ref)


def random_graph(n, avg_deg, seed, self_loops=True):
    rng = np.random.default_rng(seed)
    e = n * avg_deg
    return build_graph(rng.integers(0, n, e).astype(np.int32),
                       rng.integers(0, n, e).astype(np.int32), n,
                       add_self_loops=self_loops)


@pytest.mark.parametrize("bs", [32, 64, 128])
@pytest.mark.parametrize("d", [8, 32, 128])
def test_spmm_shape_sweep(bs, d):
    g = random_graph(300, 5, seed=bs + d)
    bsg = block_sparse(g, bs=bs)
    dev = block_sparse_dev(bsg)
    h = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.n, d)).astype(np.float32))
    out = aggregate_pallas(dev, h, d_tile=min(d, 128))
    ref = spmm_dense_ref(jnp.asarray(g.dense_adjacency()), h)
    np.testing.assert_allclose(out, ref, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_spmm_dtype_sweep(dtype):
    g = random_graph(256, 6, seed=7)
    bsg = block_sparse(g, bs=64)
    dev = block_sparse_dev(bsg, dtype=jnp.float32)
    h = jax.random.normal(jax.random.PRNGKey(0), (g.n, 64)).astype(dtype)
    out = aggregate_pallas(dev, h, d_tile=64)
    ref = spmm_ref(dev.blocks, dev.block_rows, dev.block_cols,
                   jnp.pad(h, ((0, dev.n_padded - g.n), (0, 0))))[: g.n]
    atol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(out.astype(jnp.float32),
                               ref.astype(jnp.float32), atol=atol)
    assert out.dtype == dtype


def test_spmm_non_divisible_dims_padded():
    g = random_graph(197, 4, seed=3)          # n not divisible by bs
    bsg = block_sparse(g, bs=64)
    dev = block_sparse_dev(bsg)
    h = jnp.asarray(np.random.default_rng(1).normal(
        size=(g.n, 52)).astype(np.float32))   # d not divisible by tile
    out = aggregate_pallas(dev, h, d_tile=32)
    ref = spmm_dense_ref(jnp.asarray(g.dense_adjacency()), h)
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_spmm_empty_rows_no_self_loops():
    """Vertices with no in-edges must produce zero rows (zero-fill tiles)."""
    n = 160
    src = np.array([1, 2, 3], np.int32)
    dst = np.array([0, 0, 1], np.int32)
    g = build_graph(src, dst, n, add_self_loops=False, normalization="none")
    bsg = block_sparse(g, bs=32)
    dev = block_sparse_dev(bsg)
    h = jnp.asarray(np.random.default_rng(2).normal(
        size=(n, 32)).astype(np.float32))
    out = np.asarray(aggregate_pallas(dev, h, d_tile=32))
    np.testing.assert_allclose(out[0], np.asarray(h[1] + h[2]), atol=1e-5)
    np.testing.assert_allclose(out[1], np.asarray(h[3]), atol=1e-5)
    np.testing.assert_allclose(out[2:], 0.0)


def test_spmm_matches_segment_sum_on_sbm():
    from repro.gnn import layers as L
    data = sbm_power_law(n=700, num_classes=4, feat_dim=16, avg_degree=10,
                         seed=5)
    g = data.graph
    dev = block_sparse_dev(block_sparse(g, bs=128))
    h = jnp.asarray(np.random.default_rng(4).normal(
        size=(g.n, 128)).astype(np.float32))
    out = aggregate_pallas(dev, h)
    ref = L.aggregate(L.edge_list_dev(g), h)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_spmm_kernel_direct_call_accumulation_order():
    """Multiple tiles per destination row accumulate exactly once each."""
    bs = 32
    n_blocks = 3
    rng = np.random.default_rng(0)
    # row 0: 3 tiles, row 1: 1 tile, row 2: 2 tiles
    rows = np.array([0, 0, 0, 1, 2, 2], np.int32)
    cols = np.array([0, 1, 2, 1, 0, 2], np.int32)
    first = np.array([1, 0, 0, 1, 1, 0], np.int32)
    blocks = rng.normal(size=(6, bs, bs)).astype(np.float32)
    h = rng.normal(size=(n_blocks * bs, 64)).astype(np.float32)
    out = spmm_block_sparse(jnp.asarray(blocks), jnp.asarray(rows),
                            jnp.asarray(cols), jnp.asarray(first),
                            jnp.asarray(h), d_tile=64)
    ref = spmm_ref(jnp.asarray(blocks), jnp.asarray(rows), jnp.asarray(cols),
                   jnp.asarray(h))
    np.testing.assert_allclose(out, ref, atol=1e-4)


# ---------------------------------------------------------------------------
# Plan-based aggregation: custom VJP, shape validation, interpret contract
# ---------------------------------------------------------------------------

def test_plan_vjp_grad_matches_dense_oracle():
    """Â through the plan kernel: forward AND grad vs the dense oracle,
    with n not a multiple of bs and d < d_tile (padded tail rows/cols)."""
    from repro.kernels.spmm import square_plan_dev

    g = random_graph(197, 4, seed=11)        # 197 % 64 != 0
    plan = square_plan_dev(block_sparse(g, bs=64))
    rng = np.random.default_rng(2)
    h = jnp.asarray(rng.normal(size=(g.n, 20)).astype(np.float32))
    cot = jnp.asarray(rng.normal(size=(g.n, 20)).astype(np.float32))
    a = jnp.asarray(g.dense_adjacency())

    def f(hh):
        return jnp.vdot(aggregate_pallas(plan, hh), cot)

    def f_ref(hh):
        return jnp.vdot(a @ hh, cot)

    np.testing.assert_allclose(f(h), f_ref(h), rtol=1e-5)
    gh = jax.jit(jax.grad(f))(h)
    gh_ref = jax.grad(f_ref)(h)
    np.testing.assert_allclose(gh, gh_ref, atol=1e-4)


def test_chunked_plan_scan_vjp_matches_dense_oracle():
    """Stacked per-chunk plans under lax.scan (the engines' §4.2 shape),
    n_chunks ∤ n: value and grad vs the dense oracle."""
    from repro.graph import chunk_block_sparse
    from repro.kernels.spmm import aggregate_plan, block_sparse_plan_dev

    g = random_graph(197, 4, seed=12)        # 3 ∤ 197
    plan = block_sparse_plan_dev(chunk_block_sparse(g, 3, bs=64))
    cs = plan.n_rows
    rng = np.random.default_rng(3)
    h = jnp.asarray(rng.normal(size=(g.n, 20)).astype(np.float32))
    cot = jnp.asarray(rng.normal(size=(3 * cs, 20)).astype(np.float32))
    a = jnp.asarray(g.dense_adjacency())

    def f(hh):
        def body(_, p):
            return None, aggregate_plan(p, hh)[:cs]
        _, out = jax.lax.scan(body, None, plan)
        return jnp.vdot(out.reshape(-1, hh.shape[1]), cot)

    def f_ref(hh):
        out = a @ hh                          # (n, d); pad to chunk grid
        out = jnp.pad(out, ((0, 3 * cs - g.n), (0, 0)))
        return jnp.vdot(out, cot)

    np.testing.assert_allclose(f(h), f_ref(h), rtol=1e-5)
    np.testing.assert_allclose(jax.jit(jax.grad(f))(h), jax.grad(f_ref)(h),
                               atol=1e-4)


def test_spmm_shape_validation_errors():
    """Mis-shaped operands raise ValueErrors naming the offending shape
    (they used to be bare asserts)."""
    bs = 64
    blocks = jnp.zeros((1, bs, bs), jnp.float32)
    z = jnp.zeros((1,), jnp.int32)
    with pytest.raises(ValueError, match=r"100 rows, not a multiple"):
        spmm_block_sparse(blocks, z, z, z, jnp.zeros((100, 64)))
    with pytest.raises(ValueError, match=r"n_out=100 is not a multiple"):
        spmm_block_sparse(blocks, z, z, z, jnp.zeros((128, 64)), n_out=100)
    with pytest.raises(ValueError, match=r"d=100 is not a multiple"):
        spmm_block_sparse(blocks, z, z, z, jnp.zeros((128, 100)), d_tile=64)


def test_resolve_interpret_auto_contract():
    """None → interpret everywhere except a real TPU; explicit overrides
    pass through untouched."""
    from repro.kernels.spmm import resolve_interpret

    assert resolve_interpret(None) == (jax.default_backend() != "tpu")
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
