"""Trace-time collective telemetry (``repro.runtime.telemetry``).

Fast lane: the pure ledger machinery (records, loop scopes, the
constraint-transition classifier, the ring cost model's agreement with
the HLO census's), the static ``axis_size`` contract, the
``replica_slice`` no-silent-truncation guard, and single-device traces
through the real engine (collection plumbing without multi-device
buffers).  The 8-device byte-for-byte equivalence — ledger == analytic
formulas == HLO census for every mode × backend plus a (2,4) hybrid
mesh — runs as a subprocess with pinned XLA_FLAGS
(tests/dist_progs/check_telemetry.py, slow lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import run_dist_prog
from repro.core import decouple as D
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.runtime import collect_comm, engine, loop_scope, tp_mesh
from repro.runtime import collectives as C
from repro.runtime import telemetry as T


# ---------------------------------------------------------------------------
# ledger arithmetic
# ---------------------------------------------------------------------------

def test_record_accumulates_per_key():
    with collect_comm() as led:
        T.record("all_to_all", "model", np.zeros((4, 8), np.float32),
                 group_size=8, mirror=True)
        T.record("all_to_all", "model", np.zeros((4, 8), np.float32),
                 group_size=8, mirror=True)
        T.record("all_gather", "data", np.zeros((2, 8), np.float32),
                 group_size=2, mirror=False)
    # a2a: payload 128 B/call, ring wire (8−1)/8 × 128 = 112
    assert led.payload_bytes("all_to_all") == 256.0
    assert led.wire_bytes("all_to_all") == 224.0
    assert led.wire_bytes("all_to_all", train=True) == 448.0
    assert led.call_count("all_to_all") == 2.0
    assert led.call_count("all_to_all", train=True) == 4.0
    # all_gather: wire on RESULT = (g−1)×payload = 1×64; unmirrored
    assert led.wire_bytes("all_gather", "data") == 64.0
    assert led.wire_bytes("all_gather", "data", train=True) == 64.0
    # axis filter: 'model' doesn't see the data-axis gather
    assert led.wire_bytes(axis="model") == 224.0
    assert len(led) == 2 and bool(led)


def test_record_result_size_per_op():
    """ring_wire_factor is defined on the RESULT size; record() must
    derive it from the input payload per op — all_gather grows g×,
    psum_scatter shrinks g× (a factor-on-input would overcount the
    scatter by g×), the rest preserve it."""
    x = np.zeros((256,), np.float32)          # 1024 B payload
    with collect_comm() as led:
        for op in ("all_gather", "psum_scatter", "psum", "all_to_all",
                   "ppermute"):
            T.record(op, "data", x, group_size=4)
    assert led.wire_bytes("all_gather") == 3 * 1024       # (g−1)·payload
    assert led.wire_bytes("psum_scatter") == 3 * 1024 / 4  # (g−1)·res
    assert led.wire_bytes("psum") == 2 * 3 / 4 * 1024
    assert led.wire_bytes("all_to_all") == 3 / 4 * 1024
    assert led.wire_bytes("ppermute") == 1024


def test_ring_attention_scan_counts_hops():
    """The ring's two per-step ppermutes rotate axis_size× — the scan is
    loop_scoped so a ledger counts every hop (trivially 1 hop on a
    single-device axis, but the count must come from the scope)."""
    from jax.sharding import PartitionSpec as P
    from repro.nn.ring_attention import ring_attention_local

    def body(q, k, v):
        return ring_attention_local(q, k, v, "model")

    fn = engine(body, in_specs=(P(), P(), P()), out_specs=P(),
                mesh=tp_mesh(1))
    q = jnp.zeros((1, 4, 2, 4))
    with collect_comm() as led:
        jax.jit(fn).lower(q, q, q)
    assert led.call_count("ppermute") == 2   # 2 ppermutes × 1 hop
    assert led.payload_bytes("ppermute") == 2 * q.size * 4


def test_multi_axis_label_and_query():
    with collect_comm() as led:
        T.record("psum", ("model", "data"), np.float32(0.0), group_size=8)
    ((op, label, dtype),) = led.entries().keys()
    assert (op, label, dtype) == ("psum", "model+data", "float32")
    # component queries match the joined label
    assert led.wire_bytes("psum", "data") == led.wire_bytes("psum")
    assert led.wire_bytes("psum", "model") > 0
    assert led.wire_bytes("psum", "pod") == 0.0


def test_no_active_ledger_is_noop():
    # must not raise and must not leak state into later collections
    T.record("all_to_all", "model", np.zeros((4,), np.float32),
             group_size=8)
    with collect_comm() as led:
        pass
    assert len(led) == 0 and not led


def test_nested_ledgers_both_record():
    with collect_comm() as outer:
        with collect_comm() as inner:
            T.record("all_to_all", "model", np.zeros((4,), np.float32),
                     group_size=2)
        T.record("all_to_all", "model", np.zeros((4,), np.float32),
                 group_size=2)
    assert inner.call_count() == 1.0
    assert outer.call_count() == 2.0


def test_unknown_op_raises():
    with collect_comm():
        with pytest.raises(T.TelemetryError, match="unknown collective"):
            T.record("bcast", "model", np.zeros((4,), np.float32),
                     group_size=2)


# ---------------------------------------------------------------------------
# loop scopes
# ---------------------------------------------------------------------------

def test_loop_scope_multiplies_and_nests():
    x = np.zeros((4,), np.float32)
    with collect_comm() as led:
        with loop_scope(4):
            T.record("all_to_all", "model", x, group_size=8, mirror=True)
            with loop_scope(3):
                T.record("all_to_all", "model", x, group_size=8)
        T.record("all_to_all", "model", x, group_size=8)
    assert led.call_count("all_to_all") == 4 + 12 + 1
    assert led.call_count("all_to_all", train=True) == 4 + 12 + 1 + 4


def test_loop_scope_rejects_bad_trips():
    for bad in (0, -1, 2.5, True, None):
        with pytest.raises(ValueError, match="positive int"):
            with loop_scope(bad):
                pass


# ---------------------------------------------------------------------------
# ring cost model: must agree with the HLO census's
# ---------------------------------------------------------------------------

def test_ring_factors_match_hlo_census():
    from repro.launch.roofline import _wire_factor
    for g in (1, 2, 4, 8):
        for op, hlo in T.OP_TO_HLO.items():
            assert T.ring_wire_factor(op, g) == _wire_factor(hlo, g), \
                (op, g)


# ---------------------------------------------------------------------------
# constraint-transition classifier
# ---------------------------------------------------------------------------

SIZES = {"model": 4, "data": 2}


def _implied(src, dst, shape=(16, 8), itemsize=4):
    return T.implied_collectives(shape, itemsize, src, dst, SIZES)


def test_transition_split_is_model_a2a():
    # P(model, ·) → P(·, model): the paper's split; result bytes=total/4
    out = _implied(P("model", None), P(None, "model"))
    assert out == [("all_to_all", "model", 128.0, 0.75 * 128.0)]


def test_transition_hybrid_stage_is_data_gather():
    # dropping the data axis from the hybrid vertex layout = replica
    # all-gather; result = total / model_sharding = 512/4 = 128
    out = _implied(P(("model", "data"), None), P("model", None))
    assert out == [("all_gather", "data", 64.0, 0.5 * 128.0)]


def test_transition_add_axis_is_free():
    assert _implied(P("model", None), P(("model", "data"), None)) == []
    assert _implied(P("model", None), P("model", None)) == []


def test_transition_unknown_axis_raises():
    with pytest.raises(T.TelemetryError, match="pod"):
        _implied(P("pod", None), P(None, "pod"))


def test_transition_records_into_ledger():
    with collect_comm() as led:
        T.record_transition((16, 8), np.float32, P("model", None),
                            P(None, "model"), SIZES, mirror=True)
    assert led.wire_bytes("all_to_all", "model") == 96.0
    assert led.wire_bytes("all_to_all", "model", train=True) == 192.0


# ---------------------------------------------------------------------------
# axis_size: the static-int contract (satellite: the 0.4.x fallback)
# ---------------------------------------------------------------------------

def test_axis_size_static_int_inside_engine():
    seen = {}

    def body(x):
        seen["n"] = C.axis_size("model")
        seen["static"] = C.static_axis_size("model")
        return x

    fn = engine(body, in_specs=P("model"), out_specs=P("model"),
                mesh=tp_mesh(1))
    fn(jnp.arange(1.0))
    assert seen["n"] == 1 and isinstance(seen["n"], int)
    assert seen["static"] == 1 and isinstance(seen["static"], int)


def test_axis_size_unbound_returns_none():
    assert C.static_axis_size("model") is None
    assert C.static_axis_size("no-such-axis") is None


def test_axis_size_psum_fallback_branch(monkeypatch):
    """With both static probes disabled, axis_size falls back to
    psum(1, axis) — still the right *value* (static only via jax's
    non-tracer constant fold, which is why it is a last resort)."""
    monkeypatch.setattr(C, "static_axis_size", lambda axis: None)
    seen = {}

    def body(x):
        seen["n"] = C.axis_size("model")
        return x

    fn = engine(body, in_specs=P("model"), out_specs=P("model"),
                mesh=tp_mesh(1))
    fn(jnp.arange(1.0))
    assert int(seen["n"]) == 1


# ---------------------------------------------------------------------------
# replica_slice: no silent truncation (satellite)
# ---------------------------------------------------------------------------

def test_replica_block_divides():
    assert C._replica_block(8, 4, 0, ("data",)) == 2
    assert C._replica_block(8, 1, 0, ()) == 8


def test_replica_block_refuses_truncation():
    with pytest.raises(ValueError) as e:
        C._replica_block(10, 4, 0, ("pod", "data"))
    msg = str(e.value)
    # error must name the length, axis, and replica product (the old
    # `// n` silently dropped 10 % 4 = 2 trailing rows per replica)
    assert "length 10" in msg and "axis 0" in msg
    assert "replica count 4" in msg and "('pod', 'data')" in msg


def test_replica_ops_identity_ledger():
    """data_axes=() replica ops are identities and record nothing — the
    zero-entry ledger of the pure-TP path."""
    x = jnp.arange(6.0).reshape(3, 2)
    with collect_comm() as led:
        assert C.replica_gather(x, ()) is x
        assert C.replica_slice(x, ()) is x
        assert C.psum_replicas(x, ()) is x
    assert len(led) == 0


# ---------------------------------------------------------------------------
# collection through the real engine (single device: plumbing only)
# ---------------------------------------------------------------------------

def _tiny_tp(n_workers=1, n_chunks=4):
    data = sbm_power_law(n=32, num_classes=4, feat_dim=8, avg_degree=4,
                         seed=0)
    bundle = D.prepare_bundle(data, n_workers=n_workers, n_chunks=n_chunks)
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=8,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return data, bundle, cfg, params


def test_ledger_fills_on_first_trace_only():
    data, bundle, cfg, params = _tiny_tp()
    loss_fn = D.make_tp_loss_fn(cfg, bundle, tp_mesh(1), mode="decoupled")
    jitted = jax.jit(loss_fn)
    with collect_comm() as led:
        jitted.lower(params, bundle.train_mask)
    # decoupled: split + gather + 3 scalar psums; 1-device axis → 0 wire
    assert led.call_count("all_to_all") == 2
    assert led.wire_bytes("all_to_all") == 0.0
    assert led.call_count("psum") == 3
    # the cached second trace records nothing (trace-time semantics)
    with collect_comm() as led2:
        jitted.lower(params, bundle.train_mask)
    assert len(led2) == 0


def test_pipelined_scan_counts_trips():
    """The chunked-pipeline scans trace once but must count n_chunks× —
    the while-loop undercount the census re-derives from trip constants
    (8-device byte equality is pinned in check_telemetry)."""
    data, bundle, cfg, params = _tiny_tp(n_chunks=4)
    loss_fn = D.make_tp_loss_fn(cfg, bundle, tp_mesh(1),
                                mode="decoupled_pipelined")
    with collect_comm() as led:
        jax.jit(loss_fn).lower(params, bundle.train_mask)
    # L=2 rounds → one split scan + one gather scan, 4 chunks each
    assert led.call_count("all_to_all") == 8
    assert led.call_count("all_to_all", train=True) == 16


def test_naive_layer0_not_mirrored():
    data, bundle, cfg, params = _tiny_tp()
    loss_fn = D.make_tp_loss_fn(cfg, bundle, tp_mesh(1), mode="naive")
    with collect_comm() as led:
        jax.jit(loss_fn).lower(params, bundle.train_mask)
    # 2 a2a per layer forward, but layer 0 moves undifferentiated input
    # features: only layer 1's pair declares an autodiff mirror
    assert led.call_count("all_to_all") == 4
    assert led.call_count("all_to_all", train=True) == 6


# ---------------------------------------------------------------------------
# 8-device byte-for-byte equivalence (slow lane)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_telemetry_matches_census_8dev():
    """Ledger == analytic formulas == HLO census for every mode × both
    backends on the bench workload, incl. a (2,4) hybrid mesh."""
    run_dist_prog("check_telemetry.py", timeout=1200)
