"""Pluggable aggregation backends (repro.core.agg).

Fast lane: single-device (tp_mesh(1)) loss+grad equivalence of the
segment / blocksparse / dense backends across engine modes, factory-level
backend resolution errors, and the GAT segment-sum fallback.  The real
8-device matrix (all modes × both engine backends × pure TP and hybrid
meshes, with CommLedger byte-identity and the jaxpr collective audit)
lives in tests/dist_progs/check_agg_backends.py (slow lane).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import max_tree_diff, run_dist_prog
from repro.core import agg as AGG
from repro.core import decouple as D
from repro.gnn import dp_baseline as DP
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.runtime import tp_mesh


@pytest.fixture(scope="module")
def setup():
    data = sbm_power_law(n=96, num_classes=3, feat_dim=12, avg_degree=6,
                         seed=0)
    bundles = {agg: D.prepare_bundle(data, n_workers=1, n_chunks=3,
                                     agg=agg, agg_block_size=32)
               for agg in AGG.AGG_BACKENDS}
    return data, bundles, tp_mesh(1)


@pytest.mark.parametrize("mode", ["decoupled", "decoupled_pipelined",
                                  "naive"])
@pytest.mark.parametrize("backend", ["explicit", "constraint"])
def test_tp_backends_equivalent(setup, mode, backend):
    data, bundles, mesh = setup
    cfg = D.padded_gnn_config(data, bundles["segment"], model="gcn",
                              hidden_dim=16, num_layers=2, gamma=0.7)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ref = None
    for agg in AGG.AGG_BACKENDS:
        vg = D.make_tp_value_and_grad(cfg, bundles[agg], mesh, mode=mode,
                                      backend=backend)
        loss, grads = vg(params, bundles[agg].train_mask)
        if ref is None:
            ref = (loss, grads)
            continue
        assert abs(float(loss) - float(ref[0])) < 1e-5, agg
        assert max_tree_diff(grads, ref[1]) < 1e-5, agg


@pytest.mark.parametrize("backend", ["explicit", "constraint"])
def test_dp_backends_equivalent(setup, backend):
    data, _, mesh = setup
    cfg = M.GNNConfig(model="gcn", in_dim=12, hidden_dim=16, num_classes=3,
                      num_layers=2, decoupled=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ref = None
    for agg in AGG.AGG_BACKENDS:
        bundle = DP.prepare_dp_bundle(data, k=1, agg=agg, agg_block_size=32)
        vg = DP.make_dp_value_and_grad(cfg, bundle, mesh, backend=backend)
        loss, grads = vg(params, bundle.train_mask)
        if ref is None:
            ref = (loss, grads)
            continue
        assert abs(float(loss) - float(ref[0])) < 1e-5, agg
        assert max_tree_diff(grads, ref[1]) < 1e-5, agg


def test_factory_agg_override(setup):
    """An explicit factory agg= must be satisfiable on the bundle: a
    segment-prepared bundle has no tiles; an unknown name is rejected."""
    data, bundles, mesh = setup
    cfg = D.padded_gnn_config(data, bundles["segment"], model="gcn",
                              hidden_dim=16, num_layers=2)
    with pytest.raises(ValueError, match="carries no tile"):
        D.make_tp_loss_fn(cfg, bundles["segment"], mesh, agg="blocksparse")
    with pytest.raises(ValueError, match="carries no dense"):
        D.make_tp_loss_fn(cfg, bundles["segment"], mesh, agg="dense")
    with pytest.raises(ValueError, match="unknown aggregation backend"):
        D.make_tp_loss_fn(cfg, bundles["segment"], mesh, agg="csr")
    with pytest.raises(ValueError, match="unknown aggregation backend"):
        D.prepare_bundle(data, n_workers=1, n_chunks=3, agg="csr")
    # a blocksparse bundle can always fall back to the segment path
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    loss_bs = D.make_tp_loss_fn(cfg, bundles["blocksparse"], mesh,
                                agg="segment")
    loss_seg = D.make_tp_loss_fn(cfg, bundles["segment"], mesh)
    a = loss_bs(params, bundles["blocksparse"].train_mask)
    b = loss_seg(params, bundles["segment"].train_mask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_gat_falls_back_to_segment(setup):
    """GAT's runtime attention weights cannot be baked into tiles: on a
    blocksparse-prepared bundle it must silently keep the segment path
    and agree exactly with the segment-prepared bundle."""
    data, bundles, mesh = setup
    cfg = D.padded_gnn_config(data, bundles["segment"], model="gat",
                              hidden_dim=16, num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    out = {}
    for agg in ("segment", "blocksparse"):
        vg = D.make_tp_value_and_grad(cfg, bundles[agg], mesh,
                                      mode="decoupled")
        out[agg] = vg(params, bundles[agg].train_mask)
    assert float(out["segment"][0]) == float(out["blocksparse"][0])
    assert max_tree_diff(out["segment"][1], out["blocksparse"][1]) == 0.0


def test_chunk_agg_segment_matches_reference():
    """The shared chunk_agg segment branch is the engines' baseline math:
    gather · w, segment-sum into chunk_size+1 slots, drop the pad row."""
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.normal(size=(24, 6)).astype(np.float32))
    src = jnp.asarray(rng.integers(0, 24, 40).astype(np.int32))
    dst = jnp.asarray(rng.integers(0, 9, 40).astype(np.int32))  # 8 = pad
    w = jnp.asarray(rng.normal(size=40).astype(np.float32))
    out = AGG.chunk_agg("segment", z, (src, dst, w), 8)
    ref = np.zeros((9, 6), np.float32)
    np.add.at(ref, np.asarray(dst), np.asarray(z)[np.asarray(src)]
              * np.asarray(w)[:, None])
    np.testing.assert_allclose(out, ref[:8], atol=1e-5)


@pytest.mark.slow
def test_agg_backends_8_devices():
    """Full matrix on 8 forced devices: losses+grads equal, CommLedger
    byte-identical, blocksparse programs pass the jaxpr audit."""
    run_dist_prog("check_agg_backends.py")
