"""Unit tests for the transformer substrate (attention, MoE, SSM, RoPE)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ArchConfig
from repro.nn import attention as A
from repro.nn import layers as nl
from repro.nn import moe as moe_lib
from repro.nn import ssm as ssm_lib
from repro.nn.param import split_params


def mini_cfg(**kw) -> ArchConfig:
    base = dict(name="t", arch_type="dense", num_layers=2, d_model=64,
                num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                head_dim=16, dtype="float32")
    base.update(kw)
    return ArchConfig(**base)


def values(tree):
    return jax.tree.map(lambda l: l, tree)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    pos = jnp.arange(8)[None]
    y = nl.apply_rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)
    # dot products depend only on relative offsets
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    qs = jnp.broadcast_to(q, (1, 8, 1, 16))
    rq = nl.apply_rope(qs, pos)
    d01 = jnp.einsum("d,d->", rq[0, 0, 0], rq[0, 1, 0])
    d34 = jnp.einsum("d,d->", rq[0, 3, 0], rq[0, 4, 0])
    np.testing.assert_allclose(d01, d34, rtol=1e-4)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def test_gqa_equals_mha_when_kv_repeated():
    """GQA with kv heads broadcast == full MHA with duplicated kv."""
    cfg = mini_cfg()
    b, s = 2, 12
    q = jax.random.normal(jax.random.PRNGKey(0), (b, s, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, 2, 16))
    mask = (jnp.tril(jnp.ones((s, s), bool)))[None]
    out = A.attention_core(q, k, v, mask)
    k_full = jnp.repeat(k, 2, axis=2)
    v_full = jnp.repeat(v, 2, axis=2)
    # interleaving: group g of kv head h is q head h*2+g
    out_full = A.attention_core(q, k_full, v_full, mask)
    np.testing.assert_allclose(out, out_full, atol=1e-5)


def test_sliding_window_mask_limits_receptive_field():
    cfg = mini_cfg(sliding_window=4)
    p = A.init_attention(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda l: l.value, p,
                     is_leaf=lambda x: hasattr(x, "names"))
    b, s = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    y1 = A.gqa_attention(p, cfg, x, pos, window=4)
    # perturb a token > window away from the last position
    x2 = x.at[:, 2].add(10.0)
    y2 = A.gqa_attention(p, cfg, x2, pos, window=4)
    np.testing.assert_allclose(y1[:, -1], y2[:, -1], atol=1e-4)
    assert not np.allclose(y1[:, 3], y2[:, 3], atol=1e-4)


def test_attn_softcap_bounds_scores():
    s = jnp.linspace(-500, 500, 11)
    capped = nl.softcap(s, 50.0)
    assert float(jnp.abs(capped).max()) <= 50.0
    np.testing.assert_allclose(nl.softcap(s, None), s)


def test_mla_absorbed_decode_matches_explicit():
    """MLA decode (latent-absorbed) == explicit k/v reconstruction."""
    cfg = mini_cfg(use_mla=True, kv_lora_rank=32, rope_head_dim=8,
                   num_kv_heads=4)
    leafs = A.init_attention(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda l: l.value, leafs,
                     is_leaf=lambda x: hasattr(x, "names"))
    b, s = 2, 9
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    full = A.mla_attention(p, cfg, x, pos)
    # prefill s-1 then decode last token
    y_pre, cache = A.mla_prefill(p, cfg, x[:, :-1], pos[:, :-1], max_len=s)
    np.testing.assert_allclose(full[:, :-1], y_pre, atol=1e-4)
    y_dec, _ = A.mla_decode(p, cfg, x[:, -1:], cache)
    np.testing.assert_allclose(full[:, -1:], y_dec, atol=1e-4)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def moe_cfg(**kw):
    return mini_cfg(arch_type="moe", moe=True, num_experts=4,
                    num_experts_per_tok=2, moe_d_ff=32, **kw)


def _moe_params(cfg):
    leafs = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    return jax.tree.map(lambda l: l.value, leafs,
                        is_leaf=lambda x: hasattr(x, "names"))


def test_moe_dropless_matches_dense_oracle():
    cfg = moe_cfg()
    p = _moe_params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = moe_lib.moe_apply(p, cfg, x, dropless=True)
    # dense oracle: every expert on every token, weighted by top-k gates
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, 2)
    top_p = top_p / top_p.sum(-1, keepdims=True)
    act = jax.nn.silu
    ref = jnp.zeros_like(xf)
    for e in range(cfg.num_experts):
        h = act(xf @ p["gate"][e]) * (xf @ p["up"][e])
        ye = h @ p["down"][e]
        w = ((top_e == e) * top_p).sum(-1)
        ref += ye * w[:, None]
    np.testing.assert_allclose(y.reshape(-1, cfg.d_model), ref, atol=1e-4)
    assert np.isfinite(float(aux))


def test_moe_capacity_drops_overflow():
    cfg = moe_cfg()
    p = _moe_params(cfg)
    # skew the router so all tokens pick expert 0 hardest
    p["router"] = p["router"].at[:, 0].add(100.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, cfg.d_model))
    y_small, _ = moe_lib.moe_apply(p, cfg, x, capacity_factor=0.25)
    y_drop, _ = moe_lib.moe_apply(p, cfg, x, dropless=True)
    assert not np.allclose(np.asarray(y_small), np.asarray(y_drop))


def test_moe_aux_loss_balanced_lower_than_skewed():
    cfg = moe_cfg()
    p = _moe_params(cfg)
    # positive inputs + a positive router column → all tokens rank expert 0
    # first; balanced router leaves routing to chance
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(3),
                                  (4, 16, cfg.d_model))) + 0.05
    _, aux_balanced = moe_lib.moe_apply(p, cfg, x)
    p_skew = dict(p)
    p_skew["router"] = jnp.zeros_like(p["router"]).at[:, 0].set(1.0)
    _, aux_skewed = moe_lib.moe_apply(p_skew, cfg, x)
    assert float(aux_skewed) > float(aux_balanced)


def test_moe_shared_expert_always_active():
    cfg = moe_cfg(num_shared_experts=1)
    p = _moe_params(cfg)
    x = jnp.zeros((1, 4, cfg.d_model))
    y, _ = moe_lib.moe_apply(p, cfg, x, dropless=True)
    assert y.shape == x.shape


# ---------------------------------------------------------------------------
# SSM
# ---------------------------------------------------------------------------

def ssm_cfg():
    return mini_cfg(arch_type="ssm", ssm=True, num_heads=0, num_kv_heads=0,
                    d_ff=0, ssm_state_dim=16, ssm_head_dim=16, ssm_chunk=8)


def test_mamba2_prefill_then_decode_matches_forward():
    cfg = ssm_cfg()
    leafs = ssm_lib.init_mamba2(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda l: l.value, leafs,
                     is_leaf=lambda x: hasattr(x, "names"))
    b, s = 2, 24
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model))
    full = ssm_lib.mamba2_forward(p, cfg, x)
    y_pre, cache = ssm_lib.mamba2_prefill(p, cfg, x[:, :-1])
    np.testing.assert_allclose(full[:, :-1], y_pre, atol=1e-4)
    y_dec, cache2 = ssm_lib.mamba2_decode(p, cfg, x[:, -1:], cache)
    np.testing.assert_allclose(full[:, -1:], y_dec, atol=1e-4)
    assert int(cache2.length) == s


def test_ssd_causality():
    cfg = ssm_cfg()
    leafs = ssm_lib.init_mamba2(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda l: l.value, leafs,
                     is_leaf=lambda x: hasattr(x, "names"))
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(1), (1, 16, cfg.d_model))
    y1 = ssm_lib.mamba2_forward(p, cfg, x)
    x2 = x.at[:, 10].add(5.0)       # future perturbation
    y2 = ssm_lib.mamba2_forward(p, cfg, x2)
    np.testing.assert_allclose(y1[:, :10], y2[:, :10], atol=1e-4)
    assert not np.allclose(y1[:, 10:], y2[:, 10:], atol=1e-4)
