"""The repro.runtime compat layer: shard_map resolution on the installed
JAX, eager spec validation, mesh construction/divisibility, and the
split/gather round-trip (N=1 here; real 8-worker collectives via the
subprocess check, which absorbs the old test_split_gather_roundtrip)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import run_dist_prog
from repro import runtime
from repro.core import tp
from repro.runtime import collectives as C


# ---------------------------------------------------------------------------
# shard_map resolution
# ---------------------------------------------------------------------------

def test_shard_map_resolves_on_installed_jax():
    impl, check_kw = runtime.resolve_shard_map()
    assert callable(impl)
    # either of the two known check-flag spellings, or a future signature
    # whose flag the shim simply drops
    assert check_kw == runtime.CHECK_KW
    assert check_kw is None or check_kw.startswith("check_")
    assert runtime.JAX_VERSION == jax.__version__


def test_engine_executes_on_current_jax():
    mesh = runtime.tp_mesh(1)
    f = runtime.engine(lambda x: C.psum(x.sum(), "model"),
                       in_specs=P("model", None), out_specs=P(), mesh=mesh)
    assert float(f(jnp.ones((4, 4)))) == 16.0


# ---------------------------------------------------------------------------
# spec validation errors
# ---------------------------------------------------------------------------

def test_rejects_unknown_axis_with_clear_error():
    mesh = runtime.tp_mesh(1)
    with pytest.raises(ValueError, match="bogus.*only has axes"):
        runtime.engine(lambda x: x, in_specs=P("bogus", None),
                       out_specs=P(), mesh=mesh)


def test_rejects_non_spec_leaves():
    mesh = runtime.tp_mesh(1)
    with pytest.raises(TypeError, match="expected PartitionSpec"):
        runtime.engine(lambda x: x, in_specs="model", out_specs=P(),
                       mesh=mesh)


def test_rejects_repeated_axis_in_one_spec():
    mesh = runtime.tp_mesh(1)
    with pytest.raises(ValueError, match="more than one dimension"):
        runtime.validate_specs(mesh, P("model", "model"))


# ---------------------------------------------------------------------------
# TPMesh contract
# ---------------------------------------------------------------------------

def test_tp_mesh_builds_and_validates():
    m = runtime.tp_mesh(1)
    assert m.size == 1 and m.axis == "model"
    assert m.padded(10, chunks=4) == 12
    m.validate_divisible(n_vertices=8, dim=4)   # fine at N=1
    with pytest.raises(ValueError, match="devices visible"):
        runtime.tp_mesh(9999)


def test_tp_mesh_divisibility_error_names_padding():
    # fabricate an N=4 contract check without needing 4 devices
    class Fake(runtime.TPMesh):
        @property
        def size(self):
            return 4

    f = Fake(runtime.tp_mesh(1).mesh)
    with pytest.raises(ValueError, match=r"10 % 4 != 0 \(pad to 12\)"):
        f.validate_divisible(n_vertices=10)
    with pytest.raises(ValueError, match=r"dim 6 % 4 != 0 \(pad to 8\)"):
        f.validate_divisible(dim=6)


def test_as_mesh_coercion():
    m = runtime.tp_mesh(1)
    assert runtime.as_mesh(m) is m.mesh
    assert runtime.as_mesh(m.mesh) is m.mesh
    with pytest.raises(TypeError):
        runtime.as_mesh("not a mesh")


# ---------------------------------------------------------------------------
# split/gather round-trip under the engine
# ---------------------------------------------------------------------------

def test_split_gather_roundtrip_single_device():
    mesh = runtime.tp_mesh(1)
    h = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
    f = runtime.engine(lambda x: tp.gather(tp.split(x)), mesh=mesh,
                       in_specs=P("model", None), out_specs=P("model", None))
    np.testing.assert_array_equal(f(h), h)


@pytest.mark.slow
def test_split_gather_roundtrip_8_workers():
    """Real 8-device all-to-alls in a child process (forced host devices)."""
    run_dist_prog("check_runtime_roundtrip.py")
