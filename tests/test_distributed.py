"""Multi-worker correctness via child processes with 8 forced host devices.

The main pytest process stays on 1 device; each child runs with the pinned
``conftest.DIST_XLA_FLAGS`` (``--xla_force_host_platform_device_count=8``)
so the runtime-engine collectives (all_to_all gather/split, halo exchange,
psum) execute across 8 real device buffers.
"""
import pytest

from conftest import run_dist_prog


@pytest.mark.slow
def test_tp_equivalence_8_workers():
    run_dist_prog("check_tp_equivalence.py")


@pytest.mark.slow
def test_dp_baseline_8_workers():
    run_dist_prog("check_dp_baseline.py")


@pytest.mark.slow
def test_explicit_collectives_8_workers():
    """runtime.smap a2a mixing + EP MoE ≡ constraint path ≡ 1-device oracle."""
    run_dist_prog("check_explicit_collectives.py")
