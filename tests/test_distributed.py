"""Multi-worker correctness via child processes with 8 forced host devices.

The main pytest process stays on 1 device (see conftest); each child sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the shard_map
collectives (all_to_all gather/split, halo exchange, psum) execute across 8
real device buffers.
"""
import os
import subprocess
import sys

import pytest

HERE = os.path.dirname(__file__)
SRC = os.path.abspath(os.path.join(HERE, "..", "src"))
PROGS = os.path.join(HERE, "dist_progs")


def run_prog(name, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(PROGS, name)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert proc.returncode == 0, \
        f"{name} failed:\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    assert proc.stdout.strip().endswith(f"OK {name[:-3]}")


@pytest.mark.slow
def test_tp_equivalence_8_workers():
    run_prog("check_tp_equivalence.py")


@pytest.mark.slow
def test_dp_baseline_8_workers():
    run_prog("check_dp_baseline.py")


@pytest.mark.slow
def test_explicit_collectives_8_workers():
    """shard_map a2a mixing + EP MoE ≡ constraint path ≡ 1-device oracle."""
    run_prog("check_explicit_collectives.py")
