"""The real multi-host launch path (``runtime.distributed``) under test.

Fast lane: eager topology validation (mismatched ``process_id`` /
missing coordinator never touch the network), the env contract parser,
``resolve_mesh_shape``'s multihost accounting note, and the ledger
merge machinery (``CommLedger.from_dict`` / ``merge_from``) that
coordinator-side verdict merging rides on.

Slow lane (the acceptance gate): ``check_multihost.py`` under the
multi-process harness — a single-process 8-device reference run, then
2 processes × 4 fake devices with a localhost coordinator, which must
reproduce every loss AND grad (all four modes × both backends, pure TP
and (2,4) hybrid) to atol 1e-5; plus the failure modes: unreachable
coordinator and under-populated job both fail actionably instead of
hanging past the timeout.
"""
import json

import jax
import pytest

from conftest import harness, max_tree_diff
from repro.core import decouple as D
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.runtime import resolve_mesh_shape, tp_mesh
from repro.runtime import distributed as dist
from repro.runtime.telemetry import CommLedger, TelemetryError


# ---------------------------------------------------------------------------
# fast: eager topology validation (no sockets, no backend)
# ---------------------------------------------------------------------------

def test_initialize_rejects_bad_topology():
    with pytest.raises(ValueError, match=r"process_id=5 out of range"):
        dist.initialize(coordinator_address="127.0.0.1:1",
                        num_processes=2, process_id=5)
    with pytest.raises(ValueError, match="coordinator address"):
        dist.initialize(num_processes=2, process_id=0)
    with pytest.raises(ValueError, match="host:port"):
        dist.initialize(coordinator_address="nocolon",
                        num_processes=2, process_id=0)
    with pytest.raises(ValueError, match="num_processes=0"):
        dist.initialize(coordinator_address="127.0.0.1:1",
                        num_processes=0, process_id=0)


def test_env_topology_parsing():
    env = {dist.ENV_COORDINATOR: "10.0.0.1:1234",
           dist.ENV_NUM_PROCESSES: "16", dist.ENV_PROCESS_ID: "3",
           dist.ENV_INIT_TIMEOUT: "5.5"}
    assert dist.env_topology(env) == {
        "coordinator_address": "10.0.0.1:1234", "num_processes": 16,
        "process_id": 3, "timeout": 5.5}
    assert dist.env_topology({}) == {}
    with pytest.raises(ValueError, match="NUM_PROCESSES"):
        dist.env_topology({dist.ENV_NUM_PROCESSES: "two"})


def test_single_process_context_without_init():
    assert not dist.is_initialized()
    ctx = dist.context()
    assert ctx.num_processes == 1 and ctx.process_id == 0
    assert ctx.is_coordinator and not ctx.is_distributed
    assert dist.is_coordinator()
    assert dist.topology_note() == ""       # no noise on a single process


def test_topology_query_before_initialize_raises(monkeypatch):
    """With the multihost env contract set, querying the topology before
    initialize() must raise (a local-only backend would report every
    rank as the coordinator) instead of silently answering wrong."""
    monkeypatch.setenv(dist.ENV_NUM_PROCESSES, "2")
    monkeypatch.setenv(dist.ENV_COORDINATOR, "127.0.0.1:1")
    with pytest.raises(RuntimeError, match="initialize\\(\\) has not run"):
        dist.context()
    with pytest.raises(RuntimeError, match="initialize\\(\\) has not run"):
        dist.process_count()


def test_resolve_mesh_shape_note_names_process_topology():
    note = " [multihost: 2 processes × 4 local devices each = 8 global " \
           "devices; this process (0) holds only jax.local_devices()]"
    with pytest.raises(ValueError, match="2 processes × 4 local devices"):
        resolve_mesh_shape(8, model=16, note=note)
    with pytest.raises(ValueError, match="2 processes × 4 local devices"):
        resolve_mesh_shape(8, data=3, note=note)
    # the note must not change the accounting itself
    assert resolve_mesh_shape(8, model=4, data=2, note=note) == (1, 2, 4)


# ---------------------------------------------------------------------------
# fast: coordinator-side ledger merge (how per-process verdicts combine)
# ---------------------------------------------------------------------------

def test_ledger_roundtrip_and_merge():
    led = CommLedger()
    led.add("all_to_all", "model", "float32", payload=128.0, wire=112.0,
            calls=2.0, mirror=True)
    led.add("all_gather", ("data",), "float32", payload=64.0, wire=64.0)
    clone = CommLedger.from_dict(json.loads(json.dumps(led.as_dict())))
    assert clone.as_dict() == led.as_dict()
    merged = CommLedger.from_dict(led.as_dict()).merge_from(clone)
    assert merged.wire_bytes("all_to_all") == 2 * led.wire_bytes(
        "all_to_all")
    assert merged.call_count("all_gather") == 2.0
    with pytest.raises(TelemetryError, match="malformed ledger key"):
        CommLedger.from_dict({"not-a-key": {}})


# ---------------------------------------------------------------------------
# fast: the jitted value-and-grad handle == eager value_and_grad
# ---------------------------------------------------------------------------

def test_value_and_grad_handle_matches_eager():
    """make_tp_value_and_grad (the multihost-safe single-executable
    spelling) must equal eager jax.value_and_grad of make_tp_loss_fn."""
    data = sbm_power_law(n=120, num_classes=4, feat_dim=8, avg_degree=6,
                         seed=3)
    mesh = tp_mesh(1)
    bundle = D.prepare_bundle(data, n_workers=1, n_chunks=2)
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=8,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eager = jax.value_and_grad(D.make_tp_loss_fn(
        cfg, bundle, mesh, mode="decoupled"))(params, bundle.train_mask)
    jitted = D.make_tp_value_and_grad(
        cfg, bundle, mesh, mode="decoupled")(params, bundle.train_mask)
    assert abs(float(eager[0]) - float(jitted[0])) < 1e-6
    assert max_tree_diff(eager[1], jitted[1]) < 1e-6


# ---------------------------------------------------------------------------
# slow: the real 2-process × 4-device topology vs the 8-device reference
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multihost_matches_single_process(tmp_path):
    ref = tmp_path / "multihost_ref.json"
    env = {"CHECK_MULTIHOST_REF": str(ref)}
    # reference: the PR 3 single-process suite (1 × 8 forced devices)
    harness.run_multiproc("check_multihost.py", n_processes=1,
                          devices_per_process=8, timeout=1800, env=env)
    assert ref.exists()
    # the real thing: 2 jax.distributed processes × 4 devices each
    results = harness.run_multiproc("check_multihost.py", n_processes=2,
                                    devices_per_process=4, timeout=1800,
                                    env=env)
    # per-process telemetry ledgers, merged at the coordinator (here):
    # every process traced the same SPMD program, so the ledgers agree
    # and the merged job total is exactly 2× per-device counters
    verdicts = [r.verdicts[-1] for r in results]
    assert sorted(v["process_id"] for v in verdicts) == [0, 1]
    led0, led1 = (CommLedger.from_dict(v["ledger"]) for v in verdicts)
    assert led0.as_dict() == led1.as_dict()
    assert led0.wire_bytes("all_to_all", train=True) > 0
    merged = CommLedger.from_dict(verdicts[0]["ledger"]).merge_from(led1)
    assert merged.wire_bytes("all_to_all", train=True) == \
        2 * led0.wire_bytes("all_to_all", train=True)
    # both processes observed the identical (replicated) loss trajectory
    assert verdicts[0]["losses"] == verdicts[1]["losses"]


@pytest.mark.slow
def test_coordinator_unreachable_fails_fast():
    harness.run_multiproc("check_multihost.py", n_processes=1,
                          devices_per_process=2, timeout=300,
                          env={"CHECK_MULTIHOST_MODE": "unreachable"})


@pytest.mark.slow
def test_mismatched_process_ids_fail_actionably():
    harness.run_multiproc("check_multihost.py", n_processes=1,
                          devices_per_process=2, timeout=300,
                          env={"CHECK_MULTIHOST_MODE": "mismatch"})


@pytest.mark.slow
def test_underpopulated_job_never_hangs_past_timeout(tmp_path):
    """NUM_PROCESSES=3 with only 2 processes launched: either
    initialization fails actionably within its own timeout, or the
    harness's hard cap kills the stragglers — never a silent hang."""
    env = {"CHECK_MULTIHOST_REF": str(tmp_path / "unused.json"),
           "NUM_PROCESSES": "3", "DIST_INIT_TIMEOUT": "10"}
    try:
        results = harness.run_multiproc(
            "check_multihost.py", n_processes=2, devices_per_process=2,
            timeout=120, env=env, check=False)
    except TimeoutError:
        return                        # hard cap did its job
    assert all(r.returncode != 0 for r in results), \
        "\n".join(r.summary() for r in results)
    blob = "\n".join(r.stderr for r in results)
    # the preflight line pins our topology context next to the failure
    # (which may be a C++ LOG(FATAL) deadline, not a Python traceback)
    assert "connecting to coordinator" in blob, blob
    assert ("DEADLINE" in blob or "Deadline" in blob
            or "NUM_PROCESSES" in blob), blob
