"""RT002 fixture: shard_map entered outside runtime/ — engine code must
stay backend-agnostic (runtime/smap.py owns the per-shard entry)."""
from jax.experimental.shard_map import shard_map


def leak(fn, mesh, specs):
    return shard_map(fn, mesh=mesh, in_specs=specs, out_specs=specs)
