"""RT001 fixture: the `from`-import spelling the old line regex missed.

The retired check matched calls prefixed with the literal module name;
a bare call after a from-import never matches it.
"""
from jax.lax import all_to_all


def leak(x, axis):
    return all_to_all(x, axis, split_axis=0, concat_axis=0)
