"""RT001 fixture: the plain attribute spelling (sanity — the one
spelling the old regex *did* catch; RT001 must too)."""
import jax


def leak(x, axis):
    return jax.lax.psum(x, axis)
