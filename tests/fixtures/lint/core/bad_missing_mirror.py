"""RT003 fixture: an engine-code collective call site (this file sits
under a ``core`` path segment) without an explicit ``mirror=`` — the
ledger cannot account the backward bytes of an undeclared site."""
from repro.runtime import collectives as C


def leak(h, axis):
    return C.all_gather(h, axis)
