"""RT004 fixture: a scan whose body communicates, without a
``telemetry.loop_scope`` wrapper — the body traces once but executes
trip×, so the ledger undercounts by the trip factor."""
import jax

from repro.runtime import collectives as C


def leak(k, perm, axis, n):
    def step(carry, _):
        nxt = C.ppermute(carry, axis, perm=perm, mirror=True)
        return nxt, None

    out, _ = jax.lax.scan(step, k, None, length=n)
    return out
