"""RT005 fixture: multihost bootstrap outside runtime/distributed.py —
both the env-contract read and the direct initialize call."""
import os

import jax


def leak():
    n = os.environ.get("NUM_PROCESSES")
    if n:
        jax.distributed.initialize()
    return n
