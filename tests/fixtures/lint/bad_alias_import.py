"""RT001 fixture: the aliased-import spelling the old line regex missed.

``_l.psum(`` does not match a regex anchored on the literal module name
``lax.``.
"""
import jax.lax as _l


def leak(x, axis):
    return _l.psum(x, axis)
