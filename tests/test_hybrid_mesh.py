"""Multi-axis mesh unit tests + the 8-device hybrid DP×TP equivalence run.

Fast tests cover the unified mesh owner (``runtime.mesh``): hybrid mesh
construction, the strict no-truncation device accounting, replica-axis
derivation (``data_axes_for``), the launch shims, and the degenerate
1×1 hybrid path through both engine backends (replica ops on size-1
axes).  The real 8-worker cross-mode equivalence — hybrid (2,4)/(4,2)
vs pure TP (model=8) vs a single-device reference, GCN/GAT × all four
modes × both backends — runs as a subprocess with pinned XLA_FLAGS
(tests/dist_progs/check_hybrid_mesh.py).
"""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import max_tree_diff, run_dist_prog
from repro.core import decouple as D
from repro.core import tp
from repro.gnn import dp_baseline as DP
from repro.gnn import models as M
from repro.graph import sbm_power_law
from repro.launch.mesh import make_host_mesh
from repro.runtime import (TPMesh, data_axes_for, hybrid_mesh,
                           resolve_mesh_shape, tp_mesh)


# ---------------------------------------------------------------------------
# resolve_mesh_shape: the strict device-accounting contract
# ---------------------------------------------------------------------------

def test_resolve_exact_and_inferred():
    assert resolve_mesh_shape(8, model=4, data=2) == (1, 2, 4)
    assert resolve_mesh_shape(8, data=2) == (1, 2, 4)          # inferred
    assert resolve_mesh_shape(8, data=2, pod=2) == (2, 2, 2)
    assert resolve_mesh_shape(1) == (1, 1, 1)


def test_resolve_refuses_silent_truncation():
    # the old make_host_mesh quietly used devs[:data*model]; now an error
    with pytest.raises(ValueError, match="truncate"):
        resolve_mesh_shape(8, model=2, data=2)
    with pytest.raises(ValueError, match="truncate"):
        resolve_mesh_shape(8, model=16, data=1)


def test_resolve_rejects_bad_degrees():
    with pytest.raises(ValueError, match="divide"):
        resolve_mesh_shape(8, data=3)                          # 8 % 3 != 0
    with pytest.raises(ValueError, match="positive"):
        resolve_mesh_shape(8, model=0)
    with pytest.raises(ValueError, match="positive"):
        resolve_mesh_shape(8, data=-2)
    with pytest.raises(ValueError, match="at least one device"):
        resolve_mesh_shape(0)


# ---------------------------------------------------------------------------
# hybrid_mesh / TPMesh with replica axes (1 real device)
# ---------------------------------------------------------------------------

def test_hybrid_mesh_single_device():
    m = hybrid_mesh(model=1, data=1)
    assert m.mesh.axis_names == ("data", "model")
    assert m.axis == "model" and m.data_axes == ("data",)
    assert m.size == 1 and m.data_size == 1 and m.n_devices == 1
    # strict: cannot ask for more than exists
    with pytest.raises(ValueError, match="truncate|divide"):
        hybrid_mesh(model=2, data=1)


def test_tpmesh_rejects_bad_replica_axes():
    raw = hybrid_mesh(model=1, data=1).mesh
    with pytest.raises(ValueError, match="data axis"):
        TPMesh(raw, axis="model", data_axes=("bogus",))
    with pytest.raises(ValueError, match="both the model axis"):
        TPMesh(raw, axis="model", data_axes=("model",))


def test_tpmesh_hybrid_divisibility_counts_all_devices():
    # fabricate a (data=2, model=4) contract check without 8 devices
    class Fake(TPMesh):
        @property
        def size(self):
            return 4

        @property
        def data_size(self):
            return 2

    f = Fake(tp_mesh(1).mesh)
    with pytest.raises(ValueError, match=r"20 % 8 != 0 \(pad to 24\)"):
        f.validate_divisible(n_vertices=20)      # vertices shard over 8
    with pytest.raises(ValueError, match=r"dim 6 % 4 != 0"):
        f.validate_divisible(dim=6)              # features over model only
    f.validate_divisible(n_vertices=16, dim=8)   # fits both contracts


# ---------------------------------------------------------------------------
# data_axes_for: no silent () for unknown axes
# ---------------------------------------------------------------------------

def test_data_axes_for_tpmesh_and_raw():
    hm = hybrid_mesh(model=1, data=1)
    assert data_axes_for(hm) == ("data",)
    assert data_axes_for(hm.mesh) == ("data",)
    assert data_axes_for(tp_mesh(1)) == ()       # pure TP: genuinely none
    assert data_axes_for(tp_mesh(1).mesh) == ()


def test_data_axes_for_rejects_unknown_axes():
    dev = np.array(jax.devices()[:1])
    weird = jax.sharding.Mesh(dev.reshape(1, 1), ("replica", "model"))
    with pytest.raises(ValueError, match="replica"):
        data_axes_for(weird)                     # not silently ()
    no_model = jax.sharding.Mesh(dev, ("data",))
    with pytest.raises(ValueError, match="no model axis"):
        data_axes_for(no_model)


# ---------------------------------------------------------------------------
# launch shims delegate to the single owner
# ---------------------------------------------------------------------------

def test_make_host_mesh_shim():
    m = make_host_mesh(model=1, data=1)
    assert isinstance(m, jax.sharding.Mesh)
    assert m.axis_names == ("data", "model")
    with pytest.raises(ValueError, match="truncate|divide"):
        make_host_mesh(model=1, data=1, pod=2)   # pod path exists + strict
    # the documented subset escape hatch is exposed by the shim too
    m2 = make_host_mesh(model=1, data=1, devices=jax.devices()[:1])
    assert m2.axis_names == ("data", "model")


def test_vertex_spec_helper():
    assert tp.vertex_axes("model", ()) == "model"
    assert tp.vertex_axes("model", ("data",)) == ("model", "data")
    assert tp.vertex_spec("model", ("pod", "data")) == \
        P(("model", "pod", "data"), None)
    assert tp.vertex_spec("model", ()) == P("model", None)


# ---------------------------------------------------------------------------
# degenerate 1×1 hybrid: replica ops run (size-1 axes) on both backends
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    data = sbm_power_law(n=200, num_classes=5, feat_dim=24, avg_degree=8,
                         seed=0)
    bundle = D.prepare_bundle(data, n_workers=1, n_chunks=3, n_replicas=1)
    return data, bundle, hybrid_mesh(model=1, data=1)


@pytest.mark.parametrize("mode", ["decoupled", "naive"])
def test_degenerate_hybrid_matches_pure_tp(setup, mode):
    data, bundle, hm = setup
    cfg = D.padded_gnn_config(data, bundle, model="gcn", hidden_dim=16,
                              num_layers=2)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    ref = jax.value_and_grad(D.make_tp_loss_fn(
        cfg, bundle, tp_mesh(1), mode=mode, backend="explicit"))(
        params, bundle.train_mask)
    for backend in ("explicit", "constraint"):
        got = jax.value_and_grad(D.make_tp_loss_fn(
            cfg, bundle, hm, mode=mode, backend=backend))(
            params, bundle.train_mask)
        assert abs(float(ref[0]) - float(got[0])) < 1e-5
        assert max_tree_diff(ref[1], got[1]) < 1e-5


def test_degenerate_hybrid_dp(setup):
    data, _, hm = setup
    dp_bundle = DP.prepare_dp_bundle(data, k=1, n_replicas=1)
    cfg = M.GNNConfig(model="gcn", in_dim=24, hidden_dim=16, num_classes=5,
                      num_layers=2, decoupled=False)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    ref = jax.value_and_grad(DP.make_dp_loss_fn(
        cfg, dp_bundle, tp_mesh(1), backend="explicit"))(
        params, dp_bundle.train_mask)
    got = jax.value_and_grad(DP.make_dp_loss_fn(
        cfg, dp_bundle, hm, backend="constraint"))(
        params, dp_bundle.train_mask)
    assert abs(float(ref[0]) - float(got[0])) < 1e-5
    assert max_tree_diff(ref[1], got[1]) < 1e-5


def test_bundle_mesh_mismatch_raises(setup):
    """The factories fail early when a bundle was prepared for a different
    (model, data) shape than the mesh provides (fabricated degrees — real
    multi-device checks live in the dist prog)."""
    data, _, _ = setup

    class Fake(TPMesh):
        @property
        def size(self):
            return 4

    fake = Fake(tp_mesh(1).mesh)
    # n=200 pads to 201 with (n_workers=1, chunks=3): violates N=4
    odd = D.prepare_bundle(data, n_workers=1, n_chunks=3)
    with pytest.raises(ValueError, match="divisibility"):
        D._check_bundle_fits(odd, fake, "model", ())
    # padding fits N=4 but the bundle's comm plans were built for N=1
    fits = D.prepare_bundle(data, n_workers=1, n_chunks=4)
    with pytest.raises(ValueError, match="n_workers=1"):
        D._check_bundle_fits(fits, fake, "model", ())
    # the pure-TP escape hatch: data_axes=() must validate against the
    # model degree alone even when the mesh itself carries replica axes
    # (the replica count comes from the resolved axes, not the mesh's
    # own bookkeeping)
    class FakeHybrid(Fake):
        @property
        def data_size(self):
            return 2

    pure4 = D.prepare_bundle(data, n_workers=4, n_chunks=3)  # pads to 204
    assert pure4.n_padded % 4 == 0 and pure4.n_padded % 8 != 0
    D._check_bundle_fits(pure4, FakeHybrid(tp_mesh(1).mesh),
                         "model", ())                    # must not raise


# ---------------------------------------------------------------------------
# the real thing: 8 forced devices, all modes × backends × shapes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_hybrid_mesh_8_workers():
    # GCN/GAT × 4 modes × 2 backends × 2 hybrid shapes + pure-TP and
    # single-device references: the heaviest dist prog — generous timeout
    run_dist_prog("check_hybrid_mesh.py", timeout=2400)
