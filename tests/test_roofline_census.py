"""Parser regressions for the HLO-regex census (``launch.roofline``).

The census is now the *cross-check* of the telemetry ledger (the primary
measurement lives in ``repro.runtime.telemetry``), but a cross-check
that silently parses to zero is worse than none: both shipped PR 2 bugs
were exactly that — tuple-result ``/*index=N*/`` comments broke
``_DEF_RE`` (collectives skipped entirely) and literal
``replica_groups={{...}}`` fell back to group size 1 (wire factor 0, so
measured a2a bytes were always 0.0).  These tests pin the three
``replica_groups`` spellings, tuple-result definition lines, and the
while-loop trip multiplier on synthetic HLO text, and pin the deletion
of the dead ``_OP_RE``.
"""
import pytest

from repro.launch import roofline as R


# ---------------------------------------------------------------------------
# replica_groups: all three spellings
# ---------------------------------------------------------------------------

def test_group_size_iota_form():
    line = ("  %ag = f32[8,4]{1,0} all-gather(f32[1,4] %x), "
            "replica_groups=[2,4]<=[8], dimensions={0}")
    assert R._group_size(line, all_participants=8) == 4


def test_group_size_literal_form():
    # PR 2 regression: literal groups used to fall back to 1 → factor 0
    line = ("  %a2a = f32[4096,2] all-to-all(f32[4096,2] %x), "
            "replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}")
    assert R._group_size(line, all_participants=8) == 4


def test_group_size_empty_form_uses_num_partitions():
    line = "  %ar = f32[] all-reduce(f32[] %x), replica_groups={}"
    assert R._group_size(line, all_participants=8) == 8
    assert R._group_size(line, all_participants=1) == 1


def test_group_size_unparsed_defaults_to_one():
    assert R._group_size("  %x = f32[4] add(f32[4] %a, f32[4] %b)") == 1


# ---------------------------------------------------------------------------
# _DEF_RE: plain and tuple results (the /*index=N*/ comment regression)
# ---------------------------------------------------------------------------

def test_def_re_plain_result():
    m = R._DEF_RE.match(
        "  %y = f32[512,16]{1,0} all-to-all(f32[512,16] %x), "
        "replica_groups=[1,8]<=[8]")
    assert m and m.group(3) == "all-to-all"


def test_def_re_tuple_result_with_index_comments():
    # PR 2 regression: `/*index=5*/` inside tuple types contains `=` and
    # `*`, which the pre-fix regex treated as a definition terminator
    m = R._DEF_RE.match(
        "  %t = (f32[512,16]{1,0} /*index=0*/, f32[512,16] /*index=1*/) "
        "all-to-all-start(f32[512,16] %x), replica_groups={{0,1}}")
    assert m and m.group(3) == "all-to-all-start"


def test_dead_op_re_deleted():
    # the old collective matcher was dead code shadowing the real parse
    # path (_DEF_RE) — keep it gone
    assert not hasattr(R, "_OP_RE")


# ---------------------------------------------------------------------------
# hlo_census end-to-end on synthetic modules
# ---------------------------------------------------------------------------

def _census(body_lines, extra_comps=""):
    hlo = ("HloModule m, num_partitions=8\n\n"
           + extra_comps
           + "ENTRY %main (p0: f32[512,16]) -> f32[512,16] {\n"
           + "\n".join(body_lines) + "\n}\n")
    return R.hlo_census(hlo)


def test_census_counts_literal_group_a2a():
    c = _census([
        "  %p0 = f32[512,16]{1,0} parameter(0)",
        "  ROOT %a2a = f32[512,16]{1,0} all-to-all(f32[512,16] %p0), "
        "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}",
    ])
    bytes_ = 512 * 16 * 4
    assert c["collectives"]["all-to-all"] == bytes_ * 7 / 8
    assert c["collectives"]["counts"]["all-to-all"] == 1


def test_census_tuple_result_start_done_counted_once():
    c = _census([
        "  %p0 = f32[512,16]{1,0} parameter(0)",
        "  %st = (f32[512,16] /*index=0*/, f32[512,16] /*index=1*/) "
        "all-to-all-start(f32[512,16] %p0), replica_groups=[1,8]<=[8], "
        "dimensions={0}",
        "  ROOT %dn = f32[512,16]{1,0} all-to-all-done(%st)",
    ])
    # -start carries the bytes (tuple result = 2× operand shape); -done
    # must not double count
    assert c["collectives"]["counts"]["all-to-all"] == 1
    assert c["collectives"]["all-to-all"] == 2 * 512 * 16 * 4 * 7 / 8


def test_census_while_trip_multiplier():
    extra = (
        "%cond (s: f32[512,16]) -> pred[] {\n"
        "  %c4 = s32[] constant(4)\n"
        "  %i = s32[] constant(0)\n"
        "  ROOT %lt = pred[] compare(%i, %c4), direction=LT\n"
        "}\n\n"
        "%body (s: f32[512,16]) -> f32[512,16] {\n"
        "  %s = f32[512,16]{1,0} parameter(0)\n"
        "  ROOT %a2a = f32[512,16]{1,0} all-to-all(f32[512,16] %s), "
        "replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}\n"
        "}\n\n")
    c = _census([
        "  %p0 = f32[512,16]{1,0} parameter(0)",
        "  ROOT %w = f32[512,16]{1,0} while(f32[512,16] %p0), "
        "condition=%cond, body=%body",
    ], extra_comps=extra)
    # the loop body's a2a executes trip (=4)×, not once — the undercount
    # class the telemetry loop_scope mirrors on the ledger side
    assert c["collectives"]["counts"]["all-to-all"] == 4
    assert c["collectives"]["all-to-all"] == 4 * 512 * 16 * 4 * 7 / 8


def test_census_empty_groups_resolve_from_num_partitions():
    c = _census([
        "  %p0 = f32[512,16]{1,0} parameter(0)",
        "  ROOT %ar = f32[512,16]{1,0} all-reduce(f32[512,16] %p0), "
        "replica_groups={}, to_apply=%add",
    ])
    # group = num_partitions (8) → all-reduce factor 2·(8−1)/8
    assert c["collectives"]["all-reduce"] == 512 * 16 * 4 * 2 * 7 / 8


@pytest.mark.parametrize("kind,factor", [
    ("all-gather", 7 / 8), ("all-reduce", 2 * 7 / 8),
    ("reduce-scatter", 7.0), ("all-to-all", 7 / 8),
    ("collective-permute", 1.0),
])
def test_wire_factor_table(kind, factor):
    assert R._wire_factor(kind, 8) == factor
    assert R._wire_factor(kind, 1) == (1.0 if kind == "collective-permute"
                                       else 0.0)
