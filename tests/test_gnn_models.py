"""GNN model semantics vs dense linear-algebra oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.gnn import layers as L
from repro.gnn import models as M
from repro.graph import build_graph, sbm_power_law, chunk_graph


@pytest.fixture(scope="module")
def data():
    return sbm_power_law(n=300, num_classes=4, feat_dim=16, avg_degree=6,
                         seed=0)


def test_aggregate_equals_dense_spmm(data):
    g = data.graph
    gd = L.edge_list_dev(g)
    h = jnp.asarray(np.random.default_rng(0).normal(
        size=(g.n, 8)).astype(np.float32))
    out = L.aggregate(gd, h)
    ref = g.dense_adjacency() @ np.asarray(h)
    np.testing.assert_allclose(out, ref, atol=1e-4)


@pytest.mark.parametrize("n_chunks", [1, 3, 4])
def test_chunked_aggregation_equals_monolithic(data, n_chunks):
    g = data.graph
    gd = L.edge_list_dev(g)
    cg = L.chunked_dev(chunk_graph(g, n_chunks))
    h = jnp.asarray(np.random.default_rng(1).normal(
        size=(g.n, 12)).astype(np.float32))
    np.testing.assert_allclose(L.aggregate_chunked(cg, h),
                               L.aggregate(gd, h), atol=1e-4)


def test_chunked_respects_per_edge_weights(data):
    g = data.graph
    gd = L.edge_list_dev(g)
    cg = L.chunked_dev(chunk_graph(g, 3))
    h = jnp.asarray(np.random.default_rng(2).normal(
        size=(g.n, 8)).astype(np.float32))
    w = jnp.asarray(np.random.default_rng(3).uniform(
        size=(g.e,)).astype(np.float32))
    w_chunk = L.rechunk_edge_values(cg, w)
    np.testing.assert_allclose(
        L.aggregate_chunked(cg, h, edge_weight=w_chunk),
        L.aggregate(gd, h, edge_weight=w), atol=1e-4)


def test_segment_softmax_normalizes(data):
    g = data.graph
    scores = jnp.asarray(np.random.default_rng(4).normal(
        size=(g.e,)).astype(np.float32))
    alpha = L.segment_softmax(scores, jnp.asarray(g.dst), g.n)
    sums = jax.ops.segment_sum(alpha, jnp.asarray(g.dst), num_segments=g.n)
    has_edges = np.diff(g.indptr) > 0
    np.testing.assert_allclose(np.asarray(sums)[has_edges], 1.0, atol=1e-5)


def test_gat_attention_matches_manual(data):
    g = data.graph
    gd = L.edge_list_dev(g)
    key = jax.random.PRNGKey(0)
    p = L.init_gat_layer(key, 16, 8)
    h = jnp.asarray(data.features)
    alpha, hw = L.gat_attention(p, gd, h)
    # manual dense computation
    hw_np = np.asarray(h @ p["w"])
    sl = hw_np @ np.asarray(p["a_l"])
    sr = hw_np @ np.asarray(p["a_r"])
    e = sl[g.src] + sr[g.dst]
    e = np.where(e > 0, e, 0.2 * e)
    a_ref = np.zeros_like(e)
    for v in range(g.n):
        seg = slice(g.indptr[v], g.indptr[v + 1])
        ex = np.exp(e[seg] - e[seg].max())
        a_ref[seg] = ex / ex.sum()
    np.testing.assert_allclose(alpha, a_ref, atol=1e-5)


def test_decoupled_forward_is_power_iteration(data):
    """decoupled == Â^L · MLP(X) exactly (eq. 10)."""
    g = data.graph
    gd = L.edge_list_dev(g)
    cfg = M.GNNConfig(model="gcn", in_dim=16, hidden_dim=8, num_classes=4,
                      num_layers=2, gamma=0.9)
    params = M.init_params(jax.random.PRNGKey(1), cfg)
    x = jnp.asarray(data.features)
    out = M.decoupled_forward(params, cfg, gd, x)
    h = np.asarray(x)
    for i, p in enumerate(params["layers"]):
        h = h @ np.asarray(p["w"]) + np.asarray(p["b"])
        if i < cfg.num_layers - 1:
            h = np.maximum(h, 0)
    a = 0.9 * g.dense_adjacency()
    ref = a @ (a @ h)
    np.testing.assert_allclose(out, ref, atol=1e-3)


@pytest.mark.parametrize("model", ["gcn", "gat", "sage", "gin"])
def test_models_train_and_learn(data, model):
    from repro.gnn.train import train_full_graph
    cfg = M.GNNConfig(model=model, in_dim=16, hidden_dim=16, num_classes=4,
                      num_layers=2, decoupled=True)
    params, logs = train_full_graph(data, cfg, epochs=30, lr=1e-2,
                                    log_every=30)
    assert logs[-1].test_acc > 0.7, f"{model} failed to learn"
    assert np.isfinite(logs[-1].loss)


def test_coupled_vs_decoupled_accuracy_parity(data):
    """Paper §5.7: decoupled training reaches comparable accuracy."""
    from repro.gnn.train import train_full_graph
    accs = {}
    for dec in (False, True):
        cfg = M.GNNConfig(model="gcn", in_dim=16, hidden_dim=16,
                          num_classes=4, num_layers=2, decoupled=dec)
        _, logs = train_full_graph(data, cfg, epochs=60, lr=1e-2,
                                   log_every=60)
        accs[dec] = logs[-1].test_acc
    assert abs(accs[True] - accs[False]) < 0.1, accs


def test_rgcn_trains():
    from repro.graph import heterogeneous_sbm
    from repro.gnn.train import train_full_graph
    data = heterogeneous_sbm(n=300, num_classes=4, num_edge_types=3,
                             feat_dim=16, seed=0)
    cfg = M.GNNConfig(model="rgcn", in_dim=16, hidden_dim=16, num_classes=4,
                      num_layers=2, decoupled=False,
                      num_edge_types=3)
    params, logs = train_full_graph(data, cfg, epochs=30, lr=1e-2,
                                    log_every=30)
    assert np.isfinite(logs[-1].loss)
    assert logs[-1].test_acc > 0.5
